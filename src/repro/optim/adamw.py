"""AdamW with fp32 master params, global-norm clipping and optional
int8-compressed gradient exchange (error feedback) — pure JAX pytrees.

Model params may live in bf16; the optimizer keeps fp32 master copies and
moments (ZeRO-style sharding of these comes from the sharding rules: the
``embed`` dim of every weight shards over ``data`` in train mode, so m/v/
master scale with the pod).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    lr_min: float = 3e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False     # int8 + error feedback (cross-pod DP)


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: AdamWConfig):
    # copy=True: fp32 params must not alias the master copy (donation)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "master": jax.tree_util.tree_map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["ef"] = jax.tree_util.tree_map(zeros, params)
    return state


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _quantize_int8(g, ef):
    """Error-feedback int8 quantization (per-tensor scale).  Models the
    numerics of a compressed cross-pod all-reduce: the rounding residual is
    carried to the next step instead of being lost."""
    g = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    deq = q * scale
    return deq, g - deq


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.compress_grads:
        pairs = jax.tree_util.tree_map(_quantize_int8, grads, state["ef"])
        grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        master = master - lr * (u + cfg.weight_decay * master)
        return m, v, master

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                 state["master"])
    m = jax.tree_util.tree_map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree_util.tree_map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree_util.tree_map(
        lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"m": m, "v": v, "master": master, "step": step}
    if cfg.compress_grads:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
