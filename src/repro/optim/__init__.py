from repro.optim.adamw import AdamWConfig, global_norm, init, schedule, update

__all__ = ["AdamWConfig", "global_norm", "init", "schedule", "update"]
