"""Checkpointing: atomic, keep-k, async, mesh-elastic.

Arrays are saved *unsharded* (fetched to host) keyed by pytree path, with a
JSON metadata sidecar (step, arch, mesh shape).  On restore the arrays are
re-placed under whatever sharding the *current* context resolves — so a run
checkpointed on a 2-pod mesh restarts on a single pod (elastic rescale)
without conversion.  Writes go to a temp dir + atomic rename; a `latest`
symlink flips last, so a preemption mid-write can never corrupt the newest
complete checkpoint.  Async mode runs the serialization off the training
thread (checkpointing off the critical path).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np

from repro.distributed.sharding import current_ctx, named_sharding
from repro.obs.clock import now, to_wall


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, metadata: Optional[dict] = None):
        """Snapshot to host memory synchronously; write async if enabled."""
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {"step": step, "time": to_wall(now()), **(metadata or {})}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict):
        tmp = os.path.join(self.directory, f".tmp_step_{step:08d}")
        final = os.path.join(self.directory, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in host.items()})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        latest = os.path.join(self.directory, "latest")
        tmp_link = latest + ".tmp"
        if os.path.lexists(tmp_link):
            os.remove(tmp_link)
        os.symlink(os.path.basename(final), tmp_link)
        os.replace(tmp_link, latest)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.isdir(
                    os.path.join(self.directory, d)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                axes_tree=None):
        """Restore into the structure of `template` (values ignored).  With
        an active sharding context and `axes_tree`, leaves are device_put
        under the *current* mesh's shardings (elastic rescale)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.directory, f"step_{step:08d}")
        blobs = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        flat_t, treedef = _flatten(template)
        ctx = current_ctx()
        flat_axes = _flatten(axes_tree)[0] if axes_tree is not None else {}
        out = {}
        for k, tmpl in flat_t.items():
            arr = blobs[k]
            if ctx is not None and k in flat_axes:
                sh = named_sharding(flat_axes[k], arr.shape, ctx)
                out[k] = jax.device_put(arr, sh)
            else:
                out[k] = jax.numpy.asarray(arr, dtype=tmpl.dtype
                                           if hasattr(tmpl, "dtype") else None)
        leaves = [out[jax.tree_util.keystr(p)] for p, _ in
                  jax.tree_util.tree_flatten_with_path(template)[0]]
        return jax.tree_util.tree_unflatten(treedef, leaves), meta
