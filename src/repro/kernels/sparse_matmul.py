"""Pallas TPU block-gather sparse matmul — the WiSparse decode kernel.

TPU adaptation of the paper's TEAL-derived CUDA gather kernels (DESIGN.md
SS3): input channels are grouped into blocks of `blk` (>=128, the lane
width); a scalar-prefetch array lists the kept block ids and the grid
iterates only over those, with ``BlockSpec.index_map`` remapping each grid
step to the kept block's tile of W.  HBM->VMEM DMA traffic and MXU FLOPs
both shrink by (kept blocks / total blocks).  Per-channel WiSparse masks
are applied to x *before* the kernel (elementwise, free on the VPU), so
numerics match the paper's Eq. 5 exactly while skipping stays
block-granular.

Two variants:
  * shared  — one kept-block set for the whole batch (batched serving mode)
  * per_seq — per-sequence block sets (the paper's per-token masks); W tiles
    are re-fetched per sequence, which is exactly the batching cost the
    paper's limitation section describes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLK = 128      # channel-block (lane) size
DEFAULT_MT = 256       # output tile
DEFAULT_BT = 8         # batch tile

# Per-core VMEM (TPU on-chip vector memory, ~16 MB/core).  Every
# kernel's working set — all live operand/output blocks, double-buffered
# for the DMA pipeline — must fit under this or the launch fails at
# compile time on real hardware (the interpreter hides it on CPU).
VMEM_BYTES = 16 * 1024 * 1024
DOUBLE_BUFFER = 2      # pallas pipelines block DMA against compute


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """One operand/output of a kernel launch: its BlockSpec geometry in
    checkable form.  ``index_map`` is the exact callable handed to
    ``pl.BlockSpec`` (block-unit coordinates); ``padded`` is the array
    shape the kernel actually launches over (after any zero-padding)."""
    name: str
    block: Tuple[int, ...]
    padded: Tuple[int, ...]
    index_map: Callable
    bytes_per_elem: int = 4

    @property
    def block_bytes(self) -> int:
        n = 1
        for d in self.block:
            n *= d
        return n * self.bytes_per_elem


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """The launch geometry of one Pallas kernel, built by the same plan
    function the kernel itself consumes — so ``repro.analysis``'s
    pallas passes check exactly what launches, and the two cannot
    drift.  ``tiles`` holds the resolved tile sizes (post ``_fit_tile``)
    keyed by dim name for the divisibility contract checks."""
    kernel: str
    grid: Tuple[int, ...]
    inputs: Tuple[BlockPlan, ...]
    outputs: Tuple[BlockPlan, ...]
    tiles: Tuple[Tuple[str, int, int], ...]   # (dim, tile, padded_size)

    @property
    def blocks(self) -> Tuple[BlockPlan, ...]:
        return self.inputs + self.outputs

    def vmem_bytes(self) -> int:
        """Working-set estimate: every block double-buffered."""
        return DOUBLE_BUFFER * sum(b.block_bytes for b in self.blocks)


def shared_plan(B: int, n: int, m: int, kb: int, *,
                blk: int = DEFAULT_BLK, mt: int = DEFAULT_MT,
                bt: int = DEFAULT_BT, x_bytes: int = 4,
                w_bytes: int = 4) -> KernelPlan:
    """Launch plan for :func:`sparse_matmul_shared` (also its single
    source of geometry truth — the kernel reads tiles/grid from here)."""
    blk = min(blk, n)
    assert n % blk == 0, (n, blk)
    mt = _fit_tile(m, mt)
    bt = _fit_tile(B, bt)
    Bp = B + (-B % bt)
    mp = m + (-m % mt)
    grid = (Bp // bt, mp // mt, kb)
    return KernelPlan(
        kernel="sparse_matmul_shared", grid=grid,
        inputs=(
            BlockPlan("x", (bt, blk), (Bp, n),
                      lambda b, j, i, idx: (b, idx[i]), x_bytes),
            BlockPlan("w", (blk, mt), (n, mp),
                      lambda b, j, i, idx: (idx[i], j), w_bytes),
        ),
        outputs=(
            BlockPlan("y", (bt, mt), (Bp, mp),
                      lambda b, j, i, idx: (b, j), 4),
        ),
        tiles=(("B", bt, Bp), ("m", mt, mp), ("n", blk, n)))


def per_seq_plan(B: int, n: int, m: int, kb: int, *,
                 blk: int = DEFAULT_BLK, mt: int = DEFAULT_MT,
                 x_bytes: int = 4, w_bytes: int = 4) -> KernelPlan:
    """Launch plan for :func:`sparse_matmul_per_seq`."""
    blk = min(blk, n)
    assert n % blk == 0
    mt = _fit_tile(m, mt)
    mp = m + (-m % mt)
    grid = (B, mp // mt, kb)
    return KernelPlan(
        kernel="sparse_matmul_per_seq", grid=grid,
        inputs=(
            BlockPlan("x", (1, blk), (B, n),
                      lambda b, j, i, idx: (b, idx[b, i]), x_bytes),
            BlockPlan("w", (blk, mt), (n, mp),
                      lambda b, j, i, idx: (idx[b, i], j), w_bytes),
        ),
        outputs=(
            BlockPlan("y", (1, mt), (B, mp),
                      lambda b, j, i, idx: (b, j), 4),
        ),
        tiles=(("m", mt, mp), ("n", blk, n)))


def score_mask_plan(B: int, n: int, *, blk: int = DEFAULT_BLK,
                    x_bytes: int = 4) -> KernelPlan:
    """Launch plan for :func:`score_mask`."""
    blk = min(blk, n)
    assert n % blk == 0
    nb = n // blk
    return KernelPlan(
        kernel="score_mask", grid=(nb,),
        inputs=(
            BlockPlan("x", (B, blk), (B, n),
                      lambda j, ab: (0, j), x_bytes),
            BlockPlan("g", (blk,), (n,), lambda j, ab: (j,), 4),
            BlockPlan("rw", (B, 1), (B, 1), lambda j, ab: (0, 0), 4),
        ),
        outputs=(
            BlockPlan("xm", (B, blk), (B, n),
                      lambda j, ab: (0, j), x_bytes),
            BlockPlan("bs", (1, 1), (nb, 1), lambda j, ab: (j, 0), 4),
        ),
        tiles=(("n", blk, n),))


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU.  Kernel
    callers that pass ``interpret=None`` get this — so forgetting the
    kwarg can no longer silently run the interpreter on real TPUs (or
    crash on CPU with a compiled kernel)."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def _fit_tile(size: int, want: int) -> int:
    """Tile for a dim of ``size``: the largest divisor of ``size`` in
    [want/2, want] if one exists (full-width tiles, zero padding —
    e.g. 384 under a 256 tile runs at 192), else ``want`` with the
    caller padding up to a multiple.  Never degrades below want/2, so
    prime dims pad instead of collapsing to 1-wide tiles."""
    want = min(want, size)
    for t in range(want, max(want // 2, 1) - 1, -1):
        if size % t == 0:
            return t
    return want


def _pad_dim(a, axis: int, tile: int):
    """Pad ``axis`` up to a multiple of ``tile`` (zeros).  Returns the
    padded array and the padded size.  Zero-padding is exact here: extra
    batch rows compute garbage rows that are sliced away, and extra
    output columns only ever multiply against zero weight columns."""
    size = a.shape[axis]
    pad = -size % tile
    if pad:
        pads = [(0, 0)] * a.ndim
        pads[axis] = (0, pad)
        a = jnp.pad(a, pads)
    return a, size + pad


def _acc_kernel(idx_ref, x_ref, w_ref, o_ref):
    """One (batch-tile, out-tile) x kept-block accumulation step."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def sparse_matmul_shared(x, w, block_idx, *, blk: int = DEFAULT_BLK,
                         mt: int = DEFAULT_MT, bt: int = DEFAULT_BT,
                         interpret: Optional[bool] = None):
    """y[b, :] = sum_{kept blocks i} x[b, blk_i] @ w[blk_i, :].

    x: (B, n) already per-channel masked; w: (n, m); block_idx: (kb,) int32
    kept channel-block ids (entries may repeat-pad with 0 iff the padded
    lanes of x were zeroed).  Returns (B, m) float32.

    Tiles shrink only to a clean divisor in [tile/2, tile]; otherwise
    the dim is zero-padded up to a tile multiple and the result sliced
    back — full-width MXU tiles regardless of shape.  (The old fallback
    shrank the tile until it divided, which silently degraded to 1-wide
    tiles on prime dims.)
    """
    interpret = _resolve_interpret(interpret)
    B, n = x.shape
    m = w.shape[1]
    kb = block_idx.shape[0]
    plan = shared_plan(B, n, m, kb, blk=min(blk, n), mt=mt, bt=bt,
                       x_bytes=x.dtype.itemsize, w_bytes=w.dtype.itemsize)
    (_, bt, Bp), (_, mt, mp), (_, blk, _) = plan.tiles
    x, _ = _pad_dim(x, 0, bt)
    w, _ = _pad_dim(w, 1, mt)

    xs, ws = plan.inputs
    (ys,) = plan.outputs
    y = pl.pallas_call(
        _acc_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=plan.grid,
            in_specs=[
                pl.BlockSpec(xs.block, xs.index_map),
                pl.BlockSpec(ws.block, ws.index_map),
            ],
            out_specs=pl.BlockSpec(ys.block, ys.index_map),
        ),
        out_shape=jax.ShapeDtypeStruct(ys.padded, jnp.float32),
        interpret=interpret,
    )(block_idx, x, w)
    return y[:B, :m] if (Bp, mp) != (B, m) else y


def _acc_kernel_perseq(idx_ref, x_ref, w_ref, o_ref):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def sparse_matmul_per_seq(x, w, block_idx, *, blk: int = DEFAULT_BLK,
                          mt: int = DEFAULT_MT,
                          interpret: Optional[bool] = None):
    """Per-sequence kept-block sets (paper's per-token masks).

    x: (B, n) masked; w: (n, m); block_idx: (B, kb) int32.  Returns (B, m).
    Non-divisible output dims shrink to a clean divisor tile or pad
    (see sparse_matmul_shared).
    """
    interpret = _resolve_interpret(interpret)
    B, n = x.shape
    m = w.shape[1]
    kb = block_idx.shape[1]
    plan = per_seq_plan(B, n, m, kb, blk=min(blk, n), mt=mt,
                        x_bytes=x.dtype.itemsize, w_bytes=w.dtype.itemsize)
    (_, mt, mp), (_, blk, _) = plan.tiles
    w, _ = _pad_dim(w, 1, mt)

    xs, ws = plan.inputs
    (ys,) = plan.outputs
    y = pl.pallas_call(
        _acc_kernel_perseq,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=plan.grid,
            in_specs=[
                pl.BlockSpec(xs.block, xs.index_map),
                pl.BlockSpec(ws.block, ws.index_map),
            ],
            out_specs=pl.BlockSpec(ys.block, ys.index_map),
        ),
        out_shape=jax.ShapeDtypeStruct(ys.padded, jnp.float32),
        interpret=interpret,
    )(block_idx, x, w)
    return y[:, :m] if mp != m else y


def _score_mask_kernel(ab_ref, x_ref, g_ref, w_ref, xm_ref, bs_ref):
    """Fused WiSparse scoring: s=|x|*g^alpha, m=s>=tau, xm=x*m and the
    per-channel-block aggregate score (for block selection).  Each row's
    score contribution is scaled by its weight (serving: 0 for freed
    slots / pad tokens, 1 otherwise; all-ones is bit-identical to the
    unweighted sum).  The mask itself stays per-token (unweighted)."""
    alpha = ab_ref[0]
    tau = ab_ref[1]
    x = x_ref[...]
    g = jnp.maximum(g_ref[...], 1e-12).astype(jnp.float32)
    s = jnp.abs(x.astype(jnp.float32)) * jnp.power(g, alpha)
    keep = s >= tau
    xm_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    bs_ref[0, 0] = jnp.sum(jnp.where(keep, s, 0.0) * w_ref[...])


def score_mask(x, g, alpha, tau, *, blk: int = DEFAULT_BLK,
               interpret: Optional[bool] = None, row_weights=None):
    """Returns (x_masked (B,n), block_scores (n//blk,)) — Eq. 4/5 fused.
    row_weights (B,) optionally weights each row's block-score
    contribution (the serving engine's active-slot / real-token mask)."""
    interpret = _resolve_interpret(interpret)
    B, n = x.shape
    plan = score_mask_plan(B, n, blk=min(blk, n),
                           x_bytes=x.dtype.itemsize)
    ((_, blk, _),) = plan.tiles
    nb = n // blk
    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(tau, jnp.float32)])
    if row_weights is None:
        rw = jnp.ones((B, 1), jnp.float32)
    else:
        rw = row_weights.reshape(B, 1).astype(jnp.float32)
    xs, gs, rs = plan.inputs
    xo, bo = plan.outputs
    xm, bs = pl.pallas_call(
        _score_mask_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=plan.grid,
            in_specs=[
                pl.BlockSpec(xs.block, xs.index_map),
                pl.BlockSpec(gs.block, gs.index_map),
                pl.BlockSpec(rs.block, rs.index_map),
            ],
            out_specs=[
                pl.BlockSpec(xo.block, xo.index_map),
                pl.BlockSpec(bo.block, bo.index_map),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct(xo.padded, x.dtype),
                   jax.ShapeDtypeStruct(bo.padded, jnp.float32)],
        interpret=interpret,
    )(ab, x, g, rw)
    return xm, bs[:, 0]
