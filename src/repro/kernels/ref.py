"""Pure-jnp oracles for the Pallas kernels (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_sparse_matmul_shared(x, w, block_idx, blk: int):
    """y = sum over kept blocks of x[:, blk_i] @ w[blk_i, :] in f32.
    NOTE duplicate block ids contribute multiple times (pad contract: pad
    entries must point at zeroed-x blocks)."""
    B, n = x.shape
    m = w.shape[1]
    y = jnp.zeros((B, m), jnp.float32)
    for i in range(block_idx.shape[0]):
        b = block_idx[i]
        xs = jax.lax.dynamic_slice(x, (0, b * blk), (B, blk))
        ws = jax.lax.dynamic_slice(w, (b * blk, 0), (blk, m))
        y = y + xs.astype(jnp.float32) @ ws.astype(jnp.float32)
    return y


def ref_sparse_matmul_per_seq(x, w, block_idx, blk: int):
    def one(xb, idx):
        return ref_sparse_matmul_shared(xb[None], w, idx, blk)[0]
    return jax.vmap(one)(x, block_idx)


def ref_score_mask(x, g, alpha, tau, blk: int):
    gf = jnp.maximum(g.astype(jnp.float32), 1e-12)
    s = jnp.abs(x.astype(jnp.float32)) * jnp.power(gf, alpha)
    keep = s >= tau
    xm = jnp.where(keep, x, jnp.zeros_like(x))
    nb = x.shape[1] // blk
    bs = jnp.where(keep, s, 0.0).sum(0).reshape(nb, blk).sum(-1)
    return xm, bs


def ref_wisparse_project(x, w, sp, k_blocks: int, blk: int):
    """Full-op oracle: score -> mask -> top-k blocks (rank-limited by the
    layer's keep_frac) -> gathered matmul."""
    xm, bs = ref_score_mask(x, sp["g"], sp["alpha"], sp["tau"], blk)
    _, idx = jax.lax.top_k(bs, k_blocks)
    nb = x.shape[1] // blk
    kb_l = jnp.round(sp["keep_frac"] * nb).astype(jnp.int32)
    rank_ok = jnp.arange(k_blocks) < kb_l
    keep_blocks = jnp.zeros((nb,), bool).at[idx].set(rank_ok)
    xm = xm * jnp.repeat(keep_blocks, blk)[None].astype(xm.dtype)
    return ref_sparse_matmul_shared(xm, w, idx, blk)
