"""Jit'd end-to-end WiSparse projection built on the Pallas kernels.

This is the ``backend="pallas"`` path of ``repro.core.sparse_linear``:
  1. fused scoring + per-channel threshold mask (Eq. 4/5) + per-block
     aggregate scores (score_mask kernel),
  2. static-budget top-k block selection (k from the policy's k_max_frac;
     ranks beyond the layer's traced keep_frac get their x zeroed, so the
     per-layer allocation still binds),
  3. block-gather matmul over exactly the kept blocks (sparse_matmul).

All execution state arrives as explicit arguments (``k_frac``,
``token_weights``) — typically from the caller's ``SparsityPolicy``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import sparse_matmul as K


def channel_plan(n: int, block: int = 128):
    """Channel-block geometry of :func:`wisparse_project`: resolved block
    width, zero-padded channel count and block count — the PR 5 contract
    (full-width blocks via padding, never 1-wide fallback blocks).  The
    projection consumes this plan and ``repro.analysis``'s pallas pass
    checks it, so the two cannot drift."""
    blk = min(block, n)
    n_padded = n + (-n % blk)
    return blk, n_padded, n_padded // blk


def wisparse_project(x, w, sp, *, block: int = 128, k_frac: float = 1.0,
                     interpret=None, per_seq: bool = False,
                     token_weights=None):
    """x: (..., n); w: (n, *out).  Returns x W with WiSparse block sparsity.

    interpret: Pallas interpret mode — ``None`` (default) auto-detects
    from the backend (compiled on TPU, interpreted elsewhere), matching
    ``SparsityPolicy.interpret``.

    token_weights: per-row weights for the shared block-score aggregate
    (the serving engine's active-slot / real-token mask, fused into the
    kernel); explicit None disables weighting."""
    interpret = K._resolve_interpret(interpret)
    n = w.shape[0]
    w2 = w.reshape(n, -1)
    lead = x.shape[:-1]
    xf = x.reshape(-1, n)
    blk, n_padded, _ = channel_plan(n, block)
    g = sp["g"]
    pad = n_padded - n
    if pad:
        # keep full-width channel blocks on non-divisible dims by
        # zero-padding the channel axis (the old `while n % blk: blk -= 1`
        # fallback degraded to 1-wide blocks on prime dims, destroying
        # both MXU tiles and the block-selection granularity).  Exact:
        # padded channels score |0|*g^a = 0 and multiply zero weight
        # rows, so the tail block just aggregates fewer real channels —
        # the same partial-block semantics as the jnp topk_block path.
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        g = jnp.pad(g, (0, pad))
        n += pad
    nb = n // blk
    kb = max(1, min(nb, round(nb * k_frac)))

    tw = token_weights
    if tw is not None and tw.size != xf.shape[0]:
        raise ValueError(
            f"token_weights has {tw.size} rows but the projection sees "
            f"{xf.shape[0]} token rows; pass token_weights=None for "
            "dispatch-reshaped projections")
    xm, bs = K.score_mask(xf, g, sp["alpha"], sp["tau"], blk=blk,
                          interpret=interpret, row_weights=tw)
    _, idx = jax.lax.top_k(bs, kb)
    # per-layer budget: zero blocks ranked past keep_frac*nb
    kb_l = jnp.round(sp["keep_frac"] * nb).astype(jnp.int32)
    rank_ok = jnp.arange(kb) < kb_l
    keep_blocks = jnp.zeros((nb,), bool).at[idx].set(rank_ok)
    xm = xm * jnp.repeat(keep_blocks, blk)[None].astype(xm.dtype)
    # entries ranked past the budget keep their own (now-zeroed) block ids,
    # so their kernel contribution is exactly zero

    if per_seq:
        y = K.sparse_matmul_per_seq(xm, w2, jnp.tile(idx, (xf.shape[0], 1)),
                                    blk=blk, interpret=interpret)
    else:
        y = K.sparse_matmul_shared(xm, w2, idx, blk=blk, interpret=interpret)
    return y.astype(x.dtype).reshape(lead + w.shape[1:])
