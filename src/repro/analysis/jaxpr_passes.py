"""jaxpr/executable passes: lower the serving warmup set, verify the
compile-time contracts the engine's dynamic gates assume.

Three rules:

  * ``jit-donation`` — every executable in the serving warmup set
    (decode / chunked-prefill / spec-verify / KV segment ops, across a
    3-rung ladder) actually donates its pool caches: each donated input
    leaf must be aliased to an output in the lowered module
    (``tf.aliasing_output``), and a representative executable is
    compiled to confirm XLA honoured the aliasing
    (``input_output_alias``).  A dropped donation silently doubles the
    pool's HBM footprint and adds a full-pool copy per decode step —
    exactly what PR 1's "pool insertion donates" fix removed.
  * ``jit-static-args`` — every ``jax.jit`` signature in
    ``models/api.py`` / ``serving/engine.py`` (and the spec/pool/quality
    construction sites they feed) declares hashable, hash-stable static
    arguments: the ladder's policies must hash equal to their deep
    copies, or every equal-but-distinct policy object is a jit cache
    miss (a silent retrace — the bug class
    ``decode_retraces_after_warmup == 0`` guards at runtime, PR 3).
  * ``pallas-blockspec`` — the Pallas kernels' launch geometry
    (``kernels.sparse_matmul`` plans, ``kernels.ops.channel_plan``)
    keeps every BlockSpec index map in bounds over the whole grid, every
    tile dividing its padded dim (the PR 5 ``_fit_tile`` contract: never
    degrade below tile/2, pad instead), and the double-buffered working
    set under the per-core VMEM budget.

The passes import the model and lower real executables, so they need a
working jax install; the CLI's ``--ast-only`` skips them.
"""
from __future__ import annotations

import ast
import copy
import dataclasses
import functools
import itertools
import os
import warnings
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.registry import GlobalPass, register

# the serving shapes the warmup set is lowered at — tiny on purpose
# (reduced config; lowering is tracing, not compiling)
_SLOTS = 4
_MAX_LEN = 64
_CHUNK = 16
_GAMMA = 2
_BUDGETS = (0.0, 0.5, 0.7)


def _line_of(repo_root: str, relpath: str, needle: str) -> int:
    """1-based line of the first occurrence of ``needle`` (anchor for
    findings that belong to a construction site, not a single token)."""
    try:
        with open(os.path.join(repo_root, relpath), encoding="utf-8") as fh:
            for i, line in enumerate(fh, 1):
                if needle in line:
                    return i
    except OSError:
        pass
    return 1


@functools.lru_cache(maxsize=1)
def _warmup_context():
    """Reduced model + 3-rung uniform ladder + abstract warmup inputs,
    built once per process and shared by the executable passes."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import api
    from repro.models import params as P
    from repro.sparsity.ladder import PolicyLadder

    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    ladder = PolicyLadder.uniform(params, cfg, budgets=_BUDGETS)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    caches = P.abstract_params(api.cache_schema(cfg, _SLOTS, _MAX_LEN),
                               cfg.dtype)
    sds = jax.ShapeDtypeStruct
    i32, f32 = jnp.dtype("int32"), jnp.dtype("float32")
    shapes = {
        "tokens": sds((_SLOTS,), i32),
        "positions": sds((_SLOTS,), i32),
        "active": sds((_SLOTS,), f32),
        "chunk_tokens": sds((1, _CHUNK), i32),
        "chunk_offset": sds((1,), i32),
        "chunk_slot": sds((), i32),
        "chunk_weights": sds((_CHUNK,), f32),
        "verify_tokens": sds((_SLOTS, _GAMMA + 1), i32),
        "verify_weights": sds((_SLOTS, _GAMMA + 1), f32),
    }
    phases = [(pol.for_phase("prefill_dense"), pol.for_phase("prefill_sparse"),
               pol.for_phase("decode")) for pol in ladder.policies]
    sp_abs = [
        None if sp is None else jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sp)
        for sp in ladder.sps
    ]
    return cfg, params, ladder, abstract, caches, shapes, phases, sp_abs


def _count_leaves(tree) -> int:
    import jax
    return len(jax.tree_util.tree_leaves(tree))


def _lowered_alias_count(lowered) -> int:
    return lowered.as_text().count("tf.aliasing_output")


def _compiled_alias_count(compiled) -> int:
    text = compiled.as_text()
    return text.count("may-alias") + text.count("must-alias")


@register
class JitDonationPass(GlobalPass):
    """Donation actually takes for the full serving warmup executable set.

    For each of the 3 uniform-ladder rungs this lowers the decode and
    both prefill-chunk phase executables (plus the spec-verify
    executable at the verifier rung and the KV pool's donated segment
    ops) through the SAME construction sites the engine uses
    (``engine.make_engine_steps``, ``spec.make_verify_jit``,
    ``SlotKVPool``), then requires one ``tf.aliasing_output`` annotation
    per donated cache leaf.  Motivated by PR 1's pool-copy fix and PR
    4's rollback donation; dynamic counterpart:
    ``tests/test_perf_paths.py``.
    """

    rule = "jit-donation"

    def run(self, repo_root: str) -> List[Finding]:
        from repro.serving.engine import make_engine_steps
        from repro.serving.spec import make_verify_jit

        cfg, params, ladder, abstract, caches, shapes, phases, sp_abs = \
            _warmup_context()
        findings: List[Finding] = []
        engine_rel = "src/repro/serving/engine.py"
        engine_line = _line_of(repo_root, engine_rel, "donate_argnums=(3,)")
        n_cache = _count_leaves(caches)

        dstep, cstep, _pstep = make_engine_steps(cfg)
        lowered = {}
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            for r, ((pd, ps, dec), sp) in enumerate(zip(phases, sp_abs)):
                lowered[f"decode[rung={r}]"] = dstep.lower(
                    abstract, shapes["tokens"], shapes["positions"], caches,
                    sp, shapes["active"], policy=dec)
                for name, pol in (("prefill_dense", pd),
                                  ("prefill_sparse", ps)):
                    lowered[f"chunk[rung={r},{name}]"] = cstep.lower(
                        abstract, shapes["chunk_tokens"],
                        shapes["chunk_offset"], shapes["chunk_slot"], caches,
                        sp, shapes["chunk_weights"], policy=pol)
            vstep = make_verify_jit(cfg)
            _, _, dec0 = phases[0]
            lowered[f"verify[gamma={_GAMMA}]"] = vstep.lower(
                abstract, shapes["verify_tokens"], shapes["positions"],
                caches, sp_abs[0], shapes["verify_weights"], policy=dec0)

        for name, lo in lowered.items():
            got = _lowered_alias_count(lo)
            if got != n_cache:
                findings.append(Finding(
                    rule=self.rule, path=engine_rel, line=engine_line,
                    message=(f"{name}: donation dropped — {got} of "
                             f"{n_cache} donated cache leaves are aliased "
                             "to outputs in the lowered module; the pool "
                             "would be copied every step"),
                    snippet=name))

        # segment executables: the pool's donated write/rollback ops
        findings.extend(self._check_pool(repo_root, cfg))

        # compile one representative executable end-to-end: XLA must
        # honour the aliasing, not just receive the request
        _, _, dec1 = phases[1]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            compiled = dstep.lower(
                abstract, shapes["tokens"], shapes["positions"], caches,
                sp_abs[1], shapes["active"], policy=dec1).compile()
        got = _compiled_alias_count(compiled)
        if got < n_cache:
            findings.append(Finding(
                rule=self.rule, path=engine_rel, line=engine_line,
                message=(f"decode[rung=1] compiled: XLA honoured only "
                         f"{got} of {n_cache} requested cache aliases "
                         "(input_output_alias) — donation requested but "
                         "not taken on this backend"),
                snippet="decode[rung=1] input_output_alias"))
        return findings

    def _check_pool(self, repo_root: str, cfg) -> List[Finding]:
        import jax
        import jax.numpy as jnp

        from repro.models import api
        from repro.models import params as P
        from repro.serving.kv_pool import SlotKVPool

        findings: List[Finding] = []
        rel = "src/repro/serving/kv_pool.py"
        pool = SlotKVPool(cfg, _SLOTS, _MAX_LEN)
        caches_abs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), pool.caches)
        n_cache = _count_leaves(caches_abs)
        seg_abs = P.abstract_params(
            api.prefix_segment_schema(cfg, _CHUNK), cfg.dtype)
        sds = jax.ShapeDtypeStruct
        i32 = jnp.dtype("int32")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            cases = {
                "segment-write": (pool._write_jit, "donate_argnums=(0,)",
                                  (caches_abs, seg_abs, sds((), i32))),
                "rollback": (pool._rollback_jit, "donate_argnums=(0,)",
                             (caches_abs, sds((_SLOTS,), i32),
                              sds((_SLOTS,), i32))),
            }
            for name, (jitted, needle, args) in cases.items():
                got = _lowered_alias_count(jitted.lower(*args))
                if got != n_cache:
                    findings.append(Finding(
                        rule=self.rule, path=rel,
                        line=_line_of(repo_root, rel, needle),
                        message=(f"{name}: donation dropped — {got} of "
                                 f"{n_cache} donated pool leaves aliased"),
                        snippet=name))
        return findings


@register
class JitStaticArgsPass(GlobalPass):
    """Static-argnum hashability and stability of every jitted signature.

    Enumerates ``jax.jit`` call sites in ``models/api.py``,
    ``serving/engine.py``, ``serving/spec.py``, ``serving/kv_pool.py``
    and ``obs/quality.py`` via AST; requires each to declare its statics
    explicitly (``static_argnames``/``static_argnums``) when it takes a
    policy, and dynamically verifies the warmup set's policies are
    frozen, hashable and hash-stable under deep copy — an
    identity-hashed (or mutable) policy turns every call into a retrace
    (PR 2 made SparsityPolicy frozen/hashable for exactly this;
    dynamic counterpart: the zero-retrace gates in
    ``tests/test_serving.py`` / ``tests/test_ladder.py``).
    """

    rule = "jit-static-args"
    _FILES = (
        "src/repro/models/api.py",
        "src/repro/serving/engine.py",
        "src/repro/serving/spec.py",
        "src/repro/serving/kv_pool.py",
        "src/repro/obs/quality.py",
    )

    def run(self, repo_root: str) -> List[Finding]:
        findings: List[Finding] = []
        jit_sites = []          # (relpath, line, statics: set[str]|None)
        for rel in self._FILES:
            path = os.path.join(repo_root, rel)
            if not os.path.exists(path):
                continue
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=rel)
            for node in ast.walk(tree):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "jit"
                        and isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "jax"):
                    continue
                statics = None
                for kw in node.keywords:
                    if kw.arg in ("static_argnames", "static_argnums"):
                        statics = kw
                jit_sites.append((rel, node.lineno, statics))

        if not jit_sites:
            findings.append(Finding(
                rule=self.rule, path=self._FILES[0], line=1,
                message=("found no jax.jit sites in the serving/model "
                         "files — the static-args audit has lost track "
                         "of where executables are built; update "
                         "JitStaticArgsPass._FILES"),
                snippet="no jit sites"))
            return findings

        # the values actually used as statics in the warmup set
        _, _, ladder, *_rest, phases, _sp = _warmup_context()
        policies = {p for tup in phases for p in tup}
        policies.update(ladder.policies)
        for pol in policies:
            findings.extend(self._check_policy(pol, jit_sites))
        return findings

    def _check_policy(self, pol, jit_sites) -> List[Finding]:
        site = next(((rel, line) for rel, line, statics in jit_sites
                     if statics is not None), jit_sites[0][:2])
        rel, line = site
        out: List[Finding] = []

        def finding(msg):
            return Finding(rule=self.rule, path=rel, line=line,
                           message=msg, snippet=f"policy {pol!r:.60}")

        if not (dataclasses.is_dataclass(pol)
                and pol.__dataclass_params__.frozen):
            out.append(finding(
                f"static policy {type(pol).__name__} is not a frozen "
                "dataclass — mutable statics can change under a cached "
                "executable's feet"))
        try:
            # in-process jit cache key stability; cross-process hash
            # stability is NOT required (executables are not persisted),
            # so builtin hash() is exactly right here — this IS the
            # hashability check the rule exists to protect.
            h0 = hash(pol)  # repro: ignore[no-builtin-hash-persistence]
            h1 = hash(copy.deepcopy(pol))  # repro: ignore[no-builtin-hash-persistence]
        except TypeError as e:
            out.append(finding(
                f"static policy is unhashable ({e}) — jit would raise "
                "at every call site declaring it static"))
            return out
        if h0 != h1 or pol != copy.deepcopy(pol):
            out.append(finding(
                "static policy hash/eq is identity-based: a deep copy "
                "hashes differently, so every equal-but-distinct policy "
                "object is a fresh trace (silent retrace per call)"))
        return out


@register
class PallasBlockSpecPass(GlobalPass):
    """Pallas kernel launch contracts: index maps in bounds, tiles
    divide padded dims, VMEM working set under budget.

    Sweeps the kernel plans (``kernels.sparse_matmul.shared_plan`` /
    ``per_seq_plan`` / ``score_mask_plan`` — the same objects the
    kernels launch from) over representative serving shapes including
    the prime/awkward dims from PR 5's ``_fit_tile`` fix, evaluating
    every BlockSpec index map across the full grid with worst-case
    kept-block ids.  Motivated by the PR 5 tile-collapse bug (1-wide
    tiles on prime dims); dynamic counterpart: the awkward-shape
    regression tests in ``tests/test_kernels.py``.
    """

    rule = "pallas-blockspec"
    _REL = "src/repro/kernels/sparse_matmul.py"

    # (B, n_channels, m_out): production-ish plus the prime/awkward dims
    _SHAPES = (
        (8, 4096, 4096),
        (8, 4096, 11008),
        (1, 5120, 13824),
        (3, 2048, 311),      # prime output dim -> pad path
        (7, 384, 640),       # prime batch
        (5, 256, 509),       # prime output under tile/2
        (1, 128, 128),
    )

    def run(self, repo_root: str) -> List[Finding]:
        import numpy as np

        from repro.kernels import ops
        from repro.kernels import sparse_matmul as K

        findings: List[Finding] = []

        def check_plan(plan, idx_values, line_needle):
            line = _line_of(repo_root, self._REL, line_needle)
            for dim, tile, padded in plan.tiles:
                if tile < 1 or padded % tile:
                    findings.append(Finding(
                        rule=self.rule, path=self._REL, line=line,
                        message=(f"{plan.kernel}: tile {tile} does not "
                                 f"divide padded dim {dim}={padded} — the "
                                 "_fit_tile contract (divisor in "
                                 "[tile/2, tile] or pad to a multiple) "
                                 "is broken"),
                        snippet=f"{plan.kernel} tiles {plan.tiles}"))
            if plan.vmem_bytes() > K.VMEM_BYTES:
                findings.append(Finding(
                    rule=self.rule, path=self._REL, line=line,
                    message=(f"{plan.kernel}: double-buffered working set "
                             f"{plan.vmem_bytes()} B exceeds the "
                             f"{K.VMEM_BYTES} B per-core VMEM budget for "
                             f"grid {plan.grid}"),
                    snippet=f"{plan.kernel} vmem {plan.vmem_bytes()}"))
            grid_points = itertools.product(*(range(g) for g in plan.grid))
            if np.prod(plan.grid) > 8192:
                corners = [(0, g // 2, g - 1) for g in plan.grid]
                grid_points = itertools.product(*corners)
            for point in grid_points:
                for idx in idx_values:
                    for b in plan.blocks:
                        origin = b.index_map(*point, idx)
                        for d, (o, blk_d, pad_d) in enumerate(
                                zip(origin, b.block, b.padded)):
                            if o < 0 or (int(o) + 1) * blk_d > pad_d:
                                findings.append(Finding(
                                    rule=self.rule, path=self._REL,
                                    line=line,
                                    message=(
                                        f"{plan.kernel}: operand "
                                        f"{b.name} index map out of "
                                        f"bounds at grid {point} dim {d}: "
                                        f"block origin {int(o)} x "
                                        f"{blk_d} exceeds padded dim "
                                        f"{pad_d}"),
                                    snippet=f"{plan.kernel}/{b.name}"))
                                return      # one finding per plan is enough

        for B, n, m in self._SHAPES:
            blk = min(K.DEFAULT_BLK, n)
            nb_pad = (n + (-n % blk)) // blk
            for kb in {1, max(1, nb_pad // 2), nb_pad}:
                plan = K.shared_plan(B, n + (-n % blk), m, kb)
                idxs = [np.zeros(kb, np.int32),
                        np.full(kb, nb_pad - 1, np.int32)]
                check_plan(plan, idxs, "def shared_plan")
                plan = K.per_seq_plan(B, n + (-n % blk), m, kb)
                idxs = [np.zeros((B, kb), np.int32),
                        np.full((B, kb), nb_pad - 1, np.int32)]
                check_plan(plan, idxs, "def per_seq_plan")
            sm = K.score_mask_plan(B, n + (-n % blk))
            check_plan(sm, [np.zeros(2, np.float32)], "def score_mask_plan")

        # channel_plan contract: full-width blocks via padding, never
        # 1-wide fallback (the ops.wisparse_project side of PR 5's fix)
        ops_rel = "src/repro/kernels/ops.py"
        ops_line = _line_of(repo_root, ops_rel, "def channel_plan")
        for n in (128, 256, 311, 384, 509, 4096, 64, 1):
            blk, n_padded, nb = ops.channel_plan(n)
            if n_padded % blk or n_padded < n or n_padded - n >= blk \
                    or nb != n_padded // blk or blk != min(128, n):
                findings.append(Finding(
                    rule=self.rule, path=ops_rel, line=ops_line,
                    message=(f"channel_plan(n={n}) broke the padded "
                             f"full-width-block contract: blk={blk}, "
                             f"n_padded={n_padded}, nb={nb}"),
                    snippet=f"channel_plan({n})"))

        # _fit_tile postconditions over a dense sweep: result divides the
        # dim (or signals the pad path by returning `want` verbatim) and
        # never degrades below want/2
        fit_line = _line_of(repo_root, self._REL, "def _fit_tile")
        for size in range(1, 600):
            for want in (8, 128, 256):
                t = K._fit_tile(size, want)
                eff_want = min(want, size)
                ok = (1 <= t <= eff_want and 2 * t >= eff_want
                      and (size % t == 0 or t == eff_want))
                if not ok:
                    findings.append(Finding(
                        rule=self.rule, path=self._REL, line=fit_line,
                        message=(f"_fit_tile({size}, {want}) = {t} breaks "
                                 "the contract: divisor in [want/2, want] "
                                 "or want (pad path)"),
                        snippet=f"_fit_tile({size},{want})={t}"))
        return findings
