"""CLI driver: ``python -m repro.analysis [--baseline] [--format ...]``.

Exit status: 0 when no (non-baselined) findings, 1 when findings
remain, 2 on usage/configuration errors (unreadable baseline, missing
justification, unknown rule).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.findings import (DEFAULT_BASELINE, Baseline,
                                     BaselineError, Finding)
from repro.analysis.registry import (DEFAULT_ROOTS, AnalysisError,
                                     ast_passes, find_repo_root,
                                     global_passes, run_ast_passes,
                                     run_global_passes)


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker for the repro serving "
                    "stack (AST + jaxpr passes).")
    p.add_argument("--root", default=None,
                   help="repository root (default: walk up from this "
                        "package / cwd)")
    p.add_argument("--roots", default=",".join(DEFAULT_ROOTS),
                   help="comma-separated source roots relative to the "
                        f"repo root (default: {','.join(DEFAULT_ROOTS)})")
    p.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                   default=None, metavar="FILE",
                   help="filter findings through a committed baseline "
                        f"(default file: {DEFAULT_BASELINE})")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write the current findings as a new baseline "
                        "(justifications start as TODO and must be "
                        "filled in before the file loads)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--ast-only", action="store_true",
                   help="skip the jaxpr/executable passes (no model "
                        "lowering; used by fast pre-commit hooks)")
    p.add_argument("--list-rules", action="store_true",
                   help="print every registered rule and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = _parser().parse_args(argv)
    all_ast = ast_passes()
    all_global = global_passes()
    if args.list_rules:
        for rule, p in sorted(all_ast.items()):
            print(f"{rule:30s} [ast]   {p.describe()}")
        for rule, p in sorted(all_global.items()):
            print(f"{rule:30s} [jaxpr] {p.describe()}")
        return 0

    rules = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(all_ast) - set(all_global)
        if unknown:
            print(f"error: unknown rule(s): {', '.join(sorted(unknown))}; "
                  f"known: {', '.join(sorted(set(all_ast) | set(all_global)))}",
                  file=sys.stderr)
            return 2

    try:
        repo_root = find_repo_root(args.root)
    except AnalysisError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    roots = tuple(r.strip() for r in args.roots.split(",") if r.strip())

    findings: List[Finding] = []
    ast_rules = None if rules is None else sorted(rules & set(all_ast))
    if ast_rules is None or ast_rules:
        findings.extend(run_ast_passes(repo_root, roots=roots,
                                       rules=ast_rules))
    if not args.ast_only:
        glob_rules = None if rules is None else sorted(rules & set(all_global))
        if glob_rules is None or glob_rules:
            findings.extend(run_global_passes(repo_root, rules=glob_rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if args.write_baseline:
        Baseline.from_findings(
            findings, justification="TODO: justify or fix").save(
                args.write_baseline)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}"
              " — fill in every justification before committing")
        return 0

    baselined = 0
    if args.baseline:
        base_path = args.baseline
        if not os.path.isabs(base_path):
            base_path = os.path.join(repo_root, base_path)
        try:
            base = Baseline.load(base_path)
        except OSError as e:
            print(f"error: baseline {base_path}: {e}", file=sys.stderr)
            return 2
        except BaselineError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        kept = base.filter(findings)
        baselined = len(findings) - len(kept)
        findings = kept

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_dict() for f in findings],
            "baselined": baselined,
        }, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f.format())
        tail = f" ({baselined} baselined)" if baselined else ""
        print(f"{len(findings)} finding(s){tail}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
