"""Findings, inline suppressions and the committed baseline.

A :class:`Finding` is one rule violation at one source location.  Passes
produce them; the driver (``repro.analysis.registry``) filters them
through two escape hatches before they can fail a run:

  * **inline suppressions** — ``# repro: ignore[rule-id]`` on the
    flagged line (or ``# repro: ignore`` to silence every rule there).
    Suppressions are for sites where the invariant genuinely does not
    apply; the comment itself is the justification's anchor.
  * **the baseline** — a committed JSON file of grandfathered findings
    (``analysis-baseline.json`` at the repo root).  Every entry must
    carry a written ``justification``; the CLI refuses a baseline with
    empty ones.  Baseline entries match by *fingerprint* (rule id,
    relative path, stripped source line text) rather than line number,
    so unrelated edits above a grandfathered site don't resurrect it.

New findings — anything not suppressed and not baselined — exit the CLI
nonzero, which is what makes the CI ``analysis`` job a tripwire for the
invariants instead of a dashboard.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning")

# `# repro: ignore` or `# repro: ignore[rule-a, rule-b]`
_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[([A-Za-z0-9_,\s-]*)\])?")

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis-baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: ``rule`` is the registry id (kebab-case),
    ``path`` is repo-relative, ``line`` is 1-based, ``snippet`` is the
    stripped source line (the baseline fingerprint component)."""
    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"
    snippet: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity}[{self.rule}] "
                f"{self.message}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def parse_suppressions(source: str) -> Dict[int, Optional[frozenset]]:
    """Line -> suppressed rule ids (``None`` = all rules) for one file.
    Only the flagged line's own trailing comment counts — a suppression
    can't silently cover a whole block."""
    out: Dict[int, Optional[frozenset]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = m.group(1)
        if rules is None:
            out[i] = None
        else:
            out[i] = frozenset(r.strip() for r in rules.split(",") if r.strip())
    return out


def is_suppressed(f: Finding, suppressions: Dict[int, Optional[frozenset]]) -> bool:
    rules = suppressions.get(f.line, False)
    if rules is False:
        return False
    return rules is None or f.rule in rules


class BaselineError(ValueError):
    """The baseline file is malformed or carries unjustified entries."""


class Baseline:
    """Grandfathered findings, keyed by fingerprint with per-key counts
    (two identical offending lines in one file need a count of 2)."""

    def __init__(self, entries: Sequence[dict] = ()):
        self.entries = list(entries)
        self._counts: Dict[Tuple[str, str, str], int] = {}
        for e in self.entries:
            fp = (e["rule"], e["path"], e.get("snippet", ""))
            self._counts[fp] = self._counts.get(fp, 0) + int(e.get("count", 1))

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as fh:
            doc = json.load(fh)
        if doc.get("version") != BASELINE_VERSION:
            raise BaselineError(
                f"{path}: baseline version {doc.get('version')!r}, "
                f"expected {BASELINE_VERSION}")
        entries = doc.get("findings", [])
        for e in entries:
            for field in ("rule", "path"):
                if not e.get(field):
                    raise BaselineError(f"{path}: entry missing {field!r}: {e}")
            just = str(e.get("justification", "")).strip()
            if not just or just.upper().startswith("TODO"):
                raise BaselineError(
                    f"{path}: baselined finding {e['rule']} at {e['path']} "
                    f"has no written justification — every grandfathered "
                    f"finding must say why it is allowed to stand "
                    f"(--write-baseline emits TODO placeholders on "
                    f"purpose; fill them in)")
        return cls(entries)

    @classmethod
    def from_findings(cls, findings: Sequence[Finding],
                      justification: str = "TODO: justify") -> "Baseline":
        counts: Dict[Tuple[str, str, str], int] = {}
        for f in findings:
            counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
        entries = [
            {"rule": rule, "path": path, "snippet": snippet, "count": n,
             "justification": justification}
            for (rule, path, snippet), n in sorted(counts.items())
        ]
        return cls(entries)

    def save(self, path: str) -> None:
        doc = {"version": BASELINE_VERSION, "findings": self.entries}
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")

    def filter(self, findings: Sequence[Finding]) -> List[Finding]:
        """Findings NOT covered by the baseline (new findings).  Each
        baseline entry absorbs at most ``count`` matching findings."""
        budget = dict(self._counts)
        fresh = []
        for f in findings:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
            else:
                fresh.append(f)
        return fresh
