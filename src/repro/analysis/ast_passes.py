"""AST passes: the repo's serving-correctness contracts, checked at lint time.

Each pass encodes an invariant that was previously enforced only
dynamically (by running the engine under pytest) or socially (by review).
The rule ids are stable — they are what ``# repro: ignore[rule-id]``
suppressions and the committed baseline reference.

Rules:
  * ``no-raw-time``            — all timestamps flow through ``repro.obs.clock``
  * ``no-builtin-hash-persistence`` — salted ``hash()`` never feeds persisted state
  * ``no-thread-local-serving``     — no ambient thread-local serving state
  * ``hot-path-zero-cost``     — telemetry touch points guard with identity checks
  * ``traced-value-branch``    — no Python control flow on traced values
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.registry import AstPass, register


def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _snippet(source_lines: List[str], lineno: int) -> str:
    if 1 <= lineno <= len(source_lines):
        return source_lines[lineno - 1].strip()
    return ""


def _walk_with_parents(tree: ast.AST):
    """Yield every node; each node gains a ``_repro_parent`` backlink."""
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._repro_parent = parent  # type: ignore[attr-defined]
    return ast.walk(tree)


def _parents(node: ast.AST):
    while True:
        node = getattr(node, "_repro_parent", None)
        if node is None:
            return
        yield node


# ---------------------------------------------------------------------------
# no-raw-time
# ---------------------------------------------------------------------------

@register
class NoRawTime(AstPass):
    """Raw ``time.time/monotonic/perf_counter`` reads outside ``obs/clock.py``.

    Every serving-path timestamp must flow through ``repro.obs.clock``
    (``now()`` / an injected engine clock) or the flight recorder cannot
    capture it and replay diverges — the invariant PR 9 established
    (motivated by ``tests/test_flight.py`` replay bit-identity; this
    pass promotes the old grep-lint there, and widens its scope from the
    serving+obs trees to all of ``src/``, ``benchmarks/`` and
    ``examples/``).  ``time.sleep`` stays legal: it advances no clocks.
    """

    rule = "no-raw-time"
    _CALLS = frozenset({
        "time", "monotonic", "perf_counter",
        "time_ns", "monotonic_ns", "perf_counter_ns",
    })

    def applies_to(self, relpath: str) -> bool:
        return not relpath.replace("\\", "/").endswith("repro/obs/clock.py")

    def check(self, relpath, source, tree):
        lines = source.splitlines()
        findings = []
        # `from time import monotonic` makes the raw read invisible to a
        # call-site scan, so the import itself is the violation
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in self._CALLS:
                        findings.append(Finding(
                            rule=self.rule, path=relpath, line=node.lineno,
                            message=(f"importing time.{alias.name} bypasses "
                                     "repro.obs.clock — read time through "
                                     "obs.now() / the engine clock"),
                            snippet=_snippet(lines, node.lineno)))
            if isinstance(node, ast.Call):
                chain = _dotted(node.func)
                if chain and len(chain) == 2 and chain[0] == "time" \
                        and chain[1] in self._CALLS:
                    findings.append(Finding(
                        rule=self.rule, path=relpath, line=node.lineno,
                        message=(f"raw time.{chain[1]}() read — serving "
                                 "timestamps must flow through "
                                 "repro.obs.clock (now()/to_wall()) so the "
                                 "flight recorder can capture and replay "
                                 "them"),
                        snippet=_snippet(lines, node.lineno)))
        return findings


# ---------------------------------------------------------------------------
# no-builtin-hash-persistence
# ---------------------------------------------------------------------------

@register
class NoBuiltinHashPersistence(AstPass):
    """Builtin ``hash()`` feeding seeds, artifact keys, or serialized state.

    Builtin str/bytes hashing is salted per process (PYTHONHASHSEED), so
    any value derived from ``hash()`` that outlives the process — RNG
    fold-in tags, artifact/cache keys, anything written to disk — breaks
    cross-process reproducibility.  This is the exact PR 9 bug class:
    ``models/params.py`` seeded per-leaf init keys via ``hash(path)``,
    making "seed 0" params differ across processes until the crc32 fix
    (see the comment at ``models/params.py:init_params`` and the flight
    replay gates in ``tests/test_flight.py``).  Intra-process uses are
    flagged too — suppress with a justification if the value provably
    never escapes the process (``__hash__`` delegation is exempt).
    """

    rule = "no-builtin-hash-persistence"

    def check(self, relpath, source, tree):
        lines = source.splitlines()
        findings = []
        for node in _walk_with_parents(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "hash"):
                continue
            # delegating from __hash__ is in-process by construction
            in_hash_method = any(
                isinstance(p, ast.FunctionDef) and p.name == "__hash__"
                for p in _parents(node))
            if in_hash_method:
                continue
            findings.append(Finding(
                rule=self.rule, path=relpath, line=node.lineno,
                message=("builtin hash() is salted per process "
                         "(PYTHONHASHSEED) — deriving seeds, artifact keys "
                         "or persisted values from it breaks cross-process "
                         "determinism (the PR 9 params-init bug); use "
                         "zlib.crc32 / hashlib on stable bytes instead"),
                snippet=_snippet(lines, node.lineno)))
        return findings


# ---------------------------------------------------------------------------
# no-thread-local-serving
# ---------------------------------------------------------------------------

@register
class NoThreadLocalServing(AstPass):
    """Thread-local / ContextVar serving state must not reappear.

    PR 2–3 retired the thread-local ``sparsity_mode`` / ``capture_inputs``
    / ``token_weights`` contexts in favour of the explicit, hashable
    ``SparsityPolicy`` threaded through every forward — ambient state
    made executables depend on invisible inputs (retraces, capture
    leaks between engines; see ``tests/test_policy.py``'s shim-removal
    and policy-isolation tests).  Any ``threading.local()`` or
    ``contextvars.ContextVar`` in ``serving/`` or ``models/`` is a
    regression of that migration.
    """

    rule = "no-thread-local-serving"

    def applies_to(self, relpath: str) -> bool:
        p = "/" + relpath.replace("\\", "/")
        return "/serving/" in p or "/models/" in p

    def check(self, relpath, source, tree):
        lines = source.splitlines()
        findings = []
        bad_chains = {
            ("threading", "local"): "threading.local()",
            ("contextvars", "ContextVar"): "contextvars.ContextVar",
        }
        for node in ast.walk(tree):
            chain = None
            if isinstance(node, ast.Call):
                chain = _dotted(node.func)
            elif isinstance(node, ast.ClassDef):
                for base in node.bases:
                    bchain = _dotted(base)
                    if bchain and bchain in bad_chains:
                        chain = bchain
                        break
            if isinstance(node, ast.ImportFrom):
                if node.module == "threading" and any(
                        a.name == "local" for a in node.names):
                    chain = ("threading", "local")
                if node.module == "contextvars" and any(
                        a.name == "ContextVar" for a in node.names):
                    chain = ("contextvars", "ContextVar")
            if chain and chain in bad_chains:
                findings.append(Finding(
                    rule=self.rule, path=relpath, line=node.lineno,
                    message=(f"{bad_chains[chain]} in the serving/model "
                             "path — ambient per-thread state was retired "
                             "in PR 2-3 for the explicit SparsityPolicy; "
                             "thread state makes executables depend on "
                             "invisible inputs and breaks engine "
                             "isolation"),
                    snippet=_snippet(lines, node.lineno)))
        return findings


# ---------------------------------------------------------------------------
# hot-path-zero-cost
# ---------------------------------------------------------------------------

_SINKS = frozenset({"events", "tracer", "quality", "flight", "metrics",
                    "spans"})
_GUARD_EXEMPT_CALLERS = frozenset({"isinstance", "type"})


@register
class HotPathZeroCost(AstPass):
    """Telemetry touch points in the engine hot path must be identity-guarded.

    The zero-cost-when-off contract (PR 6, ``tests/test_obs.py``'s
    null-path identity tests): with telemetry disarmed the engine holds
    ``NULL_TELEMETRY`` whose sink fields are ``None``, and every emit
    site in ``serving/engine.py`` / ``serving/scheduler.py`` must reach
    a sink only under an ``is not None`` (or ``is NULL_*``) identity
    check — never through a truthiness test or an unconditional
    attribute chain, both of which either allocate or crash when
    telemetry is off.  The pass tracks ``self.obs.<sink>`` chains and
    local aliases (``ev = self.obs.events``) and requires a dominating
    identity guard for every dereference.
    """

    rule = "hot-path-zero-cost"

    def applies_to(self, relpath: str) -> bool:
        p = relpath.replace("\\", "/")
        return p.endswith(("repro/serving/engine.py",
                           "repro/serving/scheduler.py"))

    # -- sink expression recognition ------------------------------------
    def _sink_key(self, node: ast.AST,
                  aliases: Dict[str, str]) -> Optional[str]:
        """'events' etc. if ``node`` evaluates to a telemetry sink."""
        if isinstance(node, ast.Name):
            return aliases.get(node.id)
        chain = _dotted(node)
        # self.obs.events / eng.obs.tracer / telemetry.flight ...
        if chain and len(chain) >= 2 and chain[-1] in _SINKS \
                and ("obs" in chain[:-1]
                     or chain[0] in ("telemetry", "tele")):
            return chain[-1]
        return None

    def _guard_exprs(self, test: ast.AST,
                     aliases: Dict[str, str],
                     positive: bool) -> Set[str]:
        """Sink keys proven non-None by ``test`` being true (positive)
        or false (negative): ``X is not None``, ``X is None`` inverted,
        ``not (...)``, and ``and`` chains (positive) / ``or`` chains
        (negative)."""
        out: Set[str] = set()
        if isinstance(test, ast.BoolOp):
            wanted = ast.And if positive else ast.Or
            if isinstance(test.op, wanted):
                for v in test.values:
                    out |= self._guard_exprs(v, aliases, positive)
            return out
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._guard_exprs(test.operand, aliases, not positive)
        if isinstance(test, ast.Compare) and len(test.ops) == 1:
            lhs, op, rhs = test.left, test.ops[0], test.comparators[0]
            is_none = isinstance(rhs, ast.Constant) and rhs.value is None
            key = self._sink_key(lhs, aliases)
            if key and is_none:
                if isinstance(op, ast.IsNot) and positive:
                    out.add(key)
                if isinstance(op, ast.Is) and not positive:
                    out.add(key)
        return out

    def check(self, relpath, source, tree):
        lines = source.splitlines()
        findings: List[Finding] = []
        funcs = [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            findings.extend(self._check_fn(fn, relpath, lines))
        return findings

    def _check_fn(self, fn, relpath, lines) -> List[Finding]:
        aliases: Dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                key = self._sink_key(node.value, {})
                if key:
                    aliases[node.targets[0].id] = key

        findings: List[Finding] = []
        for node in _walk_with_parents(fn):
            if not isinstance(node, ast.Attribute):
                continue
            key = self._sink_key(node.value, aliases)
            if key is None:
                continue
            if self._is_exempt(node, aliases):
                continue
            if not self._is_guarded(node, key, aliases, fn):
                findings.append(Finding(
                    rule=self.rule, path=relpath, line=node.lineno,
                    message=(f"telemetry sink .{key} dereferenced without "
                             "a dominating `is not None` identity guard — "
                             "the zero-cost-when-off contract (PR 6) "
                             "requires every hot-path emit site to check "
                             "the sink identity before touching it"),
                    snippet=_snippet(lines, node.lineno)))
        return findings

    def _is_exempt(self, node: ast.Attribute,
                   aliases: Dict[str, str]) -> bool:
        """The guard test itself and bare alias assignments are legal."""
        parent = getattr(node, "_repro_parent", None)
        # operand of `is` / `is not` — that IS the guard
        if isinstance(parent, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in parent.ops):
            return True
        return False

    def _is_guarded(self, node: ast.AST, key: str,
                    aliases: Dict[str, str], fn) -> bool:
        # lexical ancestors: if/while/ifexp whose test proves the sink
        child = node
        for parent in _parents(node):
            if isinstance(parent, (ast.If, ast.While)):
                in_body = any(child is s or self._contains(s, node)
                              for s in parent.body)
                in_orelse = any(child is s or self._contains(s, node)
                                for s in parent.orelse)
                if in_body and key in self._guard_exprs(
                        parent.test, aliases, True):
                    return True
                if in_orelse and key in self._guard_exprs(
                        parent.test, aliases, False):
                    return True
            if isinstance(parent, ast.IfExp):
                if self._contains(parent.body, node) and key in \
                        self._guard_exprs(parent.test, aliases, True):
                    return True
                if self._contains(parent.orelse, node) and key in \
                        self._guard_exprs(parent.test, aliases, False):
                    return True
            if isinstance(parent, ast.BoolOp):
                # `x is not None and x.emit(...)` short-circuit guard
                positive = isinstance(parent.op, ast.And)
                proven: Set[str] = set()
                for v in parent.values:
                    if self._contains(v, node):
                        if key in proven:
                            return True
                        break
                    proven |= self._guard_exprs(v, aliases, positive)
            child = parent
        # early-return guard: a preceding `if x is None: return/raise`
        # in any enclosing statement list dominates the rest of the list
        return self._early_return_guarded(node, key, aliases, fn)

    @staticmethod
    def _contains(tree: ast.AST, node: ast.AST) -> bool:
        return any(n is node for n in ast.walk(tree))

    def _early_return_guarded(self, node, key, aliases, fn) -> bool:
        _ABORTS = (ast.Return, ast.Raise, ast.Continue, ast.Break)
        for parent in list(_parents(node)) + [fn]:
            for field in ("body", "orelse", "finalbody"):
                stmts = getattr(parent, field, None)
                if not isinstance(stmts, list):
                    continue
                idx = next((i for i, s in enumerate(stmts)
                            if self._contains(s, node)), None)
                if idx is None:
                    continue
                for s in stmts[:idx]:
                    if isinstance(s, ast.If) and s.body and \
                            isinstance(s.body[-1], _ABORTS) and \
                            key in self._guard_exprs(s.test, aliases, False):
                        return True
        return False


# ---------------------------------------------------------------------------
# traced-value-branch
# ---------------------------------------------------------------------------

_TRACED_ROOTS = (
    ("jnp",), ("jax", "numpy"), ("jax", "lax"), ("jax", "nn"),
    ("jax", "random"), ("lax",),
)
# attribute reads that yield static (Python-level) values on tracers
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding",
                           "aval", "weak_type"})
# jnp/jax calls whose results are static Python objects, not tracers
_STATIC_CALLS = frozenset({"dtype", "issubdtype", "result_type", "iinfo",
                           "finfo", "shape", "ndim", "size"})


@register
class TracedValueBranch(AstPass):
    """Python ``if``/``while`` on values produced by jax/jnp computation.

    Inside ``models/`` and ``kernels/`` every array is (or will be)
    traced: branching on one either raises ``TracerBoolConversionError``
    under jit or — the silent version — concretizes during tracing so
    the branch is baked into the executable for the traced value,
    retracing per distinct value at runtime.  That is the classic
    silent-retrace source the compile-once serving contract (PR 1's
    ``decode_retraces_after_warmup == 0`` gate, ``tests/test_serving.py``)
    forbids.  Branch on static config/shapes instead, or use
    ``jnp.where`` / ``lax.cond``.  Shape/dtype attribute reads
    (``x.shape[0] > 1``) stay legal — they are static at trace time.
    """

    rule = "traced-value-branch"

    def applies_to(self, relpath: str) -> bool:
        p = "/" + relpath.replace("\\", "/")
        return "/models/" in p or "/kernels/" in p

    def check(self, relpath, source, tree):
        lines = source.splitlines()
        findings: List[Finding] = []
        funcs = [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            findings.extend(self._check_fn(fn, relpath, lines))
        return findings

    def _is_traced_call(self, node: ast.Call, jitted: Set[str]) -> bool:
        chain = _dotted(node.func)
        if chain is None:
            # directly-invoked jit: jax.jit(f)(x)
            if isinstance(node.func, ast.Call):
                inner = _dotted(node.func.func)
                return inner in (("jax", "jit"), ("jit",))
            return False
        if chain[0] in jitted and len(chain) == 1:
            return True
        for root in _TRACED_ROOTS:
            if chain[:len(root)] == root and len(chain) > len(root):
                return chain[-1] not in _STATIC_CALLS
        return False

    def _expr_traced(self, node: ast.AST, traced: Set[str],
                     jitted: Set[str]) -> bool:
        """Does evaluating ``node`` yield a traced value?  Conservative
        dataflow: jax/jnp calls and any expression referencing a traced
        name outside a static-attr read."""
        if isinstance(node, ast.Call):
            if self._is_traced_call(node, jitted):
                return True
            # len(x), int(x)... on traced operands concretize — but len()
            # of a traced array is its static leading dim: legal
            if isinstance(node.func, ast.Name) and node.func.id == "len":
                return False
            return any(self._expr_traced(a, traced, jitted)
                       for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in traced
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self._expr_traced(node.value, traced, jitted)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False        # identity checks never concretize
            return any(self._expr_traced(n, traced, jitted)
                       for n in [node.left] + node.comparators)
        if isinstance(node, (ast.BinOp, ast.UnaryOp, ast.BoolOp,
                             ast.Subscript, ast.IfExp, ast.Tuple,
                             ast.List)):
            return any(self._expr_traced(c, traced, jitted)
                       for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        return False

    def _check_fn(self, fn, relpath, lines) -> List[Finding]:
        traced: Set[str] = set()
        jitted: Set[str] = set()
        # first sweep: which local names hold jitted callables / traced
        # values (order-insensitive fixpoint over assignments)
        assigns = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
        for _ in range(3):          # tiny fixpoint; chains are short
            for node in assigns:
                val = node.value
                names = []
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.append(t.id)
                    elif isinstance(t, (ast.Tuple, ast.List)):
                        names.extend(e.id for e in t.elts
                                     if isinstance(e, ast.Name))
                if not names:
                    continue
                chain = _dotted(val.func) if isinstance(val, ast.Call) \
                    else None
                if chain and chain[-1] == "jit" and chain[0] == "jax":
                    jitted.update(names)
                elif self._expr_traced(val, traced, jitted):
                    traced.update(names)

        findings = []
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if self._expr_traced(node.test, traced, jitted):
                findings.append(Finding(
                    rule=self.rule, path=relpath, line=node.lineno,
                    message=("Python control flow on a traced value — "
                             "under jit this concretizes at trace time "
                             "and bakes the branch into the executable "
                             "(silent retrace per value; the compile-once "
                             "contract PR 1 established).  Use jnp.where/"
                             "lax.cond, or branch on static shape/config"),
                    snippet=_snippet(lines, node.lineno)))
        return findings
