"""repro.analysis — static invariant checker for the serving stack.

Enforces, at lint time, the correctness contracts the runtime gates
only catch after the fact: clock unification (``no-raw-time``),
process-stable persisted keys (``no-builtin-hash-persistence``),
policy-not-thread-local serving state (``no-thread-local-serving``),
zero-cost-when-off telemetry (``hot-path-zero-cost``), no Python
branches on traced values (``traced-value-branch``), donation that
actually takes and static args that actually hash (``jit-donation``,
``jit-static-args``), and in-bounds Pallas launch geometry
(``pallas-blockspec``).

Run ``python -m repro.analysis --help``; suppress a single line with
``# repro: ignore[rule-id]``; grandfather findings in
``analysis-baseline.json`` (every entry needs a written justification).
"""
from repro.analysis.findings import (Baseline, BaselineError, Finding,
                                     is_suppressed, parse_suppressions)
from repro.analysis.registry import (AnalysisError, AstPass, GlobalPass,
                                     ast_passes, find_repo_root,
                                     global_passes, register,
                                     run_ast_passes, run_global_passes)

__all__ = [
    "AnalysisError", "AstPass", "Baseline", "BaselineError", "Finding",
    "GlobalPass", "ast_passes", "find_repo_root", "global_passes",
    "is_suppressed", "parse_suppressions", "register", "run_ast_passes",
    "run_global_passes",
]
