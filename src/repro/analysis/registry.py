"""Pass registry and the analysis driver.

Two pass families plug into one registry:

  * :class:`AstPass` — per-file syntactic passes over ``ast`` trees of
    everything under the scan roots (``src/``, ``benchmarks/``,
    ``examples/`` by default).  Each pass narrows itself with
    :meth:`AstPass.applies_to`, so e.g. ``hot-path-zero-cost`` only ever
    parses the engine and scheduler.
  * :class:`GlobalPass` — whole-tree semantic passes (the jaxpr /
    executable checks in ``repro.analysis.jaxpr_passes``) that import
    the model, lower the serving warmup set and inspect the artifacts.
    They are registered lazily so ``--ast-only`` runs never import jax.

The driver applies inline suppressions (``# repro: ignore[rule]``)
before returning, so a pass never needs to know about them.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.findings import Finding, is_suppressed, parse_suppressions

DEFAULT_ROOTS = ("src", "benchmarks", "examples")


class AnalysisError(RuntimeError):
    """A pass could not run at all (distinct from finding violations)."""


class AnalysisPass:
    rule: str = ""
    severity: str = "error"

    def describe(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0]


class AstPass(AnalysisPass):
    """Per-file pass: ``check`` sees one parsed module at a time."""

    def applies_to(self, relpath: str) -> bool:
        return True

    def check(self, relpath: str, source: str,
              tree: ast.Module) -> List[Finding]:
        raise NotImplementedError


class GlobalPass(AnalysisPass):
    """Whole-tree pass: ``run`` owns its own model building / lowering."""

    def run(self, repo_root: str) -> List[Finding]:
        raise NotImplementedError


_AST_PASSES: Dict[str, AstPass] = {}
_GLOBAL_PASSES: Dict[str, GlobalPass] = {}


def register(p):
    """Register a pass (usable as a class decorator)."""
    inst = p() if isinstance(p, type) else p
    if not inst.rule:
        raise ValueError(f"{type(inst).__name__} has no rule id")
    table = _AST_PASSES if isinstance(inst, AstPass) else _GLOBAL_PASSES
    if inst.rule in table:
        raise ValueError(f"duplicate rule id {inst.rule!r}")
    table[inst.rule] = inst
    return p


def ast_passes() -> Dict[str, AstPass]:
    import repro.analysis.ast_passes  # noqa: F401  (registers on import)
    return dict(_AST_PASSES)


def global_passes() -> Dict[str, GlobalPass]:
    import repro.analysis.jaxpr_passes  # noqa: F401  (registers on import)
    return dict(_GLOBAL_PASSES)


def iter_python_files(repo_root: str,
                      roots: Sequence[str] = DEFAULT_ROOTS) -> Iterable[str]:
    """Repo-relative paths of every ``.py`` file under the scan roots,
    sorted for deterministic finding order."""
    out = []
    for root in roots:
        base = os.path.join(repo_root, root)
        if os.path.isfile(base) and base.endswith(".py"):
            out.append(os.path.relpath(base, repo_root))
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    out.append(os.path.relpath(os.path.join(dirpath, fn),
                                               repo_root))
    return sorted(out)


def run_ast_passes(repo_root: str,
                   roots: Sequence[str] = DEFAULT_ROOTS,
                   rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every registered AST pass (or just ``rules``) over the scan
    roots; inline suppressions already applied."""
    passes = ast_passes()
    if rules is not None:
        unknown = set(rules) - set(passes) - set(global_passes())
        if unknown:
            raise AnalysisError(f"unknown rule ids: {sorted(unknown)}")
        passes = {r: p for r, p in passes.items() if r in rules}
    findings: List[Finding] = []
    for rel in iter_python_files(repo_root, roots):
        active = [p for p in passes.values() if p.applies_to(rel)]
        if not active:
            continue
        path = os.path.join(repo_root, rel)
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            findings.append(Finding(
                rule="syntax-error", path=rel, line=e.lineno or 1,
                message=f"cannot parse: {e.msg}"))
            continue
        suppressions = parse_suppressions(source)
        seen = set()
        for p in active:
            for f in p.check(rel, source, tree):
                # passes that walk both a function and its enclosing
                # scope can emit one site twice — keep the first
                key = (f.rule, f.path, f.line)
                if key not in seen and not is_suppressed(f, suppressions):
                    seen.add(key)
                    findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def run_global_passes(repo_root: str,
                      rules: Optional[Sequence[str]] = None) -> List[Finding]:
    passes = global_passes()
    if rules is not None:
        passes = {r: p for r, p in passes.items() if r in rules}
    findings: List[Finding] = []
    for p in passes.values():
        findings.extend(p.run(repo_root))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def find_repo_root(start: Optional[str] = None) -> str:
    """Walk up from ``start`` (default: this package) to the directory
    holding the scan roots — works from an installed ``src`` layout and
    from a checkout."""
    d = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if all(os.path.isdir(os.path.join(d, r)) for r in ("src",)) and \
                os.path.isfile(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise AnalysisError(
                "cannot locate the repo root (no pyproject.toml above "
                f"{start or os.path.dirname(__file__)}); pass --root")
        d = parent
