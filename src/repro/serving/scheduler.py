"""FIFO admission + prefill/decode interleaving policy.

Admission moves queued requests into free pool slots in arrival order,
consulting the engine's prefix cache (when armed): a cache hit copies
the matched prefix into the slot and advances the request's prefill
cursor, so only the un-cached suffix is enqueued for chunked prefill.
When both prefill and decode work exist the scheduler strictly alternates
(one prefill chunk, one decode step, ...) so in-flight decodes keep
streaming while new prompts are absorbed — the continuous-batching
property.  With only one kind of work pending it runs that kind."""
from __future__ import annotations

import collections
from typing import Deque, Dict, List

from repro.serving.kv_pool import SlotKVPool
from repro.serving.request import RequestState, Status


class Scheduler:
    def __init__(self) -> None:
        self.queue: Deque[RequestState] = collections.deque()
        self.prefilling: List[RequestState] = []
        self.decoding: Dict[int, RequestState] = {}
        self._last = "decode"        # so the first contested pick prefills

    def enqueue(self, rs: RequestState) -> None:
        self.queue.append(rs)

    def admit(self, pool: SlotKVPool, prefix_cache=None,
              tracer=None) -> None:
        while self.queue and pool.num_free:
            rs = self.queue.popleft()
            rs.slot = pool.alloc()
            if prefix_cache is not None:
                prefix_cache.admit(rs)      # hit: cursor jumps past the
            rs.status = Status.PREFILL      # cached prefix
            self.prefilling.append(rs)
            if tracer is not None:
                tracer.instant(
                    "admit", tid=rs.request.request_id + 1, slot=rs.slot,
                    cached_prefix=rs.next_offset)

    def has_work(self) -> bool:
        return bool(self.queue or self.prefilling or self.decoding)

    def next_action(self) -> str:
        """"prefill" | "decode" | "idle" (strict alternation when both)."""
        if not self.prefilling and not self.decoding:
            return "idle"
        if self.prefilling and (not self.decoding or self._last != "prefill"):
            self._last = "prefill"
            return "prefill"
        self._last = "decode"
        return "decode"

    def prefill_head(self) -> RequestState:
        return self.prefilling[0]

    def prefill_group(self) -> List[RequestState]:
        """All pending prefills sharing the FIFO head's prompt length
        (batched whole-prompt prefill shares one forward)."""
        head_len = self.prefilling[0].request.prompt_len
        return [rs for rs in self.prefilling
                if rs.request.prompt_len == head_len]

    def to_decode(self, rs: RequestState) -> None:
        self.prefilling.remove(rs)
        rs.status = Status.DECODE
        self.decoding[rs.slot] = rs

    def finish(self, rs: RequestState) -> None:
        self.decoding.pop(rs.slot, None)
        rs.status = Status.FINISHED
