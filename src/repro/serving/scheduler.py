"""Priority admission + prefill/decode interleaving policy.

Admission moves queued requests into free pool slots in *priority order*:
strict priority across the three service classes (``Priority``), and
weighted fair queuing across tenants inside a class — each tenant's
requests are stamped with a virtual start time advanced by
``(prompt_len + max_new_tokens) / weight`` per request, and the class
serves whichever tenant's head carries the smallest stamp, so a tenant
with weight 2 drains twice as fast as a weight-1 tenant under contention
while an idle tenant's backlog never starves.  With a default config
(single class, single tenant) this degenerates to exactly the old FIFO
order.

The scheduler also owns the admission-control state: a bounded queue
(``can_accept`` — the engine turns a full queue into a 429 upstream),
per-request queue-wait deadlines (``expire`` sweeps the queue before
each admission pass), and the preemption bookkeeping — ``pick_victim``
selects the least-important, youngest decoding request to suspend and
``suspended`` holds preempted requests (KV state on the host) until
``peek_resume``/``pop_resume`` bring the most important, longest-waiting
one back.  The engine drives the actual KV suspend/resume; the
scheduler only decides who.

When both prefill and decode work exist the scheduler strictly
alternates (one prefill chunk, one decode step, ...) so in-flight
decodes keep streaming while new prompts are absorbed — the
continuous-batching property.  With only one kind of work pending it
runs that kind."""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.request import Priority, RequestState, Status


class QueueFull(RuntimeError):
    """Admission queue is at capacity.  ``retry_after`` is the engine's
    estimate (seconds, >= 1) of when capacity frees up — the gateway
    maps this to HTTP 429 + ``Retry-After``."""

    def __init__(self, message: str, retry_after: float = 1.0):
        super().__init__(message)
        self.retry_after = retry_after


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission policy knobs (hashable, like the engine/SLO configs).

    ``max_queue``: bounded admission queue; 0 = unbounded (no
    backpressure).  ``preemption``: allow suspending a strictly less
    important decoding request to admit a more important arrival.
    ``tenant_weights``: ((tenant, weight), ...) WFQ shares; unlisted
    tenants get weight 1.0."""
    max_queue: int = 0
    preemption: bool = False
    tenant_weights: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.max_queue < 0:
            raise ValueError(f"max_queue {self.max_queue} must be >= 0")
        weights = dict(self.tenant_weights)
        for tenant, w in weights.items():
            if not w > 0:
                raise ValueError(
                    f"tenant {tenant!r} weight {w} must be positive")
        object.__setattr__(self, "_weights", weights)

    def weight(self, tenant: str) -> float:
        return self._weights.get(tenant, 1.0)


class Scheduler:
    def __init__(self, cfg: Optional[SchedulerConfig] = None) -> None:
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        # per-class, per-tenant FIFO deques of (vstart, seq, rs); heads
        # carry each tenant's smallest stamp because stamps are assigned
        # monotonically per tenant
        self._queues: Dict[Priority, Dict[str, Deque]] = {
            p: {} for p in Priority}
        self._vtime: Dict[Priority, Dict[str, float]] = {
            p: {} for p in Priority}
        self._vclock: Dict[Priority, float] = {p: 0.0 for p in Priority}
        self._seq = 0                # global FIFO tie-break
        self._depth = 0
        self.prefilling: List[RequestState] = []
        self.decoding: Dict[int, RequestState] = {}
        self.suspended: List[RequestState] = []   # append order = suspend order
        self._last = "decode"        # so the first contested pick prefills

    # ---- admission queue -------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return self._depth

    def can_accept(self) -> bool:
        return self.cfg.max_queue == 0 or self._depth < self.cfg.max_queue

    def enqueue(self, rs: RequestState) -> None:
        if not self.can_accept():
            raise QueueFull(
                f"admission queue at capacity ({self.cfg.max_queue})")
        req = rs.request
        p, tenant = req.priority, req.tenant
        start = max(self._vtime[p].get(tenant, 0.0), self._vclock[p])
        cost = (req.prompt_len + req.max_new_tokens) / self.cfg.weight(tenant)
        self._vtime[p][tenant] = start + cost
        self._queues[p].setdefault(tenant, collections.deque()).append(
            (start, self._seq, rs))
        self._seq += 1
        self._depth += 1

    def queued(self) -> List[RequestState]:
        """Every queued request, most-important class first (order within
        a class is unspecified — use for expiry sweeps and introspection,
        not admission: ``pop_admit`` owns the WFQ order)."""
        out = []
        for p in Priority:
            for dq in self._queues[p].values():
                out.extend(rs for _, _, rs in dq)
        return out

    def expire(self, now: float) -> List[RequestState]:
        """Remove and return queued requests whose queue-wait deadline
        (``arrival_time + queue_deadline_s``) has passed.  The engine
        finishes them with ``FinishReason.EXPIRED``."""
        expired: List[RequestState] = []
        for p in Priority:
            for tenant, dq in self._queues[p].items():
                kept = collections.deque()
                for entry in dq:
                    rs = entry[2]
                    dl = rs.request.queue_deadline_s
                    if dl is not None and now - rs.request.arrival_time > dl:
                        expired.append(rs)
                        self._depth -= 1
                    else:
                        kept.append(entry)
                self._queues[p][tenant] = kept
        return expired

    def head_priority(self) -> Optional[Priority]:
        """Class of the request ``pop_admit`` would return, or None."""
        for p in Priority:
            if any(self._queues[p].values()):
                return p
        return None

    def pop_admit(self) -> RequestState:
        """Pop the next request in admission order: most important
        non-empty class, then the tenant whose head carries the smallest
        WFQ stamp (FIFO seq breaks ties)."""
        for p in Priority:
            heads = [(dq[0], tenant)
                     for tenant, dq in self._queues[p].items() if dq]
            if not heads:
                continue
            (start, _seq, rs), tenant = min(heads)
            self._queues[p][tenant].popleft()
            self._vclock[p] = max(self._vclock[p], start)
            self._depth -= 1
            return rs
        raise IndexError("pop_admit: admission queue is empty")

    # ---- preemption ------------------------------------------------------
    def pick_victim(self, priority: Priority) -> Optional[RequestState]:
        """The decoding request to suspend so a ``priority``-class
        arrival can run: the least important, then youngest, decoding
        request whose class is *strictly* less important — or None (no
        eligible victim means no preemption, never a same-class swap)."""
        victims = [rs for rs in self.decoding.values()
                   if rs.request.priority > priority]
        if not victims:
            return None
        return max(victims, key=lambda rs: (
            rs.request.priority, rs.request.arrival_time,
            rs.request.request_id))

    def suspend(self, rs: RequestState) -> None:
        """Move a decoding request to the suspended set (the engine has
        already extracted its KV state and will free the slot)."""
        popped = self.decoding.pop(rs.slot, None)
        if popped is not rs:
            raise ValueError(
                f"suspend: request {rs.request.request_id} is not decoding "
                f"in slot {rs.slot}")
        rs.status = Status.SUSPENDED
        self.suspended.append(rs)

    def peek_resume(self) -> Optional[RequestState]:
        """The suspended request next in line for a slot: most important
        class first, earliest suspension within a class."""
        if not self.suspended:
            return None
        return min(enumerate(self.suspended),
                   key=lambda e: (e[1].request.priority, e[0]))[1]

    def pop_resume(self) -> RequestState:
        rs = self.peek_resume()
        if rs is None:
            raise IndexError("pop_resume: no suspended requests")
        self.suspended.remove(rs)
        return rs

    # ---- prefill/decode interleaving ------------------------------------
    def has_work(self) -> bool:
        return bool(self._depth or self.prefilling or self.decoding
                    or self.suspended)

    def next_action(self) -> str:
        """"prefill" | "decode" | "idle" (strict alternation when both)."""
        if not self.prefilling and not self.decoding:
            return "idle"
        if self.prefilling and (not self.decoding or self._last != "prefill"):
            self._last = "prefill"
            return "prefill"
        self._last = "decode"
        return "decode"

    def prefill_head(self) -> RequestState:
        return self.prefilling[0]

    def prefill_group(self) -> List[RequestState]:
        """All pending prefills sharing the head's prompt length
        (batched whole-prompt prefill shares one forward)."""
        head_len = self.prefilling[0].request.prompt_len
        return [rs for rs in self.prefilling
                if rs.request.prompt_len == head_len]

    def to_decode(self, rs: RequestState) -> None:
        self.prefilling.remove(rs)
        rs.status = Status.DECODE
        self.decoding[rs.slot] = rs

    def finish(self, rs: RequestState) -> None:
        self.decoding.pop(rs.slot, None)
        rs.status = Status.FINISHED
