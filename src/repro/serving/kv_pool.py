"""Slot-based KV cache pool.

One fixed ``(max_slots, max_len)`` cache tree is allocated up front from
``api.cache_schema`` and lives for the engine's lifetime; requests borrow a
slot (the batch index) and return it on completion.  Because the tree's
shapes never change, the decode step compiles exactly once.

Prefill results enter the pool through ``insert`` — a jitted per-leaf
``dynamic_update_slice`` at the slot's batch index (and time offset 0 for
the KV time dim), driven by the schema's logical axes so every cache
layout (self-attn KV, rolling-window KV, SSM conv/state) inserts through
the same code path.

Speculative decoding adds per-slot length bookkeeping with
``commit``/``rollback``: a verify forward writes a whole draft window in
place, the engine commits it, and ``rollback(slot, n)`` truncates the
rejected suffix — a donated in-place zeroing of the slot's last ``n``
cache positions (``rollback_many`` batches a whole round's truncations
into one dispatch), so rejected draft tokens vanish from the cache and
the post-rollback state is bit-identical to one that never saw them.
Slot-state mutators validate eagerly (double ``free``, ``insert`` into an
unallocated slot, out-of-range ``commit``/``rollback`` all raise with the
slot id): with rollback in the mix, silent slot-state corruption is far
too easy to hit.  Slot-state checks consult a parallel *free-set* so
they stay O(1) at production slot counts (the free *list* keeps the
LIFO reuse order; the set mirrors it exactly — tested).

Prefix caching adds an immutable segment layer
(``repro.serving.prefix_cache``): ``extract_prefix`` copies the first
``length`` cache positions of a slot out of the pool (one
``dynamic_slice`` per leaf) and ``write_prefix`` copies a cached
segment back into a slot at offset 0 (one donated
``dynamic_update_slice`` per admission).  Segments are never mutated —
a slot that received one only ever appends *past* the copied prefix —
so one cached prefix can seed any number of slots.

Preemption generalizes the same two primitives into whole-slot
``suspend``/``resume``: ``suspend`` extracts the slot's live prefix at a
chunk-quantized physical length and moves it to *host* memory (freeing
device residency with the slot), and ``resume`` writes it back into any
slot and restores the exact live length.  Because suspend/resume lengths
are quantized to the same chunk multiples the prefix cache uses, they
hit the same per-shape executables — ``warm_segments`` (or
``PrefixCache.warm``) precompiles every one, so serving-time preemption
never traces.  The quantized tail past the live length is garbage by
construction (whatever the victim's last forward left there) but is
never attendable: decode masks positions ``>= length`` and any later
prefill overwrites them — the same argument that makes prefix-segment
admission safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import params as P


def _axes_leaf(x) -> bool:
    """A logical-axes tuple: all elements are axis names or None."""
    return (isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x))


@dataclasses.dataclass(frozen=True)
class SuspendedSlot:
    """Host-side snapshot of a preempted slot's KV state.

    ``caches`` is a segment pytree (leaf batch dims = 1, time dim =
    ``phys``) living in host memory; ``length`` is the exact live length
    at suspension; ``phys`` is the chunk-quantized physical extent that
    was copied (``length`` rounded up to a multiple of the suspend
    quantum, the shape ``resume`` writes back)."""
    caches: Any
    length: int
    phys: int


class SlotKVPool:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        schema = api.cache_schema(cfg, max_slots, max_len)
        # cache specs are all init="zeros": this is a plain zero allocation
        self.caches = P.init_params(schema, jax.random.PRNGKey(0), cfg.dtype)
        self._axes = P.logical_axes(schema)
        self._flat_axes = jax.tree_util.tree_leaves(
            self._axes, is_leaf=_axes_leaf)
        # rollback truncates by absolute time position, which is only
        # meaningful when every leaf is a full-length self-attn cache
        # (rolling windows index time mod window; SSM state has no time)
        self._can_rollback = all(
            "kv_seq" in axes for axes in self._flat_axes) and all(
            leaf.shape[axes.index("kv_seq")] == max_len
            for leaf, axes in zip(jax.tree_util.tree_leaves(self.caches),
                                  self._flat_axes))
        self._free: List[int] = list(range(max_slots))[::-1]   # pop() -> 0 first
        self._free_set: Set[int] = set(self._free)   # O(1) slot-state checks
        self.lengths = np.zeros(max_slots, np.int64)
        # donate the pool into the insert/rollback like the decode/chunk
        # steps do — without it every call copies the whole pool tree
        self._insert_jit = jax.jit(self._insert_tree, donate_argnums=(0,))
        self._rollback_jit = jax.jit(self._rollback_tree, donate_argnums=(0,))
        # prefix-segment layer: extract is a read (no donation); write
        # donates the pool only — the segment is reused across admissions
        self._segment_traces = 0     # python-side (re)trace counter: the
        #                              engine warmup precompiles every
        #                              quantized length, so serving-time
        #                              hits/publishes must not grow this
        self._extract_jit = jax.jit(self._extract_tree, static_argnums=(2,))
        self._write_jit = jax.jit(self._write_tree, donate_argnums=(0,))

    # ---- slot management -------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_occupied(self) -> int:
        return self.max_slots - len(self._free)

    def _check_allocated(self, slot: int, op: str) -> None:
        if not 0 <= slot < self.max_slots:
            raise ValueError(
                f"{op}: slot {slot} outside [0, {self.max_slots})")
        if slot in self._free_set:               # set: O(1), not O(max_slots)
            raise ValueError(f"{op}: slot {slot} is not allocated")

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free KV slots")
        slot = self._free.pop()
        self._free_set.remove(slot)
        return slot

    def free(self, slot: int) -> None:
        self._check_allocated(slot, "free")      # double-free raises here
        self.lengths[slot] = 0
        self._free.append(slot)
        self._free_set.add(slot)

    # ---- length bookkeeping (speculative decoding) ----------------------
    def commit(self, slot: int, n: int) -> None:
        """Account ``n`` newly written cache positions to ``slot``
        (bookkeeping only — the forward already wrote them in place)."""
        self._check_allocated(slot, "commit")
        if n < 0:
            raise ValueError(f"commit: negative token count {n}")
        new_len = int(self.lengths[slot]) + n
        if new_len > self.max_len:
            raise ValueError(
                f"commit: slot {slot} length {new_len} exceeds the pool's "
                f"{self.max_len}")
        self.lengths[slot] = new_len

    def rollback(self, slot: int, n: int) -> None:
        """Truncate the last ``n`` committed positions of ``slot``: zero
        their cache entries (donated in-place write, like ``insert``) and
        shrink the slot's length, so rejected draft tokens leave no
        trace — the cache is bit-identical to one that never saw them."""
        self.rollback_many({slot: n})

    def rollback_many(self, per_slot) -> None:
        """Roll back several slots in one donated device call (the spec
        engine truncates every rejected draft suffix of a round at once —
        one dispatch instead of one per slot).  ``per_slot``: {slot: n}.
        Validates every entry before touching anything."""
        starts = np.copy(self.lengths)
        for slot, n in per_slot.items():
            self._check_allocated(slot, "rollback")
            length = int(self.lengths[slot])
            if not 0 <= n <= length:
                raise ValueError(
                    f"rollback: slot {slot} cannot roll back {n} of "
                    f"{length} positions")
            starts[slot] = length - n
        if all(n == 0 for n in per_slot.values()):
            return
        if not self._can_rollback:
            raise ValueError(
                "rollback needs full-length self-attention caches; "
                "rolling-window and SSM cache layouts cannot truncate by "
                "position")
        self.caches = self._rollback_jit(
            self.caches, jnp.asarray(starts, jnp.int32),
            jnp.asarray(self.lengths, jnp.int32))
        for slot in per_slot:
            self.lengths[slot] = starts[slot]

    def _rollback_tree(self, pool, starts, ends):
        """Zero time positions [starts[s], ends[s]) of every slot row.
        Every cache layout stores batch before kv_seq, so the (S, T) keep
        mask reshapes straight into each leaf's broadcast shape."""
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        out = []
        for pl, axes in zip(pool_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            t_ax = axes.index("kv_seq")
            t = jnp.arange(pl.shape[t_ax])
            keep = ((t[None, :] < starts[:, None])
                    | (t[None, :] >= ends[:, None]))       # (S, T)
            shape = [1] * pl.ndim
            shape[b_ax] = pl.shape[b_ax]
            shape[t_ax] = pl.shape[t_ax]
            out.append(pl * keep.reshape(shape).astype(pl.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ---- prefill insertion ----------------------------------------------
    def _insert_tree(self, pool, pref, src, slot):
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        pref_leaves = jax.tree_util.tree_leaves(pref)
        out = []
        for pl, fl, axes in zip(pool_leaves, pref_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            upd = jax.lax.dynamic_slice_in_dim(fl, src, 1, axis=b_ax)
            start = [0] * pl.ndim
            start[b_ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                pl, upd.astype(pl.dtype), tuple(start)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def insert(self, prefill_caches, src_idx: int, slot: int,
               length: int) -> None:
        """Copy request ``src_idx`` of a prefill cache tree (shorter time
        dim allowed) into ``slot``.  Retraces per distinct prefill shape;
        the decode-facing pool shapes never change."""
        self._check_allocated(slot, "insert")
        self.caches = self._insert_jit(self.caches, prefill_caches,
                                       jnp.int32(src_idx), jnp.int32(slot))
        self.lengths[slot] = length

    # ---- prefix segments (repro.serving.prefix_cache) -------------------
    @property
    def can_cache_prefix(self) -> bool:
        """Prefix segments slice the ``kv_seq`` axis by absolute
        position — only meaningful for full-length self-attention
        caches (same precondition as rollback)."""
        return self._can_rollback

    def _extract_tree(self, pool, slot, length: int):
        self._segment_traces += 1            # runs only while tracing
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        out = []
        for pl, axes in zip(pool_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            t_ax = axes.index("kv_seq")
            starts = [0] * pl.ndim
            starts[b_ax] = slot
            sizes = list(pl.shape)
            sizes[b_ax] = 1
            sizes[t_ax] = length
            out.append(jax.lax.dynamic_slice(pl, tuple(starts), tuple(sizes)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _write_tree(self, pool, seg, slot):
        self._segment_traces += 1            # runs only while tracing
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        seg_leaves = jax.tree_util.tree_leaves(seg)
        out = []
        for pl, sl, axes in zip(pool_leaves, seg_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            starts = [0] * pl.ndim
            starts[b_ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                pl, sl.astype(pl.dtype), tuple(starts)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def extract_prefix(self, slot: int, length: int):
        """Copy cache positions ``[0, length)`` of ``slot`` out of the
        pool as an immutable segment pytree (leaf batch dims become 1).
        Compiles once per distinct ``length`` — callers quantize."""
        self._check_allocated(slot, "extract_prefix")
        if not self.can_cache_prefix:
            raise ValueError(
                "extract_prefix needs full-length self-attention caches")
        if not 0 < length <= self.max_len:
            raise ValueError(
                f"extract_prefix: length {length} outside (0, {self.max_len}]")
        return self._extract_jit(self.caches, jnp.int32(slot), length)

    def write_prefix(self, seg, slot: int) -> None:
        """Copy a cached segment into ``slot`` at offset 0 — the one
        donated ``dynamic_update_slice`` a prefix-cache admission costs.
        The *whole* physical segment is copied, so there is exactly one
        executable per segment shape (all precompilable at engine
        warmup): positions past the caller's matched length are either
        overwritten by the suffix prefill / decode before they become
        attendable, or masked (see ``chunk_attention``).  The segment
        itself is never donated or mutated (it seeds arbitrarily many
        slots)."""
        self._check_allocated(slot, "write_prefix")
        if not self.can_cache_prefix:
            raise ValueError(
                "write_prefix needs full-length self-attention caches")
        seg_t = {leaf.shape[axes.index("kv_seq")]
                 for leaf, axes in zip(jax.tree_util.tree_leaves(seg),
                                       self._flat_axes)}
        if len(seg_t) != 1 or not 0 < min(seg_t) <= self.max_len:
            raise ValueError(
                f"write_prefix: segment time dims {sorted(seg_t)} do not "
                f"fit this pool's (0, {self.max_len}] positions")
        self.caches = self._write_jit(self.caches, seg, jnp.int32(slot))

    # ---- whole-slot suspend/resume (preemption) --------------------------
    def suspend(self, slot: int, quantum: int) -> SuspendedSlot:
        """Snapshot ``slot``'s live KV state to host memory so the slot
        can be freed and the request resumed later bit-identically.

        The copy length is the slot's live length rounded up to a
        multiple of ``quantum`` (the engine's prefill chunk) — the same
        quantization the prefix cache uses, so this reuses the
        warmup-precompiled ``extract_prefix`` executables rather than
        introducing one shape (and one trace) per live length.  The
        caller frees the slot afterwards; this method only reads."""
        self._check_allocated(slot, "suspend")
        if quantum <= 0:
            raise ValueError(f"suspend: quantum {quantum} must be positive")
        length = int(self.lengths[slot])
        if length <= 0:
            raise ValueError(
                f"suspend: slot {slot} has no committed positions")
        phys = min(-(-length // quantum) * quantum, self.max_len)
        seg = self._extract_jit(self.caches, jnp.int32(slot), phys)
        return SuspendedSlot(caches=jax.device_get(seg), length=length,
                             phys=phys)

    def resume(self, seg: SuspendedSlot, slot: int) -> None:
        """Restore a suspended request's KV state into (freshly
        allocated) ``slot`` and reinstate its exact live length.  The
        whole physical segment is written back — same executable set as
        ``write_prefix`` at the same quantized shape — and positions in
        ``[length, phys)`` are unattendable garbage exactly as they were
        at suspension time, so the restored slot is bit-identical to the
        pre-preemption one over every attendable position."""
        if not isinstance(seg, SuspendedSlot):
            raise TypeError(
                f"resume: expected a SuspendedSlot, got {type(seg).__name__}")
        self.write_prefix(seg.caches, slot)
        self.lengths[slot] = seg.length

    def warm_segments(self, quantum: int, max_length: int) -> None:
        """Precompile every chunk-quantized extract/write executable up
        to ``max_length`` so serving-time suspend/resume (and prefix
        hits) never trace.  Mirrors ``PrefixCache.warm`` for engines
        that arm preemption without a prefix cache; borrows a free slot
        and restores the pool state exactly."""
        if quantum <= 0:
            raise ValueError(
                f"warm_segments: quantum {quantum} must be positive")
        slot = self.alloc()
        try:
            phys_max = min(-(-max_length // quantum) * quantum, self.max_len)
            for length in range(quantum, phys_max + 1, quantum):
                seg = self.extract_prefix(slot, length)
                self.write_prefix(seg, slot)
        finally:
            self.free(slot)
