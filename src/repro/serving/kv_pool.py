"""Slot-based KV cache pool.

One fixed ``(max_slots, max_len)`` cache tree is allocated up front from
``api.cache_schema`` and lives for the engine's lifetime; requests borrow a
slot (the batch index) and return it on completion.  Because the tree's
shapes never change, the decode step compiles exactly once.

Prefill results enter the pool through ``insert`` — a jitted per-leaf
``dynamic_update_slice`` at the slot's batch index (and time offset 0 for
the KV time dim), driven by the schema's logical axes so every cache
layout (self-attn KV, rolling-window KV, SSM conv/state) inserts through
the same code path.

Speculative decoding adds per-slot length bookkeeping with
``commit``/``rollback``: a verify forward writes a whole draft window in
place, the engine commits it, and ``rollback(slot, n)`` truncates the
rejected suffix — a donated in-place zeroing of the slot's last ``n``
cache positions (``rollback_many`` batches a whole round's truncations
into one dispatch), so rejected draft tokens vanish from the cache and
the post-rollback state is bit-identical to one that never saw them.
Slot-state mutators validate eagerly (double ``free``, ``insert`` into an
unallocated slot, out-of-range ``commit``/``rollback`` all raise with the
slot id): with rollback in the mix, silent slot-state corruption is far
too easy to hit.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import params as P


def _axes_leaf(x) -> bool:
    """A logical-axes tuple: all elements are axis names or None."""
    return (isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x))


class SlotKVPool:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        schema = api.cache_schema(cfg, max_slots, max_len)
        # cache specs are all init="zeros": this is a plain zero allocation
        self.caches = P.init_params(schema, jax.random.PRNGKey(0), cfg.dtype)
        self._axes = P.logical_axes(schema)
        self._flat_axes = jax.tree_util.tree_leaves(
            self._axes, is_leaf=_axes_leaf)
        # rollback truncates by absolute time position, which is only
        # meaningful when every leaf is a full-length self-attn cache
        # (rolling windows index time mod window; SSM state has no time)
        self._can_rollback = all(
            "kv_seq" in axes for axes in self._flat_axes) and all(
            leaf.shape[axes.index("kv_seq")] == max_len
            for leaf, axes in zip(jax.tree_util.tree_leaves(self.caches),
                                  self._flat_axes))
        self._free: List[int] = list(range(max_slots))[::-1]   # pop() -> 0 first
        self.lengths = np.zeros(max_slots, np.int64)
        # donate the pool into the insert/rollback like the decode/chunk
        # steps do — without it every call copies the whole pool tree
        self._insert_jit = jax.jit(self._insert_tree, donate_argnums=(0,))
        self._rollback_jit = jax.jit(self._rollback_tree, donate_argnums=(0,))

    # ---- slot management -------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_occupied(self) -> int:
        return self.max_slots - len(self._free)

    def _check_allocated(self, slot: int, op: str) -> None:
        if not 0 <= slot < self.max_slots:
            raise ValueError(
                f"{op}: slot {slot} outside [0, {self.max_slots})")
        if slot in self._free:
            raise ValueError(f"{op}: slot {slot} is not allocated")

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free KV slots")
        return self._free.pop()

    def free(self, slot: int) -> None:
        self._check_allocated(slot, "free")      # double-free raises here
        self.lengths[slot] = 0
        self._free.append(slot)

    # ---- length bookkeeping (speculative decoding) ----------------------
    def commit(self, slot: int, n: int) -> None:
        """Account ``n`` newly written cache positions to ``slot``
        (bookkeeping only — the forward already wrote them in place)."""
        self._check_allocated(slot, "commit")
        if n < 0:
            raise ValueError(f"commit: negative token count {n}")
        new_len = int(self.lengths[slot]) + n
        if new_len > self.max_len:
            raise ValueError(
                f"commit: slot {slot} length {new_len} exceeds the pool's "
                f"{self.max_len}")
        self.lengths[slot] = new_len

    def rollback(self, slot: int, n: int) -> None:
        """Truncate the last ``n`` committed positions of ``slot``: zero
        their cache entries (donated in-place write, like ``insert``) and
        shrink the slot's length, so rejected draft tokens leave no
        trace — the cache is bit-identical to one that never saw them."""
        self.rollback_many({slot: n})

    def rollback_many(self, per_slot) -> None:
        """Roll back several slots in one donated device call (the spec
        engine truncates every rejected draft suffix of a round at once —
        one dispatch instead of one per slot).  ``per_slot``: {slot: n}.
        Validates every entry before touching anything."""
        starts = np.copy(self.lengths)
        for slot, n in per_slot.items():
            self._check_allocated(slot, "rollback")
            length = int(self.lengths[slot])
            if not 0 <= n <= length:
                raise ValueError(
                    f"rollback: slot {slot} cannot roll back {n} of "
                    f"{length} positions")
            starts[slot] = length - n
        if all(n == 0 for n in per_slot.values()):
            return
        if not self._can_rollback:
            raise ValueError(
                "rollback needs full-length self-attention caches; "
                "rolling-window and SSM cache layouts cannot truncate by "
                "position")
        self.caches = self._rollback_jit(
            self.caches, jnp.asarray(starts, jnp.int32),
            jnp.asarray(self.lengths, jnp.int32))
        for slot in per_slot:
            self.lengths[slot] = starts[slot]

    def _rollback_tree(self, pool, starts, ends):
        """Zero time positions [starts[s], ends[s]) of every slot row.
        Every cache layout stores batch before kv_seq, so the (S, T) keep
        mask reshapes straight into each leaf's broadcast shape."""
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        out = []
        for pl, axes in zip(pool_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            t_ax = axes.index("kv_seq")
            t = jnp.arange(pl.shape[t_ax])
            keep = ((t[None, :] < starts[:, None])
                    | (t[None, :] >= ends[:, None]))       # (S, T)
            shape = [1] * pl.ndim
            shape[b_ax] = pl.shape[b_ax]
            shape[t_ax] = pl.shape[t_ax]
            out.append(pl * keep.reshape(shape).astype(pl.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ---- prefill insertion ----------------------------------------------
    def _insert_tree(self, pool, pref, src, slot):
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        pref_leaves = jax.tree_util.tree_leaves(pref)
        out = []
        for pl, fl, axes in zip(pool_leaves, pref_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            upd = jax.lax.dynamic_slice_in_dim(fl, src, 1, axis=b_ax)
            start = [0] * pl.ndim
            start[b_ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                pl, upd.astype(pl.dtype), tuple(start)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def insert(self, prefill_caches, src_idx: int, slot: int,
               length: int) -> None:
        """Copy request ``src_idx`` of a prefill cache tree (shorter time
        dim allowed) into ``slot``.  Retraces per distinct prefill shape;
        the decode-facing pool shapes never change."""
        self._check_allocated(slot, "insert")
        self.caches = self._insert_jit(self.caches, prefill_caches,
                                       jnp.int32(src_idx), jnp.int32(slot))
        self.lengths[slot] = length
