"""Slot-based KV cache pool.

One fixed ``(max_slots, max_len)`` cache tree is allocated up front from
``api.cache_schema`` and lives for the engine's lifetime; requests borrow a
slot (the batch index) and return it on completion.  Because the tree's
shapes never change, the decode step compiles exactly once.

Prefill results enter the pool through ``insert`` — a jitted per-leaf
``dynamic_update_slice`` at the slot's batch index (and time offset 0 for
the KV time dim), driven by the schema's logical axes so every cache
layout (self-attn KV, rolling-window KV, SSM conv/state) inserts through
the same code path."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import params as P


def _axes_leaf(x) -> bool:
    """A logical-axes tuple: all elements are axis names or None."""
    return (isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x))


class SlotKVPool:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        schema = api.cache_schema(cfg, max_slots, max_len)
        # cache specs are all init="zeros": this is a plain zero allocation
        self.caches = P.init_params(schema, jax.random.PRNGKey(0), cfg.dtype)
        self._axes = P.logical_axes(schema)
        self._flat_axes = jax.tree_util.tree_leaves(
            self._axes, is_leaf=_axes_leaf)
        self._free: List[int] = list(range(max_slots))[::-1]   # pop() -> 0 first
        self.lengths = np.zeros(max_slots, np.int64)
        # donate the pool into the insert like the decode/chunk steps do —
        # without it every insertion copies the whole pool tree
        self._insert_jit = jax.jit(self._insert_tree, donate_argnums=(0,))

    # ---- slot management -------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_occupied(self) -> int:
        return self.max_slots - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free KV slots")
        return self._free.pop()

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.max_slots and slot not in self._free, slot
        self.lengths[slot] = 0
        self._free.append(slot)

    # ---- prefill insertion ----------------------------------------------
    def _insert_tree(self, pool, pref, src, slot):
        pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
        pref_leaves = jax.tree_util.tree_leaves(pref)
        out = []
        for pl, fl, axes in zip(pool_leaves, pref_leaves, self._flat_axes):
            b_ax = axes.index("batch")
            upd = jax.lax.dynamic_slice_in_dim(fl, src, 1, axis=b_ax)
            start = [0] * pl.ndim
            start[b_ax] = slot
            out.append(jax.lax.dynamic_update_slice(
                pl, upd.astype(pl.dtype), tuple(start)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def insert(self, prefill_caches, src_idx: int, slot: int,
               length: int) -> None:
        """Copy request ``src_idx`` of a prefill cache tree (shorter time
        dim allowed) into ``slot``.  Retraces per distinct prefill shape;
        the decode-facing pool shapes never change."""
        self.caches = self._insert_jit(self.caches, prefill_caches,
                                       jnp.int32(src_idx), jnp.int32(slot))
        self.lengths[slot] = length
