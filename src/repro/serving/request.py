"""Request lifecycle for the continuous-batching engine.

A ``Request`` is the immutable submission (prompt, budget, stop rules,
priority class, tenant); ``RequestState`` is the engine-side mutable
record tracking its slot, prefill cursor, generated tokens and timing.
Positions follow the legacy ``generate()`` convention: the prompt
occupies cache positions ``[0, P)``; the i-th decode step consumes the
latest token at position ``P + i`` (the first generated token comes from
the prefill logits, not a decode step).

Priority scheduling adds three service classes (lower value = more
important) and two extra lifecycle states: a queued request whose
queue-wait deadline passes finishes with ``FinishReason.EXPIRED`` without
ever touching a slot, and a decoding request preempted by the scheduler
moves to ``Status.SUSPENDED`` — its KV state lives on the host
(``RequestState.suspended``) until a slot frees up and the engine resumes
it bit-identically."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, List, Optional

import numpy as np


class Priority(enum.IntEnum):
    """Service class; lower value = more important.  Admission is strict
    priority across classes; preemption only ever suspends a victim whose
    class is *strictly* less important than the arrival's."""
    INTERACTIVE = 0
    STANDARD = 1
    BEST_EFFORT = 2

    @classmethod
    def parse(cls, name: str) -> "Priority":
        try:
            return cls[str(name).strip().upper().replace("-", "_")]
        except KeyError:
            raise ValueError(
                f"unknown priority {name!r}; expected one of "
                f"{[p.name.lower() for p in cls]}") from None


class Status(enum.Enum):
    QUEUED = "queued"          # waiting for a slot
    PREFILL = "prefill"        # slot assigned, prompt being processed
    DECODE = "decode"          # generating tokens
    SUSPENDED = "suspended"    # preempted; KV state held on host
    FINISHED = "finished"


class FinishReason(enum.Enum):
    MAX_TOKENS = "max_tokens"
    EOS = "eos"
    EXPIRED = "expired"        # queue-wait deadline passed before admission


@dataclasses.dataclass(frozen=True)
class Request:
    request_id: int
    prompt: np.ndarray                       # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    priority: Priority = Priority.STANDARD
    tenant: str = "default"
    # admission deadline, seconds after arrival_time; None = wait forever
    queue_deadline_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class RequestState:
    request: Request
    status: Status = Status.QUEUED
    slot: int = -1
    next_offset: int = 0                     # chunked-prefill cursor
    tokens: List[int] = dataclasses.field(default_factory=list)
    last_token: int = -1
    finish_reason: Optional[FinishReason] = None
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None   # TPOT: previous emit wallclock
    finish_time: Optional[float] = None
    # ladder serving: rung index active when each token was emitted
    # (parallel to ``tokens``; stays empty on fixed-policy engines)
    token_rungs: List[int] = dataclasses.field(default_factory=list)
    # streaming hook: called as on_token(request_id, token) per new token
    on_token: Optional[Callable[[int, int], None]] = None
    # completion hook: called as on_finish(state) exactly once, after the
    # engine's finish bookkeeping (including deadline expiry) — the
    # gateway's end-of-stream signal
    on_finish: Optional[Callable[["RequestState"], None]] = None
    # preemption bookkeeping: host-side SuspendedSlot while suspended,
    # wallclock of the suspension, lifetime preemption count
    suspended: Optional[Any] = None
    suspend_time: Optional[float] = None
    preemptions: int = 0

    @property
    def position(self) -> int:
        """Cache position the next decode step writes (= current length)."""
        return self.request.prompt_len + len(self.tokens) - 1

    @property
    def done_prefill(self) -> bool:
        return self.next_offset >= self.request.prompt_len

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        self.last_token = token
        if self.on_token is not None:
            self.on_token(self.request.request_id, token)

    def finished(self) -> None:
        if self.on_finish is not None:
            self.on_finish(self)
