"""Request lifecycle for the continuous-batching engine.

A ``Request`` is the immutable submission (prompt, budget, stop rules);
``RequestState`` is the engine-side mutable record tracking its slot,
prefill cursor, generated tokens and timing.  Positions follow the legacy
``generate()`` convention: the prompt occupies cache positions
``[0, P)``; the i-th decode step consumes the latest token at position
``P + i`` (the first generated token comes from the prefill logits, not a
decode step)."""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional

import numpy as np


class Status(enum.Enum):
    QUEUED = "queued"          # waiting for a slot
    PREFILL = "prefill"        # slot assigned, prompt being processed
    DECODE = "decode"          # generating tokens
    FINISHED = "finished"


class FinishReason(enum.Enum):
    MAX_TOKENS = "max_tokens"
    EOS = "eos"


@dataclasses.dataclass(frozen=True)
class Request:
    request_id: int
    prompt: np.ndarray                       # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class RequestState:
    request: Request
    status: Status = Status.QUEUED
    slot: int = -1
    next_offset: int = 0                     # chunked-prefill cursor
    tokens: List[int] = dataclasses.field(default_factory=list)
    last_token: int = -1
    finish_reason: Optional[FinishReason] = None
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None   # TPOT: previous emit wallclock
    finish_time: Optional[float] = None
    # ladder serving: rung index active when each token was emitted
    # (parallel to ``tokens``; stays empty on fixed-policy engines)
    token_rungs: List[int] = dataclasses.field(default_factory=list)
    # streaming hook: called as on_token(request_id, token) per new token
    on_token: Optional[Callable[[int, int], None]] = None

    @property
    def position(self) -> int:
        """Cache position the next decode step writes (= current length)."""
        return self.request.prompt_len + len(self.tokens) - 1

    @property
    def done_prefill(self) -> bool:
        return self.next_offset >= self.request.prompt_len

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        self.last_token = token
        if self.on_token is not None:
            self.on_token(self.request.request_id, token)
