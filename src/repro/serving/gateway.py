"""``repro.serving.gateway`` — async API front door for the engine.

A stdlib-only asyncio HTTP/1.1 server (hand-rolled request parsing, same
no-dependency stance as ``repro.obs.metrics.serve_metrics``) that owns
the engine loop in a background thread and exposes:

* ``POST /v1/generate`` — submit a prompt.  ``"stream": true`` returns
  Server-Sent Events over chunked transfer encoding (one ``data:`` event
  per token, a final event with ``done``/``finish_reason``/``usage``,
  then ``data: [DONE]``); without it the response is one JSON body.
  Requests carry ``priority`` (``interactive``/``standard``/
  ``best_effort``), ``tenant`` and ``queue_deadline_s``; the engine's
  admission control maps to HTTP: queue-full backpressure → **429** with
  ``Retry-After``, a missed queue-wait deadline → **504**, validation
  errors → **400**, draining → **503**.
* ``GET /v1/health`` — liveness + load (queue depth, occupancy,
  suspended count, rung).
* ``GET /metrics`` — the engine's Prometheus text exposition
  (``repro.obs.metrics.engine_exposition``).
* ``GET /v1/debug/flight`` — the flight recorder's ring contents +
  counters (404 when no recorder is armed); also triggers a black-box
  dump when the recorder has a dump dir (``repro.obs.flight``).

Threading model: exactly one background thread touches the engine — it
drains a thread-safe submission queue, then calls ``engine.step()``
(admission, preemption and token emission all happen there).  HTTP
handlers never call into the engine directly for generation; they hand a
submission to the engine thread and receive per-token/finish events back
through ``loop.call_soon_threadsafe`` onto a per-request asyncio queue.
``/v1/health`` and ``/metrics`` read engine counters cross-thread
without locking — torn reads of monotonically increasing stats are
acceptable for observability, the same stance ``serve_metrics`` takes.

Graceful drain: SIGTERM/SIGINT (or :meth:`Gateway.stop`) stops
accepting connections, lets in-flight requests finish (the engine keeps
stepping until idle), then joins the engine thread and calls
``engine.close()`` so telemetry sinks flush.  Exit is clean — the CI
smoke job asserts exit code 0 after SIGTERM.
"""
from __future__ import annotations

import asyncio
import json
import queue
import signal
import threading
from typing import Any, Dict, Optional, Tuple

from repro.serving.engine import Engine
from repro.serving.request import FinishReason, Priority, RequestState
from repro.serving.scheduler import QueueFull

_MAX_HEADER = 64 * 1024
_MAX_BODY = 1 << 20
_REQUEST_TIMEOUT_S = 30.0


class _Pending:
    """One generate call's bridge from the engine thread back to its
    HTTP handler: engine-side callbacks post ``("token", t)`` /
    ``("finish", info)`` / ``("reject", retry_after, msg)`` /
    ``("error", msg)`` items onto an asyncio queue via
    ``call_soon_threadsafe``."""

    def __init__(self, loop: asyncio.AbstractEventLoop,
                 payload: Dict[str, Any]):
        self.loop = loop
        self.payload = payload
        self.events: asyncio.Queue = asyncio.Queue()

    def post(self, item: Tuple) -> None:
        self.loop.call_soon_threadsafe(self.events.put_nowait, item)


def _finish_info(rs: RequestState) -> Dict[str, Any]:
    return {
        "finish_reason": rs.finish_reason.value
        if rs.finish_reason is not None else None,
        "usage": {
            "prompt_tokens": rs.request.prompt_len,
            "completion_tokens": len(rs.tokens),
        },
        "preemptions": rs.preemptions,
    }


class Gateway:
    """HTTP front door over one :class:`~repro.serving.engine.Engine`.

    Two driving modes:

    * :meth:`serve_forever` — blocking; installs SIGTERM/SIGINT drain
      handlers (CLI mode, ``repro.launch.serve --gateway``).
    * :meth:`start` / :meth:`stop` — background-thread mode for tests
      and embedding; ``start`` returns the bound port.
    """

    def __init__(self, engine: Engine, host: str = "127.0.0.1",
                 port: int = 0):
        self.engine = engine
        self.host = host
        self.port = port                      # 0 = ephemeral; rebound at start
        self._submits: queue.Queue = queue.Queue()
        self._wake = threading.Event()        # engine thread idle-park
        self._stop_engine = threading.Event()
        self._draining = False
        self._inflight = 0
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._drain_requested: Optional[asyncio.Event] = None
        self._started = threading.Event()
        self._engine_thread = threading.Thread(
            target=self._engine_loop, name="gateway-engine", daemon=True)
        self._serve_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # engine thread
    # ------------------------------------------------------------------
    def _engine_loop(self) -> None:
        eng = self.engine
        while True:
            while True:
                try:
                    pending = self._submits.get_nowait()
                except queue.Empty:
                    break
                self._submit_one(pending)
            if eng.scheduler.has_work():
                eng.step()
            elif self._stop_engine.is_set():
                return
            else:
                self._wake.wait(timeout=0.02)
                self._wake.clear()

    def _submit_one(self, pending: _Pending) -> None:
        p = pending.payload
        try:
            self.engine.submit(
                p["prompt"], p["max_new_tokens"], eos_id=p.get("eos_id"),
                priority=p.get("priority", Priority.STANDARD),
                tenant=p.get("tenant", "default"),
                queue_deadline_s=p.get("queue_deadline_s"),
                on_token=lambda _rid, tok: pending.post(("token", tok)),
                on_finish=lambda rs: pending.post(
                    ("finish", _finish_info(rs))))
        except QueueFull as e:
            pending.post(("reject", e.retry_after, str(e)))
        except (ValueError, TypeError, RuntimeError) as e:
            pending.post(("error", str(e)))

    # ------------------------------------------------------------------
    # HTTP plumbing
    # ------------------------------------------------------------------
    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        head = await reader.readuntil(b"\r\n\r\n")
        if len(head) > _MAX_HEADER:
            raise ValueError("header block too large")
        lines = head.decode("latin-1").split("\r\n")
        try:
            method, target, _version = lines[0].split(" ", 2)
        except ValueError:
            raise ValueError(f"malformed request line {lines[0]!r}") from None
        headers = {}
        for ln in lines[1:]:
            if not ln:
                continue
            k, _, v = ln.partition(":")
            headers[k.strip().lower()] = v.strip()
        body = b""
        n = int(headers.get("content-length", "0") or "0")
        if n:
            if n > _MAX_BODY:
                raise ValueError(f"body of {n} bytes exceeds {_MAX_BODY}")
            body = await reader.readexactly(n)
        return method.upper(), target, headers, body

    @staticmethod
    def _response(status: int, reason: str, body: bytes,
                  content_type: str = "application/json",
                  extra: Tuple[Tuple[str, str], ...] = ()) -> bytes:
        head = [f"HTTP/1.1 {status} {reason}",
                f"Content-Type: {content_type}",
                f"Content-Length: {len(body)}",
                "Connection: close"]
        head.extend(f"{k}: {v}" for k, v in extra)
        return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + body

    @classmethod
    def _json_response(cls, status: int, reason: str, obj,
                       extra: Tuple[Tuple[str, str], ...] = ()) -> bytes:
        return cls._response(
            status, reason, (json.dumps(obj) + "\n").encode(), extra=extra)

    @staticmethod
    async def _write_chunk(writer: asyncio.StreamWriter, data: bytes) -> None:
        writer.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")
        await writer.drain()

    @staticmethod
    def _sse(obj) -> bytes:
        return f"data: {json.dumps(obj)}\n\n".encode()

    # ------------------------------------------------------------------
    # routes
    # ------------------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                method, target, headers, body = await asyncio.wait_for(
                    self._read_request(reader), timeout=_REQUEST_TIMEOUT_S)
            except (ValueError, asyncio.IncompleteReadError,
                    asyncio.LimitOverrunError, asyncio.TimeoutError) as e:
                writer.write(self._json_response(
                    400, "Bad Request", {"error": str(e)}))
                await writer.drain()
                return
            path = target.split("?", 1)[0]
            if method == "GET" and path == "/v1/health":
                writer.write(self._json_response(
                    200, "OK", self._health()))
                await writer.drain()
            elif method == "GET" and path == "/metrics":
                writer.write(self._response(
                    200, "OK", self.engine.metrics_exposition().encode(),
                    content_type="text/plain; version=0.0.4"))
                await writer.drain()
            elif method == "GET" and path == "/v1/debug/flight":
                fr = self.engine.obs.flight
                if fr is None:
                    writer.write(self._json_response(
                        404, "Not Found",
                        {"error": "no flight recorder armed "
                                  "(serve with --flight-record)"}))
                else:
                    # cross-thread snapshot of a bounded deque — same
                    # torn-read stance as /metrics; also a black-box
                    # dump trigger when a dump dir is configured
                    snap = fr.debug_snapshot()
                    dump_path = fr.dump("http")
                    if dump_path is not None:
                        snap["dump_path"] = dump_path
                    writer.write(self._json_response(200, "OK", snap))
                await writer.drain()
            elif method == "POST" and path == "/v1/generate":
                await self._generate(writer, body)
            else:
                writer.write(self._json_response(
                    404, "Not Found",
                    {"error": f"no route for {method} {path}"}))
                await writer.drain()
        except (ConnectionError, BrokenPipeError):
            pass                               # client went away mid-write
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, BrokenPipeError):
                pass

    def _health(self) -> Dict[str, Any]:
        eng = self.engine
        return {
            "status": "draining" if self._draining else "ok",
            "queue_depth": eng.scheduler.queue_depth,
            "occupancy": eng.pool.num_occupied,
            "suspended": len(eng.scheduler.suspended),
            "rung": eng.rung,
        }

    @staticmethod
    def _parse_generate(body: bytes) -> Dict[str, Any]:
        """Validate the request host-side so malformed submissions never
        reach the engine thread.  Raises ValueError (→ 400)."""
        try:
            doc = json.loads(body.decode() or "{}")
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ValueError(f"invalid JSON body: {e}") from None
        if not isinstance(doc, dict):
            raise ValueError("body must be a JSON object")
        prompt = doc.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) and not isinstance(t, bool)
                           for t in prompt)):
            raise ValueError('"prompt" must be a non-empty list of token ids')
        max_new = doc.get("max_new_tokens", 16)
        if not isinstance(max_new, int) or isinstance(max_new, bool) \
                or max_new < 1:
            raise ValueError('"max_new_tokens" must be a positive integer')
        out: Dict[str, Any] = {
            "prompt": prompt, "max_new_tokens": max_new,
            "stream": bool(doc.get("stream", False)),
        }
        if doc.get("eos_id") is not None:
            if not isinstance(doc["eos_id"], int):
                raise ValueError('"eos_id" must be an integer')
            out["eos_id"] = doc["eos_id"]
        if doc.get("priority") is not None:
            out["priority"] = Priority.parse(doc["priority"])
        if doc.get("tenant") is not None:
            if not isinstance(doc["tenant"], str) or not doc["tenant"]:
                raise ValueError('"tenant" must be a non-empty string')
            out["tenant"] = doc["tenant"]
        if doc.get("queue_deadline_s") is not None:
            dl = doc["queue_deadline_s"]
            if not isinstance(dl, (int, float)) or isinstance(dl, bool) \
                    or dl <= 0:
                raise ValueError('"queue_deadline_s" must be positive')
            out["queue_deadline_s"] = float(dl)
        return out

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        if self._draining:
            writer.write(self._json_response(
                503, "Service Unavailable", {"error": "draining"},
                extra=(("Retry-After", "1"),)))
            await writer.drain()
            return
        try:
            payload = self._parse_generate(body)
        except ValueError as e:
            writer.write(self._json_response(
                400, "Bad Request", {"error": str(e)}))
            await writer.drain()
            return
        self._inflight += 1
        try:
            pending = _Pending(asyncio.get_running_loop(), payload)
            self._submits.put(pending)
            self._wake.set()
            first = await pending.events.get()
            if first[0] == "reject":
                _, retry_after, msg = first
                writer.write(self._json_response(
                    429, "Too Many Requests", {"error": msg},
                    extra=(("Retry-After",
                            str(max(1, round(retry_after)))),)))
                await writer.drain()
                return
            if first[0] == "error":
                writer.write(self._json_response(
                    400, "Bad Request", {"error": first[1]}))
                await writer.drain()
                return
            if first[0] == "finish" and \
                    first[1]["finish_reason"] == FinishReason.EXPIRED.value:
                writer.write(self._json_response(
                    504, "Gateway Timeout",
                    {"error": "queue_deadline_exceeded", **first[1]}))
                await writer.drain()
                return
            if payload["stream"]:
                await self._stream_response(writer, first, pending)
            else:
                await self._json_generate_response(writer, first, pending)
        finally:
            self._inflight -= 1

    async def _json_generate_response(self, writer, first, pending) -> None:
        tokens = []
        event = first
        while event[0] == "token":
            tokens.append(event[1])
            event = await pending.events.get()
        info = event[1]                        # ("finish", info)
        writer.write(self._json_response(200, "OK", {
            "tokens": tokens, **info}))
        await writer.drain()

    async def _stream_response(self, writer, first, pending) -> None:
        head = ("HTTP/1.1 200 OK\r\n"
                "Content-Type: text/event-stream\r\n"
                "Cache-Control: no-store\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n\r\n")
        writer.write(head.encode("latin-1"))
        await writer.drain()
        event, index = first, 0
        while event[0] == "token":
            await self._write_chunk(
                writer, self._sse({"token": event[1], "index": index}))
            index += 1
            event = await pending.events.get()
        await self._write_chunk(
            writer, self._sse({"done": True, **event[1]}))
        await self._write_chunk(writer, b"data: [DONE]\n\n")
        writer.write(b"0\r\n\r\n")             # chunked terminator
        await writer.drain()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def begin_drain(self) -> None:
        """Request graceful shutdown (idempotent; loop-thread only — use
        :meth:`stop` from other threads)."""
        self._draining = True
        if self._drain_requested is not None:
            self._drain_requested.set()

    async def _amain(self, install_signals: bool) -> None:
        self._loop = asyncio.get_running_loop()
        self._drain_requested = asyncio.Event()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                self._loop.add_signal_handler(sig, self.begin_drain)
        self._engine_thread.start()
        self._started.set()
        try:
            await self._drain_requested.wait()
            server.close()                     # stop accepting
            await server.wait_closed()
            while self._inflight > 0:
                await asyncio.sleep(0.01)
            while (not self._submits.empty()
                   or self.engine.scheduler.has_work()):
                await asyncio.sleep(0.01)
        finally:
            self._stop_engine.set()
            self._wake.set()
            self._engine_thread.join(timeout=30.0)
            self.engine.close()

    def serve_forever(self) -> None:
        """Run until SIGTERM/SIGINT, then drain gracefully and return
        (main-thread CLI mode)."""
        asyncio.run(self._amain(install_signals=True))

    def start(self, timeout: float = 60.0) -> int:
        """Run the server on a background thread (no signal handlers);
        returns the bound port once accepting connections."""
        self._serve_thread = threading.Thread(
            target=lambda: asyncio.run(self._amain(install_signals=False)),
            name="gateway-serve", daemon=True)
        self._serve_thread.start()
        if not self._started.wait(timeout):
            raise RuntimeError("gateway failed to start")
        return self.port

    def stop(self, timeout: float = 60.0) -> None:
        """Thread-safe graceful drain + shutdown for :meth:`start`."""
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self.begin_drain)
        if self._serve_thread is not None:
            self._serve_thread.join(timeout)
            if self._serve_thread.is_alive():
                raise RuntimeError("gateway did not drain in time")
