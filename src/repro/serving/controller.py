"""SLO-aware adaptive sparsity controller.

The engine serves a :class:`repro.sparsity.PolicyLadder` — rung 0 is the
densest (highest quality) policy, the last rung the sparsest (fastest).
The controller closes the loop: after every decode step it reads the
engine's load signals (per-request inter-token gaps = TPOT, queue depth;
slot occupancy rides along as telemetry — FIFO admission saturates the
pool before the queue grows, so queue depth subsumes it) against an
:class:`SLOConfig` and decides which rung the *next* step should run.  Rung switches are retrace-free by construction:
the engine precompiles every rung's phase executables at start, and a
switch only changes which (static policy, traced sp tree) pair the next
jit call uses.

Stability machinery, because a bang-bang controller on a noisy latency
signal will oscillate:

* **EWMA smoothing** of the TPOT signal (reset on each switch so the old
  rung's latencies don't bleed into the new rung's estimate);
* **hysteresis** — escalate when the EWMA exceeds the target, but only
  de-escalate when it is *comfortably* below (``target * (1 -
  hysteresis)``) and the queue has drained;
* **dwell time** — a minimum number of decode steps between switches, so
  each rung's EWMA converges before it is judged;
* **per-rung TPOT memory** — de-escalation to a rung whose last measured
  EWMA violated the target is refused until that estimate expires
  (``estimate_ttl`` steps), which prevents the classic down-up limit
  cycle when the lower rung fundamentally cannot meet the SLO.

:class:`SpecController` is the speculative-decoding sibling: it closes a
loop on the *acceptance* signal instead of latency, tuning the draft
length gamma (and optionally the drafter rung) with the same
EWMA + dwell machinery (``repro.serving.spec``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level objectives + controller tuning.

    tpot_p95      target p95 inter-token latency, seconds.  The EWMA of
                  observed gaps is compared against it (an EWMA tracks
                  the bulk of the distribution; the benchmark reports
                  the true p95 against this same number).
    max_queue     queued (unadmitted) requests beyond which the
                  controller escalates regardless of latency.
    ewma_alpha    smoothing factor for the TPOT EWMA.
    hysteresis    de-escalation headroom: step down only when the EWMA
                  is below ``tpot_p95 * (1 - hysteresis)``.
    dwell         minimum decode steps between rung switches.
    estimate_ttl  decode steps a per-rung TPOT estimate stays trusted
                  when deciding whether a lower rung would hold the SLO.
    priority_aware  when True, TPOT-driven escalation targets best-effort
                  traffic first: a latency violation only escalates when
                  the decoding batch actually contains best-effort
                  requests (batched decode runs one policy per step, so
                  rung is the whole batch's quality knob — with an
                  all-interactive batch the controller holds the rung and
                  lets priority admission + preemption shed load
                  instead).  Queue-pressure escalation is unaffected.
    quality_aware  when True, the controller also reads the
                  :class:`repro.obs.quality.QualityMonitor` drift
                  pressure as an *advisory* de-escalation hint: positive
                  pressure (the active rung's live saliency has drifted
                  from its calibration plan) relaxes to the rung below
                  when the queue is empty, the TPOT EWMA still fits the
                  target, dwell has elapsed, and the lower rung's last
                  estimate would hold — i.e. quality can only spend
                  latency headroom, never cause an SLO violation.
    """

    tpot_p95: float
    max_queue: int = 8
    ewma_alpha: float = 0.25
    hysteresis: float = 0.25
    dwell: int = 12
    estimate_ttl: int = 500
    priority_aware: bool = False
    quality_aware: bool = False

    def __post_init__(self):
        if self.tpot_p95 <= 0:
            raise ValueError(f"tpot_p95 must be > 0, got {self.tpot_p95}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")
        if not 0.0 <= self.hysteresis < 1.0:
            raise ValueError(
                f"hysteresis must be in [0, 1), got {self.hysteresis}")
        if self.dwell < 1:
            raise ValueError(f"dwell must be >= 1, got {self.dwell}")


class AdaptiveController:
    """Feedback controller mapping load signals to a ladder rung index.

    Drive it with :meth:`update` once per decode step.  It is plain
    python over plain numbers — the engine feeds it real measurements,
    tests feed it synthetic traces."""

    def __init__(self, num_rungs: int, slo: SLOConfig,
                 initial_rung: int = 0):
        if num_rungs < 1:
            raise ValueError("controller needs at least one rung")
        if not 0 <= initial_rung < num_rungs:
            raise ValueError(
                f"initial_rung {initial_rung} outside [0, {num_rungs})")
        self.num_rungs = num_rungs
        self.slo = slo
        self.rung = initial_rung
        self.step = 0
        self._since_switch = slo.dwell        # free to act immediately
        self._ewma: Optional[float] = None
        # last converged EWMA seen at each rung + the step it was recorded
        self._rung_est: List[Optional[Tuple[float, int]]] = \
            [None] * num_rungs
        self.residency = [0] * num_rungs      # decode steps spent per rung
        self.transitions: List[Tuple[int, int, int, str]] = \
            []                                # (step, from, to, reason)
        self.last_occupancy = 0               # telemetry (see update())
        self.held_escalations = 0             # priority_aware: TPOT
        #                                       violations not acted on
        #                                       because the batch had no
        #                                       best-effort traffic
        self.quality_deescalations = 0        # quality_aware: steps down
        #                                       taken on drift pressure

    # ------------------------------------------------------------------
    @property
    def tpot_ewma(self) -> Optional[float]:
        return self._ewma

    def _observe(self, gaps: Sequence[float]) -> None:
        a = self.slo.ewma_alpha
        for g in gaps:
            self._ewma = g if self._ewma is None else \
                (1 - a) * self._ewma + a * g
        if self._ewma is not None:
            self._rung_est[self.rung] = (self._ewma, self.step)

    def _switch(self, to: int, reason: str) -> None:
        self.transitions.append((self.step, self.rung, to, reason))
        self.rung = to
        self._since_switch = 0
        self._ewma = None          # old rung's latencies don't carry over

    def _lower_rung_would_hold(self) -> bool:
        """Trust a fresh estimate of the rung below; with no (or a stale)
        estimate, probing down is allowed — the queue is empty, so a
        brief violation is cheap and refreshes the estimate."""
        est = self._rung_est[self.rung - 1]
        if est is None:
            return True
        value, at = est
        if self.step - at > self.slo.estimate_ttl:
            return True
        return value <= self.slo.tpot_p95 * (1.0 - self.slo.hysteresis)

    # ------------------------------------------------------------------
    def update(self, gaps: Sequence[float], queue_depth: int,
               occupancy: int = 0,
               best_effort_frac: Optional[float] = None,
               quality_pressure: Optional[float] = None) -> int:
        """One control tick (call after each decode step).

        gaps: the step's observed inter-token gaps, seconds (one per
        active request that emitted a non-first token).  Returns the rung
        the next step should run.

        occupancy is recorded for telemetry (:meth:`snapshot`) but does
        not actuate: admission fills free slots before the queue can
        grow, so whenever ``queue_depth`` exceeds the threshold the pool
        is already saturated — queue depth subsumes occupancy as the
        admission-pressure signal.

        best_effort_frac: fraction of the decoding batch in the
        best-effort class (only consulted when ``slo.priority_aware``):
        a TPOT violation with no best-effort traffic holds the rung
        (counted in ``held_escalations``) so quality degradation lands
        on best-effort requests before interactive ones.

        quality_pressure: the QualityMonitor's saliency-drift pressure
        in [0, 1] (only consulted when ``slo.quality_aware``): positive
        pressure de-escalates one rung when there is latency headroom —
        escalation always wins, so quality hints can never push the
        engine into an SLO violation."""
        self.last_occupancy = occupancy
        self.step += 1
        self.residency[self.rung] += 1
        self._since_switch += 1
        self._observe(gaps)
        if self._since_switch < self.slo.dwell:
            return self.rung

        slo = self.slo
        ewma = self._ewma
        over_tpot = ewma is not None and ewma > slo.tpot_p95
        over_queue = queue_depth > slo.max_queue
        if (slo.priority_aware and over_tpot and not over_queue
                and best_effort_frac is not None and best_effort_frac <= 0
                and self.rung < self.num_rungs - 1):
            self.held_escalations += 1
            return self.rung
        if (over_tpot or over_queue) and self.rung < self.num_rungs - 1:
            self._switch(self.rung + 1,
                         "tpot" if over_tpot else "queue")
        elif (slo.quality_aware and quality_pressure is not None
              and quality_pressure > 0.0
              and self.rung > 0 and queue_depth == 0
              and (ewma is None or ewma <= slo.tpot_p95)
              and self._lower_rung_would_hold()):
            # advisory quality de-escalation: the active rung's live
            # saliency drifted off its calibration plan and there is
            # latency headroom, so spend it on a denser rung.  Gated
            # more loosely than "idle" (no hysteresis margin): drift is
            # a quality signal, not a latency optimization.
            self.quality_deescalations += 1
            self._switch(self.rung - 1, "quality")
        elif (self.rung > 0 and queue_depth == 0
              and ewma is not None
              and ewma < slo.tpot_p95 * (1.0 - slo.hysteresis)
              and self._lower_rung_would_hold()):
            self._switch(self.rung - 1, "idle")
        return self.rung

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Controller state for metrics/JSONL export.

        ``tpot_estimator`` names the signal the controller actually
        steers on — its own reset-on-switch EWMA, deliberately neither
        the whole-run histogram quantile nor the windowed ring p95 that
        ``EngineStats.summary()`` reports (see
        ``repro.serving.metrics``)."""
        total = max(1, sum(self.residency))
        snap = {
            "rung": self.rung,
            "tpot_estimator": "ewma",
            "tpot_ewma_s": None if self._ewma is None
            else round(self._ewma, 6),
            "occupancy": self.last_occupancy,
            "switches": len(self.transitions),
            "rung_residency": [round(r / total, 4) for r in self.residency],
        }
        if self.slo.priority_aware:
            snap["held_escalations"] = self.held_escalations
        if self.slo.quality_aware:
            snap["quality_deescalations"] = self.quality_deescalations
        return snap


class SpecController:
    """Adaptive speculative-decoding controller: tunes the draft length
    gamma — and optionally the drafter rung — from the measured acceptance
    EWMA, since acceptance is workload-dependent.

    Same stability machinery as :class:`AdaptiveController`: the per-round
    accepted-draft fraction feeds an EWMA (reset on every switch so the
    old operating point doesn't bleed into the new one's estimate), and a
    dwell of ``dwell`` verify rounds rate-limits switches.  When the EWMA
    is high (``raise_at``) the drafts are cheap and trustworthy, so gamma
    grows toward ``gamma_max``; once gamma is maxed a drafter-adaptive
    controller instead moves the drafter to a *sparser* rung (cheaper
    drafts).  When the EWMA is low (``lower_at``) the verifier is throwing
    drafts away, so gamma shrinks toward ``gamma_min``; at the floor a
    drafter-adaptive controller falls back to a *denser* drafter rung
    (more faithful drafts).  Every operating point the controller can
    reach is precompiled by ``Engine.warmup()``, so switches are
    retrace-free."""

    def __init__(self, gamma: int, gamma_min: int, gamma_max: int, *,
                 drafter_rung: int, drafter_min: int, drafter_max: int,
                 adapt_drafter: bool = False, alpha: float = 0.2,
                 raise_at: float = 0.8, lower_at: float = 0.4,
                 dwell: int = 8):
        if not 1 <= gamma_min <= gamma <= gamma_max:
            raise ValueError(
                f"need 1 <= gamma_min <= gamma <= gamma_max, got "
                f"({gamma_min}, {gamma}, {gamma_max})")
        if not drafter_min <= drafter_rung <= drafter_max:
            raise ValueError(
                f"drafter rung {drafter_rung} outside "
                f"[{drafter_min}, {drafter_max}]")
        if not 0.0 <= lower_at < raise_at <= 1.0:
            raise ValueError(
                f"need 0 <= lower_at < raise_at <= 1, got "
                f"({lower_at}, {raise_at})")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if dwell < 1:
            raise ValueError(f"dwell must be >= 1, got {dwell}")
        self.gamma = gamma
        self.gamma_min, self.gamma_max = gamma_min, gamma_max
        self.drafter_rung = drafter_rung
        self.drafter_min, self.drafter_max = drafter_min, drafter_max
        self.adapt_drafter = adapt_drafter
        self.alpha = alpha
        self.raise_at, self.lower_at = raise_at, lower_at
        self.dwell = dwell
        self.step = 0
        self._since_switch = dwell           # free to act immediately
        self._ewma: Optional[float] = None
        self.transitions: List[Tuple[int, int, int, str]] = \
            []                               # (step, gamma, drafter, reason)

    @property
    def accept_ewma(self) -> Optional[float]:
        return self._ewma

    def _switch(self, gamma: int, drafter: int, reason: str) -> None:
        self.gamma, self.drafter_rung = gamma, drafter
        self.transitions.append((self.step, gamma, drafter, reason))
        self._since_switch = 0
        self._ewma = None        # the old operating point's acceptance
        #                          doesn't predict the new one's

    def update(self, accept_frac: float) -> Tuple[int, int]:
        """One tick per spec round with the round's mean accepted-draft
        fraction over active slots; returns the (gamma, drafter_rung) the
        next round should run."""
        self.step += 1
        self._since_switch += 1
        a = self.alpha
        self._ewma = accept_frac if self._ewma is None else \
            (1 - a) * self._ewma + a * accept_frac
        if self._since_switch < self.dwell:
            return self.gamma, self.drafter_rung
        if self._ewma >= self.raise_at:
            if self.gamma < self.gamma_max:
                self._switch(self.gamma + 1, self.drafter_rung, "accept")
            elif self.adapt_drafter and self.drafter_rung < self.drafter_max:
                self._switch(self.gamma, self.drafter_rung + 1, "accept")
        elif self._ewma <= self.lower_at:
            if self.gamma > self.gamma_min:
                self._switch(self.gamma - 1, self.drafter_rung, "reject")
            elif self.adapt_drafter and self.drafter_rung > self.drafter_min:
                self._switch(self.gamma, self.drafter_rung - 1, "reject")
        return self.gamma, self.drafter_rung

    def snapshot(self) -> dict:
        return {
            "spec_gamma": self.gamma,
            "spec_drafter_rung": self.drafter_rung,
            "spec_accept_ewma": None if self._ewma is None
            else round(self._ewma, 4),
            "spec_switches": len(self.transitions),
        }
