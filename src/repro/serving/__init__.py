"""Continuous-batching serving engine with WiSparse-aware scheduling.

The engine keeps a fixed slot pool of KV caches (one decode executable for
the engine's whole lifetime), admits requests in priority order,
interleaves chunked prefill with batched decode, and drives the paper's
§5.1 recipe (dense first half of prefill, sparse decode) by deriving a
static ``SparsityPolicy`` per phase (``policy.for_phase(...)``) — an
explicit jit argument, so concurrent engines never share execution state.

Adaptive serving: hand the engine a calibrated ``PolicyLadder`` and an
``SLOConfig`` and the ``AdaptiveController`` turns the sparsity level into
a runtime resource — rung switches under load, retrace-free.

Speculative decoding: ``EngineConfig.spec`` (a ``SpecConfig``) turns the
ladder's cheap rungs into drafters for the dense verifier rung — same
output tokens, fewer verifier passes per token (``repro.serving.spec``).

Prefix caching: ``EngineConfig.prefix_cache`` reuses KV across requests
that share a prompt prefix (system prompts, few-shot templates) via a
radix tree over token ids (``repro.serving.prefix_cache``) — cache-hit
generations stay bit-identical to cold prefill.

Admission control + preemption: ``EngineConfig.scheduler`` (a
``SchedulerConfig``) arms strict-priority classes (``Priority``) with
per-tenant weighted fair queuing, a bounded admission queue
(``QueueFull`` backpressure with a retry estimate), per-request
queue-wait deadlines, and KV preemption — a strictly less important
decoding victim is suspended to host memory and later resumed
bit-identically (``repro.serving.scheduler``, ``SlotKVPool.suspend``).

Gateway: ``repro.serving.gateway.Gateway`` puts an asyncio HTTP/1.1 +
SSE front door (``/v1/generate``, ``/v1/health``, ``/metrics``) over one
engine, owning its loop on a background thread with graceful SIGTERM
drain."""
from repro.serving.controller import (AdaptiveController, SLOConfig,
                                      SpecController)
from repro.serving.engine import (SNAPSHOT_SCHEMA_VERSION, Engine,
                                  EngineConfig)
from repro.serving.gateway import Gateway
from repro.serving.kv_pool import SlotKVPool, SuspendedSlot
from repro.serving.metrics import EngineStats, RingBuffer, percentile
from repro.serving.prefix_cache import PrefixCache, RadixTree
from repro.serving.request import (FinishReason, Priority, Request,
                                   RequestState, Status)
from repro.serving.scheduler import QueueFull, Scheduler, SchedulerConfig
from repro.serving.spec import SpecConfig, SpecDecoder
from repro.sparsity import PolicyLadder, SparsityPolicy

__all__ = [
    "Engine", "EngineConfig", "SlotKVPool", "SuspendedSlot", "EngineStats",
    "RingBuffer", "percentile", "Request", "RequestState", "Status",
    "FinishReason", "Priority", "Scheduler", "SchedulerConfig", "QueueFull",
    "Gateway", "SparsityPolicy", "PolicyLadder", "AdaptiveController",
    "SLOConfig", "SpecConfig", "SpecDecoder", "SpecController",
    "PrefixCache", "RadixTree", "SNAPSHOT_SCHEMA_VERSION",
]
