"""Continuous-batching serving engine with WiSparse-aware scheduling.

The engine keeps a fixed slot pool of KV caches (one decode executable for
the engine's whole lifetime), admits requests FIFO, interleaves chunked
prefill with batched decode, and drives the paper's §5.1 recipe (dense
first half of prefill, sparse decode) by deriving a static
``SparsityPolicy`` per phase (``policy.for_phase(...)``) — an explicit jit
argument, so concurrent engines never share execution state.

Adaptive serving: hand the engine a calibrated ``PolicyLadder`` and an
``SLOConfig`` and the ``AdaptiveController`` turns the sparsity level into
a runtime resource — rung switches under load, retrace-free.

Speculative decoding: ``EngineConfig.spec`` (a ``SpecConfig``) turns the
ladder's cheap rungs into drafters for the dense verifier rung — same
output tokens, fewer verifier passes per token (``repro.serving.spec``).

Prefix caching: ``EngineConfig.prefix_cache`` reuses KV across requests
that share a prompt prefix (system prompts, few-shot templates) via a
radix tree over token ids (``repro.serving.prefix_cache``) — cache-hit
generations stay bit-identical to cold prefill."""
from repro.serving.controller import (AdaptiveController, SLOConfig,
                                      SpecController)
from repro.serving.engine import (SNAPSHOT_SCHEMA_VERSION, Engine,
                                  EngineConfig)
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import EngineStats, RingBuffer, percentile
from repro.serving.prefix_cache import PrefixCache, RadixTree
from repro.serving.request import FinishReason, Request, RequestState, Status
from repro.serving.scheduler import Scheduler
from repro.serving.spec import SpecConfig, SpecDecoder
from repro.sparsity import PolicyLadder, SparsityPolicy

__all__ = [
    "Engine", "EngineConfig", "SlotKVPool", "EngineStats", "RingBuffer",
    "percentile", "Request", "RequestState", "Status", "FinishReason",
    "Scheduler", "SparsityPolicy", "PolicyLadder", "AdaptiveController",
    "SLOConfig", "SpecConfig", "SpecDecoder", "SpecController",
    "PrefixCache", "RadixTree", "SNAPSHOT_SCHEMA_VERSION",
]
