"""Continuous-batching serving engine with WiSparse-aware scheduling.

The engine keeps a fixed slot pool of KV caches (one decode executable for
the engine's whole lifetime), admits requests FIFO, interleaves chunked
prefill with batched decode, and drives the paper's §5.1 recipe (dense
first half of prefill, sparse decode) by switching ``sparsity_mode`` per
phase."""
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import EngineStats, percentile
from repro.serving.request import FinishReason, Request, RequestState, Status
from repro.serving.scheduler import Scheduler

__all__ = [
    "Engine", "EngineConfig", "SlotKVPool", "EngineStats", "percentile",
    "Request", "RequestState", "Status", "FinishReason", "Scheduler",
]
