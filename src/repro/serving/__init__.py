"""Continuous-batching serving engine with WiSparse-aware scheduling.

The engine keeps a fixed slot pool of KV caches (one decode executable for
the engine's whole lifetime), admits requests FIFO, interleaves chunked
prefill with batched decode, and drives the paper's §5.1 recipe (dense
first half of prefill, sparse decode) by deriving a static
``SparsityPolicy`` per phase (``policy.for_phase(...)``) — an explicit jit
argument, so concurrent engines never share execution state."""
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import EngineStats, percentile
from repro.serving.request import FinishReason, Request, RequestState, Status
from repro.serving.scheduler import Scheduler
from repro.sparsity import SparsityPolicy

__all__ = [
    "Engine", "EngineConfig", "SlotKVPool", "EngineStats", "percentile",
    "Request", "RequestState", "Status", "FinishReason", "Scheduler",
    "SparsityPolicy",
]
