"""Engine statistics: per-phase step counts/latencies, throughput, queue
depth and slot occupancy, plus request-latency percentiles."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from repro.serving.request import RequestState


def percentile(values: Iterable[float], p: float) -> float:
    """Nearest-rank percentile (no numpy interpolation surprises)."""
    vs = sorted(values)
    if not vs:
        return float("nan")
    k = max(0, min(len(vs) - 1, int(round(p / 100.0 * (len(vs) - 1)))))
    return vs[k]


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    finished: int = 0
    prefill_chunks: int = 0
    prefill_tokens: int = 0                  # real (non-pad) prompt tokens
    decode_steps: int = 0
    decode_tokens: int = 0                   # generated tokens (incl. first)
    prefill_time: float = 0.0                # seconds in prefill steps
    decode_time: float = 0.0                 # seconds in decode steps
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    def sample(self, queue_depth: int, occupied_slots: int) -> None:
        self.queue_depth.append(queue_depth)
        self.occupancy.append(occupied_slots)

    @property
    def decode_tps(self) -> float:
        return self.decode_tokens / self.decode_time if self.decode_time else 0.0

    @property
    def prefill_tps(self) -> float:
        return (self.prefill_tokens / self.prefill_time
                if self.prefill_time else 0.0)

    def summary(self) -> Dict[str, float]:
        occ = self.occupancy or [0]
        q = self.queue_depth or [0]
        return {
            "submitted": self.submitted,
            "finished": self.finished,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "prefill_time_s": round(self.prefill_time, 4),
            "decode_time_s": round(self.decode_time, 4),
            "prefill_tps": round(self.prefill_tps, 1),
            "decode_tps": round(self.decode_tps, 1),
            "mean_occupancy": round(sum(occ) / len(occ), 2),
            "mean_queue_depth": round(sum(q) / len(q), 2),
        }


def latency_percentiles(states: Iterable[RequestState],
                        ps=(50, 95)) -> Dict[str, Optional[float]]:
    """Request latency (finish - arrival) and TTFT percentiles, seconds."""
    lat, ttft = [], []
    for rs in states:
        if rs.finish_time is not None:
            lat.append(rs.finish_time - rs.request.arrival_time)
        if rs.first_token_time is not None:
            ttft.append(rs.first_token_time - rs.request.arrival_time)
    out: Dict[str, Optional[float]] = {}
    for p in ps:
        out[f"latency_p{p}"] = percentile(lat, p) if lat else None
        out[f"ttft_p{p}"] = percentile(ttft, p) if ttft else None
    return out
