"""Engine statistics: per-phase step counts/latencies, throughput, queue
depth and slot occupancy, request-latency percentiles, and the decode
inter-token (TPOT) signal the adaptive controller steers on.

Every per-sample series is a fixed-capacity :class:`RingBuffer` — a
long-running server samples queue depth and step latencies millions of
times, and the old unbounded lists grew without limit.  The ring keeps
the most recent window while tracking the *whole-run* count and sum, so
the summary means are exact at any run length.

Percentile semantics (two estimators, deliberately):

* The SLO-facing ``*_p50_s``/``*_p95_s`` summary fields are backed by
  exact whole-run :class:`repro.obs.metrics.Histogram` instances (fixed
  log-spaced buckets, observed next to each ring append).  A ring-based
  percentile silently becomes a *windowed* estimate once ``count >
  capacity`` — wrong for long-run p95 gates — and re-sorts the full
  4096-sample ring on every ``summary()``/``snapshot()`` call
  (O(n log n) per snapshot); the histogram quantile never drops a
  sample and walks cumulative bucket counts in O(buckets).
* ``tpot_p95_window_s`` keeps the recent-window (last ``capacity``
  samples) estimate explicitly, for operators who want "now" rather
  than "whole run".  The :class:`~repro.serving.controller
  .AdaptiveController` steers on neither — it keeps its own EWMA and
  reports ``tpot_estimator: "ewma"`` in its snapshot.

These histograms are also what :func:`repro.obs.metrics.engine_registry`
exports in Prometheus text-exposition format."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

from repro.obs.metrics import Histogram
from repro.serving.request import RequestState

# draft-length accept counts are small ints; unit-width bins make the
# accepted-per-verify histogram exact, not just bucket-resolved
_ACCEPT_BUCKETS = tuple(float(i) for i in range(17))


class RingBuffer:
    """Append-only numeric series keeping the last ``capacity`` samples
    plus exact whole-run ``count``/``total`` aggregates.

    Iteration yields the retained window in insertion order; for runs
    shorter than the capacity that is the full series, so downstream
    summaries are unchanged by the capping."""

    __slots__ = ("capacity", "_buf", "_start", "count", "total")

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._buf = []
        self._start = 0          # index of the oldest retained sample
        self.count = 0           # whole-run samples seen
        self.total = 0.0         # whole-run sum

    def append(self, v) -> None:
        v = float(v)
        if len(self._buf) < self.capacity:
            self._buf.append(v)
        else:
            self._buf[self._start] = v
            self._start = (self._start + 1) % self.capacity
        self.count += 1
        self.total += v

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        n = len(self._buf)
        for i in range(n):
            yield self._buf[(self._start + i) % n]

    def __bool__(self) -> bool:
        return bool(self._buf)

    @property
    def mean(self) -> float:
        """Whole-run mean (exact, not windowed)."""
        return self.total / self.count if self.count else 0.0

    @property
    def last(self) -> Optional[float]:
        if not self._buf:
            return None
        return self._buf[(self._start - 1) % len(self._buf)]


def percentile(values: Iterable[float], p: float) -> float:
    """Nearest-rank percentile (no numpy interpolation surprises)."""
    vs = sorted(values)
    if not vs:
        return float("nan")
    k = max(0, min(len(vs) - 1, int(round(p / 100.0 * (len(vs) - 1)))))
    return vs[k]


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    finished: int = 0
    prefill_chunks: int = 0
    prefill_tokens: int = 0                  # real (non-pad) prompt tokens
    decode_steps: int = 0
    decode_tokens: int = 0                   # generated tokens (incl. first)
    prefill_time: float = 0.0                # seconds in prefill steps
    decode_time: float = 0.0                 # seconds in decode steps
    queue_depth: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    occupancy: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    # per-phase step latencies (seconds per jitted step)
    decode_step_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    prefill_step_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    # per-request inter-token gaps (seconds between consecutive emitted
    # tokens — the true TPOT signal: it includes interleaved prefill work,
    # so it rises under admission pressure even when the batched decode
    # step itself is constant-time)
    tpot_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    # exact whole-run histograms backing the SLO-facing percentiles (see
    # the module docstring): observed next to the ring appends via the
    # observe_* helpers below
    tpot_hist: Histogram = dataclasses.field(default_factory=Histogram)
    ttft_hist: Histogram = dataclasses.field(default_factory=Histogram)
    decode_step_hist: Histogram = dataclasses.field(default_factory=Histogram)
    prefill_step_hist: Histogram = dataclasses.field(
        default_factory=Histogram)
    # --- speculative decoding -------------------------------------------
    spec_rounds: int = 0                     # spec rounds (draft + verify)
    spec_draft_steps: int = 0                # single-token drafter steps
    spec_verifies: int = 0                   # per-slot verify outcomes
    spec_draft_tokens: int = 0               # drafted tokens (gamma/slot)
    spec_accepted_tokens: int = 0            # drafts surviving verification
    spec_committed_tokens: int = 0           # emitted by spec (incl. bonus)
    # per-round phase latencies: one draft sample covers the round's gamma
    # sequential drafter steps, one verify sample the batched verify forward
    spec_draft_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    spec_verify_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    spec_draft_hist: Histogram = dataclasses.field(default_factory=Histogram)
    spec_verify_hist: Histogram = dataclasses.field(default_factory=Histogram)
    # per-slot per-round accepted-draft counts (the acceptance *series*;
    # the whole-run rate comes from the exact counters above)
    spec_accepted_per_verify: RingBuffer = dataclasses.field(
        default_factory=RingBuffer)
    spec_accepted_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(_ACCEPT_BUCKETS))
    # --- prefix caching --------------------------------------------------
    prefix_lookups: int = 0                  # admissions that consulted it
    prefix_hits: int = 0                     # admissions that reused KV
    prefix_tokens_saved: int = 0             # prompt tokens not prefilled
    prefix_evicted_segments: int = 0         # segments dropped by LRU
    # matched prefix length per hit (the reuse-depth series)
    prefix_hit_len: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    # --- admission control + preemption ----------------------------------
    preemptions: int = 0                     # decoding requests suspended
    resumes: int = 0                         # suspended requests restored
    rejected: int = 0                        # submissions refused (queue full)
    expired: int = 0                         # queue-wait deadline passed
    # seconds a request spent queued before admission / suspended before
    # resume (ring window + exact whole-run histogram, like TPOT)
    queue_wait_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    queue_wait_hist: Histogram = dataclasses.field(default_factory=Histogram)
    preempted_s: RingBuffer = dataclasses.field(default_factory=RingBuffer)
    preempted_hist: Histogram = dataclasses.field(default_factory=Histogram)

    def sample(self, queue_depth: int, occupied_slots: int) -> None:
        self.queue_depth.append(queue_depth)
        self.occupancy.append(occupied_slots)

    # -- paired ring + exact-histogram observation -----------------------
    def observe_tpot(self, v: float) -> None:
        self.tpot_s.append(v)
        self.tpot_hist.observe(v)

    def observe_ttft(self, v: float) -> None:
        self.ttft_hist.observe(v)

    def observe_decode_step(self, v: float) -> None:
        self.decode_step_s.append(v)
        self.decode_step_hist.observe(v)

    def observe_prefill_step(self, v: float) -> None:
        self.prefill_step_s.append(v)
        self.prefill_step_hist.observe(v)

    def observe_spec_draft(self, v: float) -> None:
        self.spec_draft_s.append(v)
        self.spec_draft_hist.observe(v)

    def observe_spec_verify(self, v: float) -> None:
        self.spec_verify_s.append(v)
        self.spec_verify_hist.observe(v)

    def observe_spec_accepted(self, n: int) -> None:
        self.spec_accepted_per_verify.append(n)
        self.spec_accepted_hist.observe(n)

    def observe_queue_wait(self, v: float) -> None:
        self.queue_wait_s.append(v)
        self.queue_wait_hist.observe(v)

    def observe_preempted(self, v: float) -> None:
        self.preempted_s.append(v)
        self.preempted_hist.observe(v)

    @property
    def decode_tps(self) -> float:
        return self.decode_tokens / self.decode_time if self.decode_time else 0.0

    @property
    def prefill_tps(self) -> float:
        return (self.prefill_tokens / self.prefill_time
                if self.prefill_time else 0.0)

    def window_tpot_p95(self) -> float:
        """Recent-window (last ``capacity`` samples) TPOT p95 — the
        "now" estimate, vs the whole-run histogram quantile."""
        return percentile(self.tpot_s, 95)

    def tpot_percentile(self, p: float) -> float:
        """Whole-run TPOT percentile from the exact histogram (bucket
        resolution, O(buckets) — see the module docstring)."""
        return self.tpot_hist.quantile(p)

    def summary(self) -> Dict[str, float]:
        out = {
            "submitted": self.submitted,
            "finished": self.finished,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "prefill_time_s": round(self.prefill_time, 4),
            "decode_time_s": round(self.decode_time, 4),
            "prefill_tps": round(self.prefill_tps, 1),
            "decode_tps": round(self.decode_tps, 1),
            "mean_occupancy": round(self.occupancy.mean, 2),
            "mean_queue_depth": round(self.queue_depth.mean, 2),
        }
        if self.tpot_hist:
            # whole-run exact-histogram percentiles; *_window_s is the
            # recent-window (last `capacity` samples) ring estimate
            out["tpot_p50_s"] = round(self.tpot_hist.quantile(50), 5)
            out["tpot_p95_s"] = round(self.tpot_hist.quantile(95), 5)
            out["tpot_p95_window_s"] = round(percentile(self.tpot_s, 95), 5)
        if self.ttft_hist:
            out["ttft_p50_s"] = round(self.ttft_hist.quantile(50), 5)
            out["ttft_p95_s"] = round(self.ttft_hist.quantile(95), 5)
        if self.spec_rounds:
            out["spec_rounds"] = self.spec_rounds
            out["spec_committed_tokens"] = self.spec_committed_tokens
            out["spec_accept_rate"] = round(
                self.spec_accepted_tokens / max(1, self.spec_draft_tokens), 4)
            out["spec_accepted_per_verify"] = round(
                self.spec_accepted_tokens / max(1, self.spec_verifies), 3)
            if self.spec_accepted_hist:
                out["spec_accepted_per_verify_p50"] = \
                    self.spec_accepted_hist.quantile(50)
                out["spec_accepted_per_verify_p95"] = \
                    self.spec_accepted_hist.quantile(95)
            for name, hist in (("spec_draft", self.spec_draft_hist),
                               ("spec_verify", self.spec_verify_hist)):
                if hist:
                    out[f"{name}_p50_s"] = round(hist.quantile(50), 5)
                    out[f"{name}_p95_s"] = round(hist.quantile(95), 5)
        if self.prefix_lookups:
            out["prefix_hit_rate"] = round(
                self.prefix_hits / self.prefix_lookups, 4)
            out["prefix_tokens_saved"] = self.prefix_tokens_saved
            if self.prefix_hit_len:
                out["prefix_hit_len_p50"] = percentile(self.prefix_hit_len, 50)
        if self.queue_wait_hist:
            out["queue_wait_p50_s"] = round(self.queue_wait_hist.quantile(50), 5)
            out["queue_wait_p95_s"] = round(self.queue_wait_hist.quantile(95), 5)
        if self.rejected or self.expired:
            out["rejected"] = self.rejected
            out["expired"] = self.expired
        if self.preemptions:
            out["preemptions"] = self.preemptions
            out["resumes"] = self.resumes
            if self.preempted_hist:
                out["preempted_p50_s"] = round(
                    self.preempted_hist.quantile(50), 5)
                out["preempted_p95_s"] = round(
                    self.preempted_hist.quantile(95), 5)
        return out


def latency_percentiles(states: Iterable[RequestState],
                        ps=(50, 95)) -> Dict[str, Optional[float]]:
    """Request latency (finish - arrival) and TTFT percentiles, seconds."""
    lat, ttft = [], []
    for rs in states:
        if rs.finish_time is not None:
            lat.append(rs.finish_time - rs.request.arrival_time)
        if rs.first_token_time is not None:
            ttft.append(rs.first_token_time - rs.request.arrival_time)
    out: Dict[str, Optional[float]] = {}
    for p in ps:
        out[f"latency_p{p}"] = percentile(lat, p) if lat else None
        out[f"ttft_p{p}"] = percentile(ttft, p) if ttft else None
    return out
