"""Prefix-sharing KV cache: radix-tree reuse over the slot pool.

Production traffic is dominated by shared prompt prefixes (system
prompts, few-shot templates), yet a plain slot pool re-prefills every
request from token 0.  This module caches *immutable* prefix segments of
the KV pool in a radix tree keyed by prompt token ids:

  * ``RadixTree`` — pure-python token-path tree.  Payload-bearing nodes
    own one cached segment each; matching walks the tree and may use a
    *longer* cached segment as the copy source for a *shorter* matched
    prefix (prefix-deterministic prefill makes position ``p``'s KV a
    function of tokens ``[0, p]`` only, so slicing a segment is exact).
    Nodes are refcount-pinned while an in-flight request uses them and
    unpinned payload-leaves are evicted LRU under a token budget.
  * ``PrefixCache`` — the engine-facing layer tying the tree to a
    :class:`~repro.serving.kv_pool.SlotKVPool`.  On admission the
    matched segment is copied into the request's slot at offset 0 (one
    donated ``dynamic_update_slice`` per admission) and only the
    un-cached suffix is enqueued for chunked prefill; on prefill
    completion the engine publishes the slot's prompt prefix back into
    the tree.

Physical segment lengths are quantized up to the engine's prefill-chunk
size, and an admission copies the *whole* physical segment, so the
extract/copy executables compile for a bounded, warmup-precompilable
set of shapes (one per chunk-multiple length).  Positions past the
matched length are garbage from the copy's perspective, which is safe
by the serving invariants: the suffix prefill rewrites ``[match, P)``
before attending each chunk, decode writes position ``p`` before any
query can reach it, and every attention mask excludes positions at or
beyond the querying offset (``chunk_attention`` / the decode valid
mask).

Memory trade-off: each payload node stores a *full* ``[0, end)``
segment, so admission costs exactly one donated ``dynamic_update_slice``
and eviction is per-node, at the price of duplicating a shared system
prompt's KV into every suffix's segment.  Per-edge delta segments
(node stores ``[parent.end, end)``, a hit assembles the ancestor chain)
would make cached bytes proportional to the trie instead — a future
refinement that trades more per-admission copies for memory; the token
budget (``capacity_tokens``) is the current backstop.

Exactness contract: reuse is bit-identical to cold prefill only when
prefill is *prefix-deterministic* — every projection runs a per-token
backend (``off`` dense or ``mask``), and the effective prefill policy
does not depend on the prompt length.  The engine validates this at
construction (:meth:`repro.sparsity.SparsityPolicy.prefix_deterministic`);
shared top-k backends aggregate saliency per call, so chunk boundaries
and batch composition would leak into cached KV and silently break the
token-parity guarantee.
"""
from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.clock import now as _obs_now
from repro.obs.profiler import NULL_CONTEXT as _NULL_CTX


class RadixNode:
    """One radix-tree node.  ``edge`` is the token span from the parent;
    ``end`` is the total token depth (prefix length) at this node.
    ``payload`` is the cached segment (opaque to the tree) covering
    positions ``[0, end)``; ``size`` its token accounting (physical,
    i.e. quantized, tokens).  Intermediate nodes created by edge splits
    carry no payload."""

    __slots__ = ("edge", "end", "parent", "children", "payload", "size",
                 "refcount", "last_used", "min_seg")

    def __init__(self, edge: Tuple[int, ...], end: int,
                 parent: Optional["RadixNode"]):
        self.edge = edge
        self.end = end
        self.parent = parent
        self.children: Dict[int, RadixNode] = {}
        self.payload = None
        self.size = 0
        self.refcount = 0
        self.last_used = 0
        # shallowest payload node in this node's subtree (self included),
        # maintained incrementally so matching is O(path), not O(subtree)
        self.min_seg: Optional[RadixNode] = None

    @property
    def path(self) -> Tuple[int, ...]:
        """Full token path from the root (test/debug helper)."""
        parts: List[Tuple[int, ...]] = []
        node: Optional[RadixNode] = self
        while node is not None:
            parts.append(node.edge)
            node = node.parent
        return tuple(t for e in reversed(parts) for t in e)


class RadixTree:
    """Radix tree over token sequences with refcounted payloads and LRU
    eviction of unpinned payload-leaves.  Payloads are opaque — the tree
    only tracks their ``size`` for the eviction budget."""

    def __init__(self):
        self.root = RadixNode((), 0, None)
        self.total_size = 0
        self._clock = 0
        self._num_payloads = 0

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------
    def _walk(self, tokens: Tuple[int, ...]):
        """Longest tree-path prefix of ``tokens``: returns
        ``(frontier, matched)`` where ``frontier`` is the deepest node
        whose subtree extends the match (possibly mid-edge) and
        ``matched`` the number of matched tokens."""
        node, i = self.root, 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                return node, i
            edge = child.edge
            k, n = 0, min(len(edge), len(tokens) - i)
            while k < n and edge[k] == tokens[i + k]:
                k += 1
            i += k
            if k < len(edge):           # stopped mid-edge: child's subtree
                return child, i         # still shares the first i tokens
            node = child
        return node, i

    def match(self, tokens, limit: Optional[int] = None,
              touch: bool = True):
        """Longest usable cached prefix of ``tokens``.

        Returns ``(source, length)``: ``source`` is a payload node whose
        first ``length`` path tokens equal ``tokens[:length]`` and whose
        segment covers at least ``length`` positions (``source.end >=
        length`` — the cache layer slices it), or ``(None, 0)`` on a
        miss.  ``limit`` caps the match (the engine passes
        ``prompt_len - 1`` so at least one suffix token remains to
        produce the first-token logits).  ``touch=False`` makes the
        match a pure read (no LRU refresh) for introspection paths."""
        tokens = tuple(tokens)
        lim = len(tokens) if limit is None else min(limit, len(tokens))
        if lim <= 0:
            return None, 0
        frontier, matched = self._walk(tokens)
        depth = min(matched, lim)
        if depth > 0:
            # every node in the frontier's subtree shares the matched
            # prefix, so any payload there can source a slice; min_seg
            # is the shallowest (fewest copied bytes), maintained
            # incrementally — no per-admission subtree scan
            src = frontier.min_seg
            if src is not None and src.end >= depth:
                if touch:
                    self.touch(src)
                return src, depth
        # fall back to the deepest fully-matched ancestor payload
        node = frontier
        while node is not None:
            if node.payload is not None and 0 < node.end <= lim \
                    and node.end <= matched:
                if touch:
                    self.touch(node)
                return node, node.end
            node = node.parent
        return None, 0

    def covered(self, tokens) -> Optional[RadixNode]:
        """The payload node at exactly ``len(tokens)`` depth, if any
        (used to skip re-publishing an already-cached prompt)."""
        tokens = tuple(tokens)
        frontier, matched = self._walk(tokens)
        if matched == len(tokens) and frontier.end == matched \
                and frontier.payload is not None:
            return frontier
        return None

    # ------------------------------------------------------------------
    # insertion
    # ------------------------------------------------------------------
    def _split(self, node: RadixNode, k: int) -> RadixNode:
        """Split ``node``'s edge after ``k`` tokens; returns the new
        intermediate (payload-less) parent."""
        assert 0 < k < len(node.edge)
        parent = node.parent
        mid = RadixNode(node.edge[:k], node.end - len(node.edge) + k, parent)
        parent.children[node.edge[0]] = mid
        node.edge = node.edge[k:]
        node.parent = mid
        mid.children[node.edge[0]] = node
        mid.min_seg = node.min_seg          # same subtree, new root
        return mid

    def insert(self, tokens, payload, size: int) -> RadixNode:
        """Attach ``payload`` (a segment covering ``[0, len(tokens))``)
        at the node for ``tokens``, splitting edges as needed.  An
        existing payload at that exact depth is kept (segments are
        immutable and content-deterministic) and only LRU-refreshed."""
        tokens = tuple(tokens)
        if not tokens:
            raise ValueError("cannot cache an empty prefix")
        node, i = self.root, 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                leaf = RadixNode(tokens[i:], len(tokens), node)
                node.children[tokens[i]] = leaf
                node = leaf
                i = len(tokens)
                break
            edge = child.edge
            k, n = 0, min(len(edge), len(tokens) - i)
            while k < n and edge[k] == tokens[i + k]:
                k += 1
            i += k
            if k < len(edge):
                node = self._split(child, k)
                if i < len(tokens):     # diverging suffix under the split
                    leaf = RadixNode(tokens[i:], len(tokens), node)
                    node.children[tokens[i]] = leaf
                    node = leaf
                    i = len(tokens)
            else:
                node = child
        if node.payload is None:
            node.payload = payload
            node.size = size
            self.total_size += size
            self._num_payloads += 1
            anc = node
            while anc is not None:
                if anc.min_seg is not None and anc.min_seg.end <= node.end:
                    break                   # ancestors above are <= too
                anc.min_seg = node
                anc = anc.parent
        self.touch(node)
        return node

    # ------------------------------------------------------------------
    # pinning / eviction
    # ------------------------------------------------------------------
    def pin(self, node: RadixNode) -> None:
        node.refcount += 1

    def unpin(self, node: RadixNode) -> None:
        if node.refcount <= 0:
            raise ValueError("unpin below zero refcount")
        node.refcount -= 1

    def touch(self, node: RadixNode) -> None:
        """Refresh a node's LRU stamp (matches and publishes do this)."""
        self._clock += 1
        node.last_used = self._clock

    def payload_nodes(self) -> List[RadixNode]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n.payload is not None:
                out.append(n)
            stack.extend(n.children.values())
        return out

    @property
    def num_payloads(self) -> int:
        return self._num_payloads

    @staticmethod
    def _has_payload_desc(node: RadixNode) -> bool:
        # a child's min_seg is non-None iff its subtree holds a payload
        return any(c.min_seg is not None for c in node.children.values())

    @staticmethod
    def _recompute_min_seg_up(node: Optional[RadixNode]) -> None:
        """Recompute ``min_seg`` from ``node`` to the root after a
        payload removal (O(depth x branching))."""
        while node is not None:
            cands = [c.min_seg for c in node.children.values()
                     if c.min_seg is not None]
            if node.payload is not None:
                cands.append(node)
            node.min_seg = min(cands, key=lambda n: n.end) \
                if cands else None
            node = node.parent

    def _payload_leaves(self) -> List[RadixNode]:
        """Payload nodes with no payload-bearing descendant — the only
        evictable nodes (inner prefixes are shared by more prompts)."""
        return [n for n in self.payload_nodes()
                if not self._has_payload_desc(n)]

    def _prune(self, node: RadixNode) -> None:
        """Detach payload-less childless chains after an eviction so
        tree paths always end in (or lead to) live payloads."""
        while node is not None and node.parent is not None \
                and node.payload is None and not node.children \
                and node.refcount == 0:
            parent = node.parent
            del parent.children[node.edge[0]]
            node = parent

    def evict(self, budget: int) -> List[RadixNode]:
        """Drop LRU unpinned payload-leaves until ``total_size <=
        budget``.  Pinned segments are never evicted (the budget may
        therefore be temporarily exceeded).  Returns the evicted nodes.

        The candidate set is computed once and maintained incrementally
        — evicting a leaf can only newly expose its nearest payload
        ancestor, so each eviction does one localized leaf-check
        instead of re-scanning every payload subtree (O(n^2) on a
        production-sized cache)."""
        evicted: List[RadixNode] = []
        if self.total_size <= budget:
            return evicted
        heap = [(n.last_used, id(n), n) for n in self._payload_leaves()
                if n.refcount == 0]
        heapq.heapify(heap)
        while self.total_size > budget and heap:
            _, _, victim = heapq.heappop(heap)
            self.total_size -= victim.size
            victim.payload = None
            victim.size = 0
            self._num_payloads -= 1
            evicted.append(victim)
            self._prune(victim)
            self._recompute_min_seg_up(victim)
            anc = victim.parent
            while anc is not None and anc.payload is None:
                anc = anc.parent
            if anc is not None and anc.refcount == 0 \
                    and not self._has_payload_desc(anc):
                heapq.heappush(heap, (anc.last_used, id(anc), anc))
        return evicted


class PrefixCache:
    """Engine-facing prefix cache over a :class:`SlotKVPool`.

    ``chunk`` quantizes physical segment lengths (and is the engine's
    prefill-chunk size, so the copied-garbage tail past a match is
    always overwritten by the first suffix chunk before it can be
    attended).  ``capacity_tokens`` bounds the cached physical tokens
    (0 = unbounded); eviction runs after each publish.  ``stats_fn``
    returns the engine's live :class:`EngineStats` (the engine swaps
    its stats object between benchmark reps, so the cache must not
    capture one instance).  ``obs_fn`` likewise returns the engine's
    live :class:`repro.obs.Telemetry` — admissions trace a
    ``prefix_lookup`` span per consult and evictions land in the
    structured event log with segment depths."""

    def __init__(self, pool, chunk: int, capacity_tokens: int = 0,
                 stats_fn: Optional[Callable] = None,
                 obs_fn: Optional[Callable] = None):
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        if capacity_tokens < 0:
            raise ValueError(
                f"capacity_tokens must be >= 0, got {capacity_tokens}")
        if not pool.can_cache_prefix:
            raise ValueError(
                "prefix caching needs full-length self-attention caches; "
                "rolling-window and SSM cache layouts cannot slice a "
                "prefix by position")
        self.pool = pool
        self.chunk = chunk
        self.capacity_tokens = capacity_tokens
        self.tree = RadixTree()
        self._stats_fn = stats_fn
        self._obs_fn = obs_fn
        self._pins: Dict[int, RadixNode] = {}   # request_id -> source node

    # ------------------------------------------------------------------
    def _phys(self, n: int) -> int:
        """Quantize a logical prefix length up to a chunk multiple (the
        bounded set of extract/copy executable shapes)."""
        return -(-n // self.chunk) * self.chunk

    def _stats(self):
        return self._stats_fn() if self._stats_fn is not None else None

    def _obs(self):
        return self._obs_fn() if self._obs_fn is not None else None

    @property
    def cached_tokens(self) -> int:
        return self.tree.total_size

    @property
    def num_segments(self) -> int:
        return self.tree.num_payloads

    def warm(self, max_prompt_len: int) -> None:
        """Precompile the segment extract/copy executables for every
        reachable quantized length (chunk multiples up to the longest
        prompt), so the first cache hit or publish at each length never
        stalls live traffic on a compile.  Called from the engine's
        ``warmup()`` on an idle pool — borrows one slot and returns it;
        the garbage it round-trips through that slot is overwritten by
        the slot's first real prefill, exactly like the engine's own
        warmup forwards."""
        slot = self.pool.alloc()
        try:
            for length in range(self.chunk,
                                self._phys(max_prompt_len) + 1,
                                self.chunk):
                seg = self.pool.extract_prefix(slot, length)
                self.pool.write_prefix(seg, slot)
        finally:
            self.pool.free(slot)

    def lookup(self, prompt) -> int:
        """Matched prefix length a request with this prompt would reuse.
        A pure read (no copy, no stats, no LRU refresh — observing the
        cache must not change what gets evicted)."""
        _, n = self.tree.match(tuple(int(t) for t in prompt),
                               limit=len(prompt) - 1, touch=False)
        return n

    # ------------------------------------------------------------------
    def admit(self, rs) -> int:
        """Consult the cache for ``rs`` (slot already allocated): on a
        hit, copy the matched prefix into the request's slot at offset 0
        and advance its prefill cursor so only the un-cached suffix is
        chunk-prefilled.  Pins the source node until :meth:`publish`.
        Returns the matched length (0 = miss)."""
        stats = self._stats()
        tele = self._obs()
        if stats is not None:
            stats.prefix_lookups += 1
        t0 = _obs_now() if tele is not None and tele.tracer is not None \
            else None
        prompt = tuple(int(t) for t in rs.request.prompt)
        src, n = self.tree.match(prompt, limit=len(prompt) - 1)
        hit = src is not None and n > 0
        if hit:
            # the whole physical segment is copied (one executable per
            # segment shape, all precompiled at warmup); only the matched
            # [0, n) prefix is accounted as live — the copied tail is
            # overwritten/masked before anything can attend it
            ctx = tele.annotate("repro/prefix_write") if tele is not None \
                else _NULL_CTX
            with ctx:
                self.pool.write_prefix(src.payload, rs.slot)
            self.pool.lengths[rs.slot] = n
            rs.next_offset = n
            self.tree.pin(src)
            self._pins[rs.request.request_id] = src
            if stats is not None:
                stats.prefix_hits += 1
                stats.prefix_tokens_saved += n
                stats.prefix_hit_len.append(n)
        if t0 is not None:
            tele.tracer.complete(
                "prefix_lookup", t0, _obs_now(),
                tid=rs.request.request_id + 1, slot=rs.slot, hit=hit,
                matched=n if hit else 0)
        return n if hit else 0

    def release(self, rs) -> None:
        """Unpin the source node ``rs`` admitted against, if any."""
        node = self._pins.pop(rs.request.request_id, None)
        if node is not None:
            self.tree.unpin(node)

    def publish(self, rs) -> None:
        """Called by the engine when ``rs`` finishes prefill: release
        the admission pin and cache the slot's full prompt prefix
        ``[0, P)`` (skipped when an identical prefix is already cached —
        prefix-deterministic prefill makes segments content-unique), then
        evict down to the token budget."""
        self.release(rs)
        prompt = tuple(int(t) for t in rs.request.prompt)
        existing = self.tree.covered(prompt)
        if existing is not None:
            self.tree.touch(existing)
            return
        phys = self._phys(len(prompt))
        tele = self._obs()
        ctx = tele.annotate("repro/prefix_extract") if tele is not None \
            else _NULL_CTX
        with ctx:
            seg = self.pool.extract_prefix(rs.slot, phys)
        self.tree.insert(prompt, seg, phys)
        if self.capacity_tokens:
            before = self.tree.total_size   # evict() zeroes victim sizes
            evicted = self.tree.evict(self.capacity_tokens)
            stats = self._stats()
            if stats is not None:
                stats.prefix_evicted_segments += len(evicted)
            if evicted and tele is not None and tele.events is not None:
                tele.events.emit(
                    "prefix_evict", segments=len(evicted),
                    tokens=before - self.tree.total_size,
                    depths=[n.end for n in evicted],
                    cached_tokens=self.tree.total_size,
                    trigger_request=rs.request.request_id)
            if evicted and tele is not None and tele.flight is not None:
                tele.flight.decision(
                    "prefix_evict", segments=len(evicted),
                    tokens=before - self.tree.total_size,
                    cached_tokens=self.tree.total_size,
                    trigger_request=rs.request.request_id)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Prefix-cache fields for the engine's JSONL snapshot."""
        stats = self._stats()
        out = {
            "prefix_cached_tokens": self.cached_tokens,
            "prefix_segments": self.num_segments,
        }
        if stats is not None:
            out["prefix_hit_rate"] = round(
                stats.prefix_hits / stats.prefix_lookups, 4) \
                if stats.prefix_lookups else None
            out["prefix_tokens_saved"] = stats.prefix_tokens_saved
        return out
