"""Continuous-batching inference engine.

One engine instance owns: the slot KV pool (fixed shapes, so the batched
decode step compiles once and never retraces), the priority scheduler, and
the jitted phase steps.  Sparsity is phase-aware per the paper's §5.1 recipe:
prefill chunks in the first ``prefill_dense_frac`` of the prompt run dense
and later chunks plus all decode steps run under the configured
:class:`SparsityPolicy`.  The policy is a hashable *static* jit argument —
an explicit value, not ambient state — so each (phase, policy) pair owns
its executable, and two engines with different policies can run
interleaved (or on separate threads) without ever sharing or leaking a
trace.

Adaptive serving: instead of one policy the engine can serve a
:class:`repro.sparsity.PolicyLadder` — a calibrated family of policies at
ascending sparsity budgets.  With an :class:`SLOConfig` an
:class:`AdaptiveController` switches the decode/prefill-sparse phases
between rungs as load changes.  Every rung's executables are precompiled
at engine start, and because compilation is keyed on the static (phase,
policy) pair while rung sp trees share one schema, a rung switch is
retrace-free (``decode_retraces_after_warmup`` asserts this).

Prefill strategies:
  * "chunked": fixed-size chunks written straight into the pool slot via
    ``mode="chunk"`` forwards (jit-stable across prompt lengths; plain
    full-attention archs only).
  * "whole":   the legacy whole-prompt prefill (batched over same-length
    requests) + pool insertion; supports every cached arch (local windows,
    SSM) at the cost of one executable per prompt length.

Speculative decoding: with ``EngineConfig.spec`` the decode action runs
draft/verify rounds instead of single batched steps — a sparse ladder
rung drafts gamma tokens per slot, the verifier rung checks them in one
batched multi-token forward, and the KV pool rolls rejected drafts back
(``repro.serving.spec``).  Output tokens are identical to verifier-only
decode; warmup() additionally precompiles a verify executable per
reachable gamma so gamma/drafter switches stay retrace-free.

Prefix caching: with ``EngineConfig.prefix_cache`` completed prefills
are published into a radix tree over prompt token ids
(``repro.serving.prefix_cache``) and admissions that share a cached
prefix copy it into their slot and chunk-prefill only the un-cached
suffix — the single largest TTFT lever under shared-system-prompt
traffic.  Requires chunked prefill and *prefix-deterministic* prefill
policies (validated eagerly at construction: dense or per-token
``mask`` backends, identical across rungs and prompt lengths), which is
what makes a cache-hit generation bit-identical to cold prefill.

Admission control + preemption: with ``EngineConfig.scheduler`` the
engine enforces a bounded admission queue (``submit`` raises
:class:`repro.serving.scheduler.QueueFull` with a retry estimate — the
gateway's 429), per-request queue-wait deadlines
(``FinishReason.EXPIRED``), strict-priority + per-tenant-WFQ admission
order, and — when ``SchedulerConfig.preemption`` is set — suspension of
a strictly less important decoding victim to host memory
(``SlotKVPool.suspend``/``resume``) so an interactive arrival gets its
slot immediately.  Preemption happens only at the admission boundary,
where every slot's KV length equals the request's committed position
(spec rounds commit + roll back entirely inside their step), so a
resumed request's remaining generation is bit-identical to an
unpreempted run; the chunk-quantized suspend/resume executables are
precompiled by :meth:`Engine.warmup`.

Telemetry: ``Engine(..., telemetry=repro.obs.Telemetry(...))`` arms
per-request span tracing (Chrome trace JSON), the structured event log
(rung switches with controller reasons, gamma changes, prefix
evictions, KV rollbacks, compile/retrace records) and per-dispatch JAX
profiler annotations.  Telemetry only *observes* host-side state —
tokens are bit-identical with it on or off — and the default
``NULL_TELEMETRY`` costs nothing: every emit site is an ``is not
None`` check and ``annotate()`` returns a shared null context.

Quality probes: ``Telemetry(quality=QualityMonitor(...))`` additionally
arms live sparsity-quality observability (``repro.obs.quality``) —
sampled shadow dense probes (run *before* the real decode dispatch, so
served tokens and KV stay bit-identical), online Eq. 6 reconstruction
error vs the ladder's calibration baselines, saliency-drift events, and
per-rung roofline counters captured at :meth:`Engine.warmup`.  Both
quality executables precompile at warmup
(``probe_retraces_after_warmup`` stays 0), and with
``SLOConfig.quality_aware`` the controller reads the drift-pressure
gauge as an advisory de-escalation hint.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.models import api
from repro.obs import NULL_TELEMETRY, Telemetry
from repro.serving.controller import AdaptiveController, SLOConfig
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import EngineStats
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import (FinishReason, Priority, Request,
                                   RequestState, Status)
from repro.serving.scheduler import QueueFull, Scheduler, SchedulerConfig
from repro.serving.spec import SpecConfig, SpecDecoder
from repro.sparsity import PolicyLadder, SparsityPolicy

_CHUNKABLE_MIXERS = ("attn", "global")

# Engine.snapshot() JSONL format version.  v1 (implicit, pre-versioned):
# load/latency/rung fields.  v2: adds "schema_version" itself plus the
# speculative-decoding fields (spec_gamma, spec_drafter_rung,
# spec_accept_ewma, spec_accept_rate) when spec decoding is armed.
# v3: adds the prefix-cache fields (prefix_hit_rate, prefix_tokens_saved,
# prefix_cached_tokens, prefix_segments) when the prefix cache is armed.
# v4: tpot_p50_s/tpot_p95_s switch from windowed ring-buffer percentiles
# to exact whole-run histogram quantiles, tpot_p95_window_s keeps the
# windowed estimate explicitly, and telemetry_events/telemetry_spans
# report live sink depths when telemetry is armed.
# v5: adds the admission-control/preemption fields (suspended,
# preemptions, resumes, rejected, expired, queue_wait_p95_s) when an
# explicit SchedulerConfig is armed; "queue_depth" still counts only
# queued (unadmitted) requests — suspended requests report separately.
# v6: adds the quality-probe fields (quality_probes, quality_probe_tokens,
# quality_agreement_mean, quality_topk_overlap_mean, quality_recon_mean,
# quality_recon_vs_baseline, quality_drift_events, quality_pressure) when
# a QualityMonitor is armed, and quality_deescalations in the controller
# section when SLOConfig.quality_aware is set.
# v7: adds the flight-recorder fields (flight_records, flight_dropped,
# flight_dumps) when a FlightRecorder is armed; "t" is documented as an
# out-of-band wall read (never part of a flight recording's replayed
# clock stream).
SNAPSHOT_SCHEMA_VERSION = 7


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """``policy`` is the engine's execution policy (validated eagerly at
    construction; ``None`` means dense).  Ladder serving ignores it — the
    rung policies come from the ladder passed to :class:`Engine`.

    ``slo`` enables the adaptive controller (requires a ladder);
    ``initial_rung`` is the rung a ladder engine starts on (and stays on
    when no SLO is configured — a pinned rung).

    ``spec`` arms self-speculative decoding (requires a ladder: the
    drafter and verifier are rungs).  The engine then serves at the
    verifier rung and its decode actions run draft/verify rounds —
    token-identical output to verifier-only decode, fewer verifier
    passes per token (``repro.serving.spec``).

    ``prefix_cache`` arms radix-tree KV prefix reuse
    (``repro.serving.prefix_cache``): completed prefills publish into
    the tree, admissions sharing a cached prefix skip straight to the
    un-cached suffix.  ``prefix_cache_tokens`` bounds the cached
    physical tokens (0 = unbounded; LRU eviction of unpinned leaves).
    Needs chunked prefill and prefix-deterministic prefill policies —
    validated eagerly at engine construction."""
    max_slots: int = 8
    max_len: int = 512
    prefill_chunk: int = 32
    policy: Optional[SparsityPolicy] = None
    prefill_dense_frac: float = 0.5  # §5.1: first fraction of prompt dense
    prefill_strategy: str = "auto"   # auto|chunked|whole
    eos_id: Optional[int] = None     # default per-request EOS
    slo: Optional[SLOConfig] = None  # adaptive serving objectives
    initial_rung: int = 0            # ladder rung at engine start
    spec: Optional[SpecConfig] = None  # self-speculative decoding
    prefix_cache: bool = False       # radix-tree KV prefix reuse
    prefix_cache_tokens: int = 0     # cached-token budget (0 = unbounded)
    scheduler: Optional[SchedulerConfig] = None  # admission + preemption
    #                                  policy; None = unbounded FIFO-
    #                                  equivalent defaults

    def __post_init__(self):
        pol = self.policy
        if pol is None:
            pol = SparsityPolicy.dense()
        elif not isinstance(pol, SparsityPolicy):
            raise TypeError(
                f"policy must be a SparsityPolicy, got {type(pol)!r}")
        object.__setattr__(self, "policy", pol)
        if self.slo is not None and not isinstance(self.slo, SLOConfig):
            raise TypeError(f"slo must be an SLOConfig, got {type(self.slo)!r}")
        if self.spec is not None and not isinstance(self.spec, SpecConfig):
            raise TypeError(
                f"spec must be a SpecConfig, got {type(self.spec)!r}")
        if self.scheduler is not None and not isinstance(
                self.scheduler, SchedulerConfig):
            raise TypeError(
                f"scheduler must be a SchedulerConfig, "
                f"got {type(self.scheduler)!r}")
        if self.initial_rung < 0:
            raise ValueError(
                f"initial_rung must be >= 0, got {self.initial_rung}")
        if not 0 <= self.prefill_dense_frac <= 1:
            raise ValueError(
                f"prefill_dense_frac must be in [0, 1], "
                f"got {self.prefill_dense_frac}")
        if self.prefill_strategy not in ("auto", "chunked", "whole"):
            raise ValueError(
                f"unknown prefill_strategy {self.prefill_strategy!r}")
        if self.prefix_cache_tokens < 0:
            raise ValueError(
                f"prefix_cache_tokens must be >= 0, "
                f"got {self.prefix_cache_tokens}")
        if self.prefix_cache and self.prefill_strategy == "whole":
            raise ValueError(
                "prefix_cache needs chunked prefill: whole-prompt "
                "prefill cannot start at a matched prefix length")


def make_engine_steps(cfg: ModelConfig, on_decode_trace=None,
                      on_chunk_trace=None):
    """The engine's three jitted step executables — slot decode, chunked
    prefill, whole-prompt prefill — with the canonical static-arg and
    donation configuration.  This is the ONE place that configuration
    lives: the :class:`Engine` serves through these exact jits, and the
    ``repro.analysis`` jaxpr passes lower the same ones, so a donation
    or static-arg regression here is caught by the lint without the two
    sites drifting apart.

    ``on_decode_trace`` / ``on_chunk_trace`` run inside the traced
    function body — i.e. only while XLA is (re)tracing — which is how
    the engine counts retraces.

    The pool caches are donated back into themselves each step (no copy
    on TPU; XLA falls back to copying where donation is unsupported).
    ``policy`` is static: it must stay a frozen, hashable
    :class:`SparsityPolicy` or every step becomes a cache miss."""
    slot_decode = api.make_slot_decode_step(cfg)
    chunk_step = api.make_chunk_prefill_step(cfg)
    prefill_step = api.make_prefill_step(cfg)

    def _decode(params, tokens, positions, caches, sp, active, *,
                policy):
        if on_decode_trace is not None:
            on_decode_trace()
        return slot_decode(params, tokens, positions, caches, sp,
                           active, policy=policy)

    def _chunk(params, tokens, offset, slot, caches, sp, weights, *,
               policy):
        if on_chunk_trace is not None:
            on_chunk_trace()
        return chunk_step(params, tokens, offset, slot, caches, sp,
                          weights, policy=policy)

    def _prefill(params, tokens, sp, *, policy):
        return prefill_step(params, {"tokens": tokens}, sp,
                            policy=policy)

    dstep = jax.jit(_decode, static_argnames=("policy",),
                    donate_argnums=(3,))
    cstep = jax.jit(_chunk, static_argnames=("policy",),
                    donate_argnums=(4,))
    pstep = jax.jit(_prefill, static_argnames=("policy",))
    return dstep, cstep, pstep


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 sp=None, *, ladder: Optional[PolicyLadder] = None,
                 telemetry: Optional[Telemetry] = None, clock=None):
        if cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                f"serving engine supports token-only models, not {cfg.family}")
        if telemetry is None:
            telemetry = NULL_TELEMETRY
        elif not isinstance(telemetry, Telemetry):
            raise TypeError(
                f"telemetry must be a repro.obs.Telemetry, "
                f"got {type(telemetry)!r}")
        self.obs = telemetry
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.ladder = ladder
        if ladder is not None:
            if not isinstance(ladder, PolicyLadder):
                raise TypeError(
                    f"ladder must be a PolicyLadder, got {type(ladder)!r}")
            if sp is not None:
                raise ValueError(
                    "pass either a ladder (which carries per-rung sp "
                    "trees) or a flat sp tree, not both")
            if not 0 <= ecfg.initial_rung < len(ladder):
                raise ValueError(
                    f"initial_rung {ecfg.initial_rung} outside the "
                    f"{len(ladder)}-rung ladder")
            self._rung_policies = list(ladder.policies)
            self._rung_sp = list(ladder.sps)
        else:
            if ecfg.slo is not None:
                raise ValueError(
                    "EngineConfig.slo needs a PolicyLadder: the controller "
                    "switches rungs, a single policy has none")
            if ecfg.initial_rung != 0:
                raise ValueError(
                    f"initial_rung={ecfg.initial_rung} needs a "
                    "PolicyLadder; a fixed-policy engine has only rung 0")
            self._rung_policies = [ecfg.policy]
            self._rung_sp = [sp]
        # per-rung per-phase static policies, derived once so equal
        # phases reuse equal (hash-equal) jit cache keys
        self._rung_phases = [
            (pol.for_phase("prefill_dense"), pol.for_phase("prefill_sparse"),
             pol.for_phase("decode")) for pol in self._rung_policies]
        self._rung = ecfg.initial_rung if ladder is not None else 0
        # injected clock: every engine time read goes through
        # self.clock.now(site).  Default is the shared SYSTEM_CLOCK
        # singleton (`is`-identity testable, zero-cost); a flight
        # recorder wraps it so each observation is captured, and replay
        # substitutes a ReplayClock feeding recorded stamps back.
        if clock is None:
            clock = obs.SYSTEM_CLOCK
        elif not hasattr(clock, "now"):
            raise TypeError(
                f"clock must expose now(site), got {type(clock)!r}")
        self.clock = clock
        if self.obs.flight is not None:
            self.clock = self.obs.flight.attach_engine(self)
        self.controller = None
        if ecfg.slo is not None:
            self.controller = AdaptiveController(
                len(self._rung_policies), ecfg.slo,
                initial_rung=self._rung)
        mixers = {m for m, _ in cfg.layer_kinds()}
        chunkable = mixers <= set(_CHUNKABLE_MIXERS)
        if ecfg.spec is not None:
            if ladder is None:
                raise ValueError(
                    "EngineConfig.spec needs a PolicyLadder: the drafter "
                    "and verifier are ladder rungs")
            if ecfg.slo is not None:
                raise ValueError(
                    "spec and slo are mutually exclusive: the spec "
                    "controller adapts gamma/drafter from acceptance, and "
                    "the verifier rung is pinned")
            if not chunkable:
                raise ValueError(
                    "speculative decoding needs plain-attention mixers "
                    f"(got {mixers}): the verify forward reuses the "
                    "chunked write-in-place path and rollback needs "
                    "full-length caches")
            if ecfg.spec.drafter_rung >= len(ladder):
                raise ValueError(
                    f"drafter_rung {ecfg.spec.drafter_rung} outside the "
                    f"{len(ladder)}-rung ladder")
            if ecfg.initial_rung != ecfg.spec.verifier_rung:
                raise ValueError(
                    "a spec engine serves at the verifier rung; set "
                    f"initial_rung == verifier_rung "
                    f"({ecfg.spec.verifier_rung})")
            ver_pol = self._rung_phases[ecfg.spec.verifier_rung][2]
            if not ver_pol.is_dense:
                raise ValueError(
                    f"verifier rung {ecfg.spec.verifier_rung} decodes "
                    "under a sparse policy; the token-parity guarantee "
                    "needs a dense verifier — shared top-k saliency "
                    "depends on the call's token rows, so a multi-token "
                    "verify forward and single-token decode would pick "
                    "different channel sets and diverge")
        # the pool holds slack past max_len: pad tokens of a request's
        # final prefill chunk land in [max_len, pool_len-1), and the last
        # position is scratch — inactive slots in a decode step must still
        # write *somewhere*, and every real position (< max_len) may
        # belong to a mid-prefill prompt span that a garbage write would
        # corrupt.  Scratch is beyond every reachable position, so the
        # decode valid-mask never admits it.  Spec decoding needs the
        # slack to also fit a (gamma+1)-token verify window (inactive-slot
        # windows and draft overshoot past a request's budget both land
        # there).
        slack = ecfg.prefill_chunk
        if ecfg.spec is not None:
            slack = max(slack, ecfg.spec.max_gamma + 1)
        self.pool_len = ecfg.max_len + slack
        self.pool = SlotKVPool(cfg, ecfg.max_slots, self.pool_len)
        self.scheduler = Scheduler(ecfg.scheduler)
        self._preemptible = (ecfg.scheduler is not None
                             and ecfg.scheduler.preemption)
        if self._preemptible and not self.pool.can_cache_prefix:
            raise ValueError(
                "preemption needs full-length self-attention caches: "
                "suspend/resume snapshots slice the kv_seq axis by "
                "absolute position (same precondition as the prefix "
                "cache and rollback)")
        self.stats = EngineStats()
        self.states: Dict[int, RequestState] = {}
        self._next_id = 0
        self._closed = False
        self._decode_traces = 0      # python-side retrace counter
        self._chunk_traces = 0
        self._warm_traces: Optional[int] = None

        if ecfg.prefill_strategy == "auto":
            self.prefill_strategy = "chunked" if chunkable else "whole"
        else:
            if ecfg.prefill_strategy == "chunked" and not chunkable:
                raise ValueError(
                    f"chunked prefill needs plain-attention mixers, got {mixers}")
            self.prefill_strategy = ecfg.prefill_strategy

        self.prefix_cache: Optional[PrefixCache] = None
        if ecfg.prefix_cache:
            if self.prefill_strategy != "chunked":
                raise ValueError(
                    "prefix_cache needs the chunked prefill strategy "
                    f"(this arch resolved to {self.prefill_strategy!r}): "
                    "rolling-window/SSM caches cannot resume mid-prompt")
            # bit-exact reuse needs every rung's *effective* prefill
            # policy to be independent of the prompt length and
            # prefix-deterministic — otherwise a cached prefix would
            # differ from what a cold prefill of the reusing request
            # would have computed.  A multi-rung engine must prefill
            # *dense*: rung sp trees differ, so even the per-token
            # "mask" backend would make cached KV rung-dependent.
            effective = [self._effective_prefill_policy(r)
                         for r in range(len(self._rung_phases))]
            if len(effective) > 1:
                if not all(p.is_dense for p in effective):
                    raise ValueError(
                        "prefix_cache on a ladder engine needs every "
                        "rung to prefill dense (a prefix cached at one "
                        "rung seeds requests served at any rung, and "
                        "rung sp trees differ); build the ladder with "
                        "dense_phases=('prefill_dense', 'prefill_sparse')")
            elif not effective[0].prefix_deterministic():
                raise ValueError(
                    f"prefix_cache needs a prefix-deterministic prefill "
                    f"policy (per-token backends 'off'/'mask'), got "
                    f"{effective[0].backend!r}: shared top-k saliency "
                    "depends on the call's token rows, so cached KV "
                    "would bake in the donor request's chunking and "
                    "break the token-parity guarantee")
            self.prefix_cache = PrefixCache(
                self.pool, ecfg.prefill_chunk, ecfg.prefix_cache_tokens,
                stats_fn=lambda: self.stats, obs_fn=lambda: self.obs)

        def _on_decode_trace():
            self._decode_traces += 1        # runs only while tracing
            self._record_compile("decode")

        def _on_chunk_trace():
            self._chunk_traces += 1
            self._record_compile("prefill_chunk")

        self._dstep, self._cstep, self._pstep = make_engine_steps(
            cfg, on_decode_trace=_on_decode_trace,
            on_chunk_trace=_on_chunk_trace)

        self.spec_decoder: Optional[SpecDecoder] = None
        if ecfg.spec is not None:
            self.spec_decoder = SpecDecoder(self, ecfg.spec)

        if self.controller is not None or self.spec_decoder is not None \
                or self.prefix_cache is not None or self._preemptible \
                or self.obs.quality is not None:
            self.warmup()

    # ------------------------------------------------------------------
    # ladder rungs
    # ------------------------------------------------------------------
    @property
    def rung(self) -> int:
        return self._rung

    @property
    def num_rungs(self) -> int:
        return len(self._rung_policies)

    @property
    def policy(self) -> SparsityPolicy:
        """The currently active rung's policy."""
        return self._rung_policies[self._rung]

    @property
    def sp(self):
        return self._rung_sp[self._rung]

    def set_rung(self, i: int) -> None:
        if not 0 <= i < self.num_rungs:
            raise ValueError(f"rung {i} outside [0, {self.num_rungs})")
        self._rung = i

    def _effective_prefill_policy(self, rung: int) -> SparsityPolicy:
        """The one policy every prefill chunk of ``rung`` runs under —
        well-defined only when the §5.1 phase split cannot produce
        prompt-length-dependent KV (the prefix-cache precondition)."""
        pd, ps, _ = self._rung_phases[rung]
        f = self.ecfg.prefill_dense_frac
        if f >= 1.0:
            return pd
        if f <= 0.0:
            return ps
        if pd != ps:
            raise ValueError(
                f"prefix_cache with prefill_dense_frac={f} needs rung "
                f"{rung}'s prefill_dense and prefill_sparse phase "
                "policies to be equal: the dense/sparse boundary scales "
                "with the prompt length, so a cached prefix would carry "
                "a different phase split than a cold prefill of the "
                "reusing request (set prefill_dense_frac to 0 or 1, or "
                "make both phases dense)")
        return pd

    def warmup(self) -> None:
        """Precompile every rung's decode (and chunked-prefill) phase
        executables — plus, under spec decoding, the verifier's verify
        executable for every reachable draft length gamma, and, under
        prefix caching, the segment extract/copy executable for every
        quantized prefix length — then zero the post-warmup retrace
        baseline.  Only valid on an idle engine: the
        warmup chunk writes garbage into slot 0's cache prefix, which is
        harmless *before* any admission (the slot's real prefill
        overwrites it) but would corrupt a live request.  Rung and gamma
        switches after this never trace
        (``decode_retraces_after_warmup`` stays 0) — except whole-prompt
        prefill executables, which are keyed on prompt length and cannot
        be precompiled here; on "whole"-strategy archs (SSM/local
        mixers) a rung switch can still compile a fresh prefill, decode
        stays retrace-free."""
        if self.scheduler.has_work() or self.pool.num_occupied:
            raise RuntimeError(
                "warmup() on a busy engine would corrupt live KV state; "
                "call it before submitting requests")
        S = self.ecfg.max_slots
        C = self.ecfg.prefill_chunk
        tokens = jnp.zeros((S,), jnp.int32)
        positions = jnp.full((S,), self.pool_len - 1, jnp.int32)
        inactive = jnp.zeros((S,), jnp.float32)
        for (pd, ps, dec), sp in zip(self._rung_phases, self._rung_sp):
            logits, self.pool.caches = self._dstep(
                self.params, tokens, positions, self.pool.caches, sp,
                inactive, policy=dec)
            logits.block_until_ready()
            if self.prefill_strategy == "chunked":
                for pol in (pd, ps):
                    logits, self.pool.caches = self._cstep(
                        self.params, jnp.zeros((1, C), jnp.int32),
                        jnp.zeros((1,), jnp.int32), jnp.int32(0),
                        self.pool.caches, sp, jnp.zeros((C,), jnp.float32),
                        policy=pol)
                    logits.block_until_ready()
        if self.spec_decoder is not None:
            sd = self.spec_decoder
            _, _, ver_pol = self._rung_phases[sd.verifier_rung]
            ver_sp = self._rung_sp[sd.verifier_rung]
            for g in self.ecfg.spec.gammas():
                logits, self.pool.caches = sd._vstep(
                    self.params, jnp.zeros((S, g + 1), jnp.int32),
                    jnp.full((S,), self.pool_len - (g + 1), jnp.int32),
                    self.pool.caches, ver_sp,
                    jnp.zeros((S, g + 1), jnp.float32), policy=ver_pol)
                logits.block_until_ready()
        if self.prefix_cache is not None:
            # segment extract/copy executables for every reachable
            # quantized length — the first hit/publish must not stall
            # live traffic on a compile.  Suspend/resume reuse the same
            # executables at the same quantized lengths, so this sweep
            # covers preemption too.
            self.prefix_cache.warm(self.ecfg.max_len - 1)
        elif self._preemptible:
            # no prefix cache, but preemption still needs the chunk-
            # quantized extract/write executables precompiled so a
            # serving-time suspend/resume never stalls on a trace
            self.pool.warm_segments(self.ecfg.prefill_chunk,
                                    self.ecfg.max_len - 1)
        if self.obs.quality is not None:
            # builds + precompiles the shadow-probe and reconstruction
            # executables and AOT-captures per-rung roofline counters —
            # before the retrace baseline below, so those compiles count
            # as warmup, and live probing never traces
            self.obs.quality.attach(self)
        self._warm_traces = (
            self._decode_traces, self._chunk_traces,
            self.spec_decoder._verify_traces
            if self.spec_decoder is not None else 0,
            self.pool._segment_traces)

    @property
    def decode_retraces_after_warmup(self) -> Optional[int]:
        """Decode (re)traces since :meth:`warmup`; None before warmup.
        The adaptive-serving invariant is that this stays 0 no matter how
        often the controller switches rungs (draft steps included — they
        run through the same decode executable at the drafter rung)."""
        if self._warm_traces is None:
            return None
        return self._decode_traces - self._warm_traces[0]

    @property
    def verify_retraces_after_warmup(self) -> Optional[int]:
        """Spec verify (re)traces since :meth:`warmup`; None before warmup
        or without spec decoding.  Stays 0 across gamma switches — every
        reachable gamma's verify executable precompiles at warmup."""
        if self._warm_traces is None or self.spec_decoder is None:
            return None
        return self.spec_decoder._verify_traces - self._warm_traces[2]

    @property
    def probe_retraces_after_warmup(self) -> Optional[int]:
        """Quality probe/recon (re)traces since :meth:`warmup`; None
        without an armed :class:`repro.obs.quality.QualityMonitor`.
        Stays 0 under live probing — both quality executables precompile
        at warmup with the shapes the hot path uses."""
        q = self.obs.quality
        if q is None or not q.armed:
            return None
        return q.retraces_after_warmup

    @property
    def segment_retraces_after_warmup(self) -> Optional[int]:
        """Segment extract/write (re)traces since :meth:`warmup`; None
        before warmup.  Covers both prefix-cache hits/publishes and
        preemption suspend/resume — warmup precompiles every
        chunk-quantized length, so this stays 0 under live traffic."""
        if self._warm_traces is None:
            return None
        return self.pool._segment_traces - self._warm_traces[3]

    # ------------------------------------------------------------------
    # telemetry plumbing
    # ------------------------------------------------------------------
    def _record_compile(self, phase: str) -> None:
        """Called from inside the jitted wrappers — runs only while XLA
        is (re)tracing, so every emission is one compile record.  A
        compile after warmup is a retrace (the bug the
        ``decode_retraces_after_warmup == 0`` invariant guards), flagged
        so the event log shows *which* executable broke the discipline."""
        ev = self.obs.events
        if ev is not None:
            ev.emit("compile", phase=phase, rung=self._rung,
                    post_warmup=self._warm_traces is not None)

    def metrics_exposition(self) -> str:
        """This engine's live stats in Prometheus text-exposition format
        (built per call, off the hot path — see
        :func:`repro.obs.metrics.engine_registry`)."""
        return obs.engine_exposition(self)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, eos_id: Optional[int] = None,
               arrival_time: Optional[float] = None,
               on_token=None, *, priority: Priority = Priority.STANDARD,
               tenant: str = "default",
               queue_deadline_s: Optional[float] = None,
               on_finish=None) -> RequestState:
        if self._closed:
            raise RuntimeError("submit() on a closed engine")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0 or prompt.size >= self.ecfg.max_len:
            raise ValueError(
                f"prompt length {prompt.size} outside (0, {self.ecfg.max_len})")
        priority = (Priority.parse(priority) if isinstance(priority, str)
                    else Priority(priority))
        if queue_deadline_s is not None and queue_deadline_s <= 0:
            raise ValueError(
                f"queue_deadline_s must be positive, got {queue_deadline_s}")
        fr = self.obs.flight
        if fr is not None:
            # submit-intent first, then its clock read(s), then the
            # decision — the replay driver re-issues the call verbatim
            # when it meets this record at the shared cursor
            fr.record_submit(prompt, max_new_tokens, eos_id, arrival_time,
                             priority, tenant, queue_deadline_s)
        if not self.scheduler.can_accept():
            self.stats.rejected += 1
            retry = self._retry_after()
            if self.obs.events is not None:
                self.obs.events.emit(
                    "reject", reason="queue_full",
                    queue_depth=self.scheduler.queue_depth,
                    retry_after_s=round(retry, 3))
            if fr is not None:
                fr.decision("reject", reason="queue_full",
                            queue_depth=self.scheduler.queue_depth,
                            retry_after_s=round(retry, 3))
            raise QueueFull(
                f"admission queue at capacity "
                f"({self.scheduler.cfg.max_queue})", retry_after=retry)
        max_new = min(max_new_tokens, self.ecfg.max_len - prompt.size)
        req = Request(self._next_id, prompt, max_new,
                      eos_id if eos_id is not None else self.ecfg.eos_id,
                      self._now("submit.arrival") if arrival_time is None
                      else arrival_time,
                      priority=priority, tenant=tenant,
                      queue_deadline_s=queue_deadline_s)
        self._next_id += 1
        rs = RequestState(req, on_token=on_token, on_finish=on_finish)
        self.states[req.request_id] = rs
        self.scheduler.enqueue(rs)
        self.stats.submitted += 1
        tr = self.obs.tracer
        if tr is not None:
            tr.thread_name(req.request_id + 1, f"req {req.request_id}")
            tr.instant("submit", tid=req.request_id + 1,
                       request=req.request_id, prompt_len=req.prompt_len,
                       max_new_tokens=max_new, priority=priority.name.lower(),
                       tenant=tenant)
        return rs

    def _retry_after(self) -> float:
        """Polite-client 429 hint: roughly how long until queued work
        ahead drains — queued requests × observed mean tokens-per-request
        × mean inter-token gap, floored at 1s (and at 1s before any
        traffic has calibrated the means)."""
        s = self.stats
        tokens_per_req = s.decode_tokens / s.finished if s.finished else 0.0
        gap = s.tpot_s.mean if s.tpot_s.count else 0.0
        return max(1.0, self.scheduler.queue_depth * tokens_per_req * gap)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self) -> str:
        """Admit (expiring, resuming and preempting as the scheduler
        config allows), then run one scheduler-chosen phase step."""
        self._admit()
        self.stats.sample(self.scheduler.queue_depth, self.pool.num_occupied)
        if self.obs.tracer is not None:
            self.obs.tracer.counter(
                "engine_load", queue_depth=self.scheduler.queue_depth,
                occupancy=self.pool.num_occupied)
        action = self.scheduler.next_action()
        if action == "prefill":
            if self.prefill_strategy == "chunked":
                self._prefill_chunk(self.scheduler.prefill_head())
            else:
                self._prefill_whole(self.scheduler.prefill_group())
        elif action == "decode":
            if self.spec_decoder is not None:
                self.spec_decoder.step()
            else:
                self._decode_step()
        return action

    def run(self) -> Dict[int, List[int]]:
        """Drive until idle; returns {request_id: generated tokens}."""
        while self.scheduler.has_work():
            self.step()
        return {rid: rs.tokens for rid, rs in self.states.items()}

    # ------------------------------------------------------------------
    # admission, preemption, resume
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """One admission pass: expire deadline-missed queued requests,
        then fill free slots — resuming suspended requests and admitting
        queued ones in priority order, suspending a strictly less
        important decoding victim when preemption is armed and the pool
        is full.  Runs before every phase step, i.e. always at a
        committed KV boundary (see the module docstring)."""
        sched = self.scheduler
        now = self._now("admit.sweep")
        for rs in sched.expire(now):
            self._expire(rs, now)
        while True:
            rs_s = sched.peek_resume()
            head_p = sched.head_priority()
            if rs_s is None and head_p is None:
                return
            # a suspended request outranks a queued one of the same
            # class: it arrived earlier and already holds partial work
            take_suspended = rs_s is not None and (
                head_p is None or rs_s.request.priority <= head_p)
            target_p = rs_s.request.priority if take_suspended else head_p
            if self.pool.num_free == 0:
                victim = (sched.pick_victim(target_p)
                          if self._preemptible else None)
                if victim is None:
                    return
                self._preempt(victim)
            if take_suspended:
                self._resume(sched.pop_resume())
            else:
                self._admit_queued(sched.pop_admit(), now)

    def _expire(self, rs: RequestState, now: float) -> None:
        req = rs.request
        rs.finish_reason = FinishReason.EXPIRED
        rs.finish_time = now
        rs.status = Status.FINISHED
        self.stats.expired += 1
        waited = now - req.arrival_time
        if self.obs.events is not None:
            self.obs.events.emit(
                "reject", reason="deadline", request=req.request_id,
                waited_s=round(waited, 4),
                deadline_s=req.queue_deadline_s)
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "expire", tid=req.request_id + 1, waited_s=waited)
        fr = self.obs.flight
        if fr is not None:
            fr.decision("reject", reason="deadline", request=req.request_id,
                        waited_s=round(waited, 4),
                        deadline_s=req.queue_deadline_s)
            fr.finish(req.request_id, rs.finish_reason.value,
                      rs.tokens, rs.token_rungs)
        rs.finished()

    def _admit_queued(self, rs: RequestState, now: float) -> None:
        rs.slot = self.pool.alloc()
        if self.prefix_cache is not None:
            self.prefix_cache.admit(rs)     # hit: cursor jumps past the
        rs.status = Status.PREFILL          # cached prefix
        self.scheduler.prefilling.append(rs)
        self.stats.observe_queue_wait(max(0.0, now - rs.request.arrival_time))
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "admit", tid=rs.request.request_id + 1, slot=rs.slot,
                cached_prefix=rs.next_offset,
                priority=rs.request.priority.name.lower())

    def _preempt(self, victim: RequestState) -> None:
        """Suspend a decoding victim: snapshot its KV state to host
        memory at a chunk-quantized length (warmup-precompiled — no
        trace) and free the slot.  Admission-boundary only: the slot's
        KV length equals the victim's committed position, which is what
        makes the later resume bit-identical."""
        t = self._now("preempt")
        req = victim.request
        slot = victim.slot
        seg = self.pool.suspend(slot, self.ecfg.prefill_chunk)
        if seg.length != victim.position:
            raise RuntimeError(
                f"preempt: slot {slot} KV length {seg.length} != request "
                f"{req.request_id} position {victim.position}; suspension "
                "must happen at a committed boundary")
        self.scheduler.suspend(victim)      # pops decoding via the slot
        self.pool.free(slot)
        victim.suspended = seg
        victim.suspend_time = t
        victim.preemptions += 1
        victim.slot = -1
        self.stats.preemptions += 1
        if self.obs.events is not None:
            self.obs.events.emit(
                "preempt", t=t, request=req.request_id, slot=slot,
                kv_length=seg.length, kv_phys=seg.phys,
                priority=req.priority.name.lower(),
                tokens_done=len(victim.tokens))
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "preempt", t=t, tid=req.request_id + 1, slot=slot,
                kv_length=seg.length)
        fr = self.obs.flight
        if fr is not None:
            fr.decision("preempt", request=req.request_id, slot=slot,
                        kv_length=seg.length,
                        tokens_done=len(victim.tokens))

    def _resume(self, rs: RequestState) -> None:
        """Restore a suspended request into a freshly allocated slot:
        write the host-side segment back (same precompiled executable
        set) and rejoin the decoding set at the exact committed
        position — generation continues bit-identically."""
        t = self._now("resume")
        req = rs.request
        slot = self.pool.alloc()
        self.pool.resume(rs.suspended, slot)
        kv_length = rs.suspended.length
        rs.suspended = None
        rs.slot = slot
        rs.status = Status.DECODE
        self.scheduler.decoding[slot] = rs
        self.stats.resumes += 1
        suspended_s = None
        if rs.suspend_time is not None:
            suspended_s = t - rs.suspend_time
            self.stats.observe_preempted(suspended_s)
            rs.suspend_time = None
        if self.obs.events is not None:
            self.obs.events.emit(
                "resume", t=t, request=req.request_id, slot=slot,
                kv_length=kv_length,
                suspended_s=None if suspended_s is None
                else round(suspended_s, 4))
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "resume", t=t, tid=req.request_id + 1, slot=slot,
                kv_length=kv_length)
        fr = self.obs.flight
        if fr is not None:
            fr.decision("resume", request=req.request_id, slot=slot,
                        kv_length=kv_length)

    # ------------------------------------------------------------------
    # phases
    # ------------------------------------------------------------------
    def _phase_policy(self, offset: int, prompt_len: int) -> SparsityPolicy:
        """§5.1: chunks starting before the dense boundary run dense."""
        pd, ps, _ = self._rung_phases[self._rung]
        dense_end = int(np.ceil(prompt_len * self.ecfg.prefill_dense_frac))
        return pd if offset < dense_end else ps

    def _emit(self, rs: RequestState, token: int) -> None:
        rs.emit(token)
        if self.ladder is not None:
            rs.token_rungs.append(self._rung)
        self.stats.decode_tokens += 1

    def _prefill_chunk(self, rs: RequestState) -> None:
        C = self.ecfg.prefill_chunk
        req = rs.request
        off = rs.next_offset
        real = min(C, req.prompt_len - off)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :real] = req.prompt[off:off + real]
        weights = np.zeros((C,), np.float32)
        weights[:real] = 1.0
        policy = self._phase_policy(off, req.prompt_len)
        t0 = self._now("prefill_chunk.t0")
        with self.obs.annotate("repro/prefill_chunk"):
            logits, self.pool.caches = self._cstep(
                self.params, jnp.asarray(chunk),
                jnp.full((1,), off, jnp.int32),
                jnp.int32(rs.slot), self.pool.caches, self.sp,
                jnp.asarray(weights), policy=policy)
            logits.block_until_ready()
        t1 = self._now("prefill_chunk.t1")
        dt = t1 - t0
        self.stats.prefill_time += dt
        self.stats.observe_prefill_step(dt)
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += real
        if self.obs.tracer is not None:
            self.obs.tracer.complete(
                "prefill_chunk", t0, t1, tid=req.request_id + 1,
                slot=rs.slot, offset=off, tokens=real, rung=self._rung)
        rs.next_offset = off + real
        self.pool.lengths[rs.slot] = rs.next_offset
        if rs.done_prefill:
            if self.prefix_cache is not None:
                # release the admission pin and cache this prompt's
                # prefix before decode can extend the slot
                self.prefix_cache.publish(rs)
            first = int(np.asarray(jnp.argmax(logits[0, real - 1])))
            self._start_decode(rs, first)

    def _prefill_whole(self, group: List[RequestState]) -> None:
        P = group[0].request.prompt_len
        tokens = np.stack([rs.request.prompt for rs in group])
        # whole-prompt prefill can't split tokens by phase: any dense
        # fraction > 0 makes the whole prompt dense (the conservative
        # accuracy choice, matching the legacy serve path)
        pd, ps, _ = self._rung_phases[self._rung]
        policy = ps if self.ecfg.prefill_dense_frac <= 0.0 else pd
        t0 = self._now("prefill_whole.t0")
        with self.obs.annotate("repro/prefill_whole"):
            logits, caches = self._pstep(self.params, jnp.asarray(tokens),
                                         self.sp, policy=policy)
            logits.block_until_ready()
        t1 = self._now("prefill_whole.t1")
        dt = t1 - t0
        self.stats.prefill_time += dt
        self.stats.observe_prefill_step(dt)
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += P * len(group)
        if self.obs.tracer is not None:
            self.obs.tracer.complete(
                "prefill_whole", t0, t1, prompt_len=P, batch=len(group),
                rung=self._rung)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for b, rs in enumerate(group):
            self.pool.insert(caches, b, rs.slot, P)
            rs.next_offset = P
            self._start_decode(rs, int(first[b]))

    def _start_decode(self, rs: RequestState, first_token: int) -> None:
        rs.first_token_time = self._now("first_token")
        rs.last_token_time = rs.first_token_time
        self.stats.observe_ttft(
            rs.first_token_time - rs.request.arrival_time)
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "first_token", t=rs.first_token_time,
                tid=rs.request.request_id + 1, slot=rs.slot,
                ttft_s=rs.first_token_time - rs.request.arrival_time)
        self._emit(rs, first_token)
        self.scheduler.to_decode(rs)
        self._maybe_finish(rs, first_token)

    def _decode_step(self) -> None:
        S = self.ecfg.max_slots
        tokens = np.zeros((S,), np.int32)
        # inactive slots write their garbage token at the scratch position
        # (see pool_len above); their logits are ignored host-side and
        # their saliency weight is zero
        positions = np.full((S,), self.pool_len - 1, np.int32)
        active = np.zeros((S,), np.float32)
        decoding = self.scheduler.decoding
        for slot, rs in decoding.items():
            tokens[slot] = rs.last_token
            positions[slot] = rs.position
            active[slot] = 1.0
        _, _, dec_policy = self._rung_phases[self._rung]
        # shadow dense quality probe (sampled): runs *before* the real
        # decode so its K/V writes land exactly on the positions the
        # serving-policy step below overwrites — served tokens and cache
        # are bit-identical to a probe-free run, and the probe stays
        # outside the timed decode region so step stats are unchanged
        q = self.obs.quality
        probe = None
        if q is not None and q.should_probe():
            probe = q.run_probe(self, tokens, positions, active)
        t0 = self._now("decode.t0")
        with self.obs.annotate("repro/decode"):
            logits, self.pool.caches = self._dstep(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                self.pool.caches, self.sp, jnp.asarray(active),
                policy=dec_policy)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
        t1 = self._now("decode.t1")
        self.stats.decode_time += t1 - t0
        self.stats.observe_decode_step(t1 - t0)
        self.stats.decode_steps += 1
        if self.obs.tracer is not None:
            self.obs.tracer.complete(
                "decode_step", t0, t1, active=len(decoding),
                rung=self._rung)
        gaps = []
        for slot, rs in list(decoding.items()):
            tok = int(nxt[slot])
            if rs.last_token_time is not None:
                gaps.append(t1 - rs.last_token_time)
                self.stats.observe_tpot(gaps[-1])
            rs.last_token_time = t1
            self._emit(rs, tok)
            self.pool.commit(slot, 1)
            self._maybe_finish(rs, tok)
        if q is not None and probe is not None:
            q.observe(self, probe, logits, nxt, active, t1)
        if self.controller is not None:
            be_frac = None
            if self.controller.slo.priority_aware:
                be_frac = (sum(
                    1 for rs in decoding.values()
                    if rs.request.priority == Priority.BEST_EFFORT
                ) / len(decoding)) if decoding else 0.0
            qp = None
            if self.controller.slo.quality_aware and q is not None \
                    and q.armed:
                qp = q.pressure
            new_rung = self.controller.update(
                gaps, queue_depth=self.scheduler.queue_depth,
                occupancy=self.pool.num_occupied,
                best_effort_frac=be_frac, quality_pressure=qp)
            if new_rung != self._rung:
                old = self._rung
                self.set_rung(new_rung)
                tr = self.controller.transitions[-1] \
                    if self.controller.transitions else None
                reason = tr[3] if tr is not None else None
                if self.obs.events is not None:
                    self.obs.events.emit(
                        "rung_switch", t=t1, from_rung=old,
                        to_rung=new_rung, reason=reason,
                        controller_step=self.controller.step,
                        queue_depth=self.scheduler.queue_depth)
                if self.obs.tracer is not None:
                    self.obs.tracer.instant(
                        "rung_switch", t=t1, from_rung=old,
                        to_rung=new_rung, reason=reason)
                fr = self.obs.flight
                if fr is not None:
                    fr.decision("rung_switch", from_rung=old,
                                to_rung=new_rung, reason=reason,
                                controller_step=self.controller.step,
                                queue_depth=self.scheduler.queue_depth)

    def _maybe_finish(self, rs: RequestState, token: int) -> None:
        req = rs.request
        if req.eos_id is not None and token == req.eos_id:
            rs.finish_reason = FinishReason.EOS
        elif len(rs.tokens) >= req.max_new_tokens:
            rs.finish_reason = FinishReason.MAX_TOKENS
        else:
            return
        rs.finish_time = self._now("finish")
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "finish", t=rs.finish_time,
                tid=req.request_id + 1, slot=rs.slot,
                reason=rs.finish_reason.value,
                tokens=len(rs.tokens))
        fr = self.obs.flight
        if fr is not None:
            fr.finish(req.request_id, rs.finish_reason.value,
                      rs.tokens, rs.token_rungs)
        self.scheduler.finish(rs)
        self.pool.free(rs.slot)
        self.stats.finished += 1
        rs.finished()

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """One metrics record (JSONL-friendly): engine load, latency
        signals and — under a controller — rung state.  Versioned via
        ``schema_version`` (see :data:`SNAPSHOT_SCHEMA_VERSION`) so
        downstream metric consumers can detect format changes."""
        s = self.stats
        out = {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            # raw out-of-band read, NOT self._now(): observability reads
            # must never consume records from a ReplayClock stream
            "t": obs.now(),
            "queue_depth": self.scheduler.queue_depth,
            "occupancy": self.pool.num_occupied,
            "submitted": s.submitted,
            "finished": s.finished,
            "decode_steps": s.decode_steps,
            "decode_tokens": s.decode_tokens,
            "decode_tps": round(s.decode_tps, 1),
            # v4: whole-run exact-histogram quantiles (bucket upper
            # bounds); *_window_s keeps the old recent-window estimate
            "tpot_p50_s": None if not s.tpot_hist
            else round(s.tpot_hist.quantile(50), 6),
            "tpot_p95_s": None if not s.tpot_hist
            else round(s.tpot_hist.quantile(95), 6),
            "tpot_p95_window_s": None if not s.tpot_s
            else round(s.window_tpot_p95(), 6),
        }
        if self.ladder is not None:
            out["rung"] = self._rung
            out["budget"] = self.ladder.budgets[self._rung]
        if self.controller is not None:
            out.update(self.controller.snapshot())
        if self.spec_decoder is not None:
            out.update(self.spec_decoder.snapshot())
            out["spec_accept_rate"] = round(
                s.spec_accepted_tokens / max(1, s.spec_draft_tokens), 4)
        if self.prefix_cache is not None:
            out.update(self.prefix_cache.snapshot())
        if self.ecfg.scheduler is not None:
            out["suspended"] = len(self.scheduler.suspended)
            out["preemptions"] = s.preemptions
            out["resumes"] = s.resumes
            out["rejected"] = s.rejected
            out["expired"] = s.expired
            out["queue_wait_p95_s"] = None if not s.queue_wait_hist \
                else round(s.queue_wait_hist.quantile(95), 6)
        if self.obs.enabled:
            if self.obs.events is not None:
                out["telemetry_events"] = self.obs.events.count
            if self.obs.tracer is not None:
                out["telemetry_spans"] = len(self.obs.tracer.events)
        if self.obs.quality is not None and self.obs.quality.armed:
            out.update(self.obs.quality.snapshot())
        if self.obs.flight is not None:
            fr = self.obs.flight
            out["flight_records"] = fr.count
            out["flight_dropped"] = fr.dropped
            out["flight_dumps"] = len(fr.dumps)
        return out

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def reset_ids(self) -> None:
        """Restart this engine's request-id namespace at 0 and drop
        finished request states.  Benchmark reps reuse warm engines while
        replaying the same trace, and parity checks key on request id —
        resetting per rep keeps ids aligned across engines and reps.
        Only valid on an idle engine (no queued, in-flight or suspended
        requests)."""
        if self.scheduler.has_work() or self.pool.num_occupied:
            raise RuntimeError(
                "reset_ids() on a busy engine would orphan live requests")
        self._next_id = 0
        self.states = {}

    def close(self) -> None:
        """Flush and close the engine's telemetry sinks (event log,
        trace export, profiler session) so artifacts are never
        truncated.  Idempotent; further ``submit`` calls raise, but
        existing state stays readable.  Prefer the context-manager form
        (``with Engine(...) as eng:``) so sinks close even when the
        driving loop raises."""
        if self._closed:
            return
        self._closed = True
        self.obs.close()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None and self.obs.flight is not None:
            # black-box trigger: the driving loop died — dump the ring
            # before the sinks close so the incident is capturable
            self.obs.flight.dump("exception")
        self.close()
        return False

    # ------------------------------------------------------------------
    def _now(self, site: str = "") -> float:
        """One engine clock read, tagged with its consuming call site —
        the flight recorder logs the tag next to each observation so a
        replay divergence names the exact site that desynchronized."""
        return self.clock.now(site)

    @property
    def decode_traces(self) -> int:
        """How many times the batched decode step has (re)traced."""
        return self._decode_traces
