"""Self-speculative decoding: sparse rungs draft, the dense rung verifies.

WiSparse's training-free sparsity gives a family of cheaper variants of
the *same* model — the ladder rungs — sharing weights and KV cache with
the dense model: the textbook precondition for self-speculative decoding.
Per engine decode action the :class:`SpecDecoder` runs ``gamma``
sequential single-token draft steps at the (sparse) drafter rung, then
one batched length-``(gamma+1)`` verify forward at the verifier rung,
accepts each slot's longest draft prefix matching the verifier's greedy
tokens, commits the verifier-faithful KV the verify wrote in place, and
rolls the rejected suffix back out of the pool
(``SlotKVPool.rollback``).

Greedy-verify semantics: every committed token — accepted drafts and the
verifier's bonus token after the last accepted draft — is exactly the
token the verifier's own greedy decode would have produced, so the output
stream is token-identical to verifier-only decode while the per-token
cost approaches the drafter's.  The drafter's fidelity only moves the
*speed* (via the acceptance rate), never the output.

Compile-once discipline: drafting reuses the engine's batched slot-decode
executable at the drafter rung (precompiled for every rung by
``Engine.warmup()``); the verify forward compiles once per (gamma,
verifier policy) and warmup covers every gamma the adaptive controller
can reach, so rung and gamma switches are retrace-free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.serving.controller import SpecController


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding execution config.

    gamma          draft tokens per verify (the classic draft length).
    drafter_rung   ladder rung that drafts (must be sparser — higher —
                   than the verifier).
    verifier_rung  ladder rung whose greedy tokens the output is
                   guaranteed to match (0 = densest; the engine serves
                   prefill and emits tokens at this rung).  Its decode
                   policy must be *dense* — the engine validates: under
                   a sparse policy the shared top-k channel set depends
                   on the call's token rows, so the multi-token verify
                   forward and single-token decode would diverge and the
                   parity guarantee would silently break.
    adaptive       arm the :class:`SpecController`: tune gamma within
                   [gamma_min, gamma_max] (and, with ``adapt_drafter``,
                   the drafter rung) from the acceptance EWMA.
    accept_ewma_alpha / raise_at / lower_at / dwell
                   controller tuning (see :class:`SpecController`).
    """

    gamma: int = 2
    drafter_rung: int = 1
    verifier_rung: int = 0
    adaptive: bool = False
    gamma_min: int = 1
    gamma_max: int = 4
    adapt_drafter: bool = False
    accept_ewma_alpha: float = 0.2
    raise_at: float = 0.8
    lower_at: float = 0.4
    dwell: int = 8

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")
        if self.verifier_rung < 0:
            raise ValueError(
                f"verifier_rung must be >= 0, got {self.verifier_rung}")
        if self.drafter_rung <= self.verifier_rung:
            raise ValueError(
                f"drafter_rung {self.drafter_rung} must be a sparser "
                f"(higher) rung than verifier_rung {self.verifier_rung} — "
                "drafting at the verifier's own cost cannot speed it up")
        if self.adaptive and not \
                1 <= self.gamma_min <= self.gamma <= self.gamma_max:
            raise ValueError(
                f"adaptive spec needs 1 <= gamma_min <= gamma <= gamma_max,"
                f" got ({self.gamma_min}, {self.gamma}, {self.gamma_max})")
        if self.adapt_drafter and not self.adaptive:
            raise ValueError("adapt_drafter needs adaptive=True")

    @property
    def max_gamma(self) -> int:
        """Largest draft length any operating point can use (sizes the
        pool slack and the warmup sweep)."""
        return self.gamma_max if self.adaptive else self.gamma

    def gammas(self):
        """Every draft length warmup must precompile a verify for."""
        if self.adaptive:
            return range(self.gamma_min, self.gamma_max + 1)
        return (self.gamma,)


def make_verify_jit(cfg, on_trace=None):
    """The jitted verify executable with the canonical static-arg and
    donation configuration (policy static, pool caches donated) — the
    single construction site shared by :class:`SpecDecoder` and the
    ``repro.analysis`` jaxpr passes, so the lint lowers exactly what
    serving runs.  ``on_trace`` runs only while XLA is (re)tracing."""
    verify = api.make_verify_step(cfg)

    def _verify(params, tokens, positions, caches, sp, weights, *,
                policy):
        if on_trace is not None:
            on_trace()
        return verify(params, tokens, positions, caches, sp, weights,
                      policy=policy)

    return jax.jit(_verify, static_argnames=("policy",),
                   donate_argnums=(3,))


class SpecDecoder:
    """Per-engine speculative decode driver (created by the engine when
    ``EngineConfig.spec`` is set; one per engine, like the scheduler).

    Owns the jitted verify step, the acceptance EWMA and — in adaptive
    mode — the :class:`SpecController`.  ``step()`` replaces the engine's
    plain batched decode step and may emit up to ``gamma + 1`` tokens per
    decoding request."""

    def __init__(self, engine, scfg: SpecConfig):
        self.engine = engine
        self.scfg = scfg
        self.gamma = scfg.gamma
        self.drafter_rung = scfg.drafter_rung
        self.verifier_rung = scfg.verifier_rung
        self._accept_ewma = None      # non-adaptive mode only; adaptive
        #                               mode's EWMA lives in the controller
        self._verify_traces = 0

        def _on_trace():
            self._verify_traces += 1        # runs only while tracing

        self._vstep = make_verify_jit(engine.cfg, on_trace=_on_trace)
        self.controller = None
        if scfg.adaptive:
            self.controller = SpecController(
                scfg.gamma, scfg.gamma_min, scfg.gamma_max,
                drafter_rung=scfg.drafter_rung,
                drafter_min=scfg.verifier_rung + 1,
                drafter_max=engine.num_rungs - 1,
                adapt_drafter=scfg.adapt_drafter,
                alpha=scfg.accept_ewma_alpha, raise_at=scfg.raise_at,
                lower_at=scfg.lower_at, dwell=scfg.dwell)

    # ------------------------------------------------------------------
    @property
    def accept_ewma(self):
        """Acceptance EWMA: the controller's (reset per switch) in
        adaptive mode, the decoder's lifetime EWMA otherwise — one owner,
        so the JSONL field always reflects the value decisions use."""
        if self.controller is not None:
            return self.controller.accept_ewma
        return self._accept_ewma

    def set_gamma(self, gamma: int) -> None:
        """Pin a draft length (tests / manual tuning).  Must be one the
        warmup precompiled, or the next verify would retrace."""
        if gamma not in self.scfg.gammas():
            raise ValueError(
                f"gamma {gamma} outside the precompiled set "
                f"{list(self.scfg.gammas())}; other values would retrace "
                "the verify executable")
        self.gamma = gamma
        if self.controller is not None:     # else the next round's update
            self.controller.gamma = gamma   # would clobber the pin

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One spec round: gamma batched draft steps at the drafter rung,
        one batched verify at the verifier rung, then per-slot
        accept/commit/rollback."""
        eng = self.engine
        decoding = dict(eng.scheduler.decoding)
        if not decoding:
            return
        g = self.gamma
        S = eng.ecfg.max_slots
        params = eng.params
        _, _, draft_pol = eng._rung_phases[self.drafter_rung]
        draft_sp = eng._rung_sp[self.drafter_rung]
        _, _, ver_pol = eng._rung_phases[self.verifier_rung]
        ver_sp = eng._rung_sp[self.verifier_rung]

        # inactive slots window into the pool's slack region (beyond every
        # reachable real position, like the plain decode scratch slot)
        start = np.full((S,), eng.pool_len - (g + 1), np.int32)
        cur = np.zeros((S,), np.int32)
        active = np.zeros((S,), np.float32)
        for slot, rs in decoding.items():
            start[slot] = rs.position
            cur[slot] = rs.last_token
            active[slot] = 1.0

        # --- draft: g sequential single-token steps, batched over slots --
        # the argmax chain stays on device (each draft feeds the next
        # without a host round-trip); one block per phase keeps the
        # draft/verify latency split honest without per-step syncs
        t0 = eng._now("spec.t0")
        with eng.obs.annotate("repro/spec_draft"):
            act = jnp.asarray(active)
            toks = jnp.asarray(cur)
            draft_cols = []
            for i in range(g):
                logits, eng.pool.caches = eng._dstep(
                    params, toks, jnp.asarray(start + i),
                    eng.pool.caches, draft_sp, act, policy=draft_pol)
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                draft_cols.append(toks)
            drafts_dev = jnp.stack(draft_cols, axis=1)         # (S, g)
            drafts_dev.block_until_ready()
        t1 = eng._now("spec.t1")

        # --- verify: one batched (g+1)-token forward ---------------------
        with eng.obs.annotate("repro/spec_verify"):
            vtokens = jnp.concatenate(
                [jnp.asarray(cur)[:, None], drafts_dev], axis=1)
            weights = np.repeat(active[:, None], g + 1, axis=1)
            logits, eng.pool.caches = self._vstep(
                params, vtokens, jnp.asarray(start),
                eng.pool.caches, ver_sp, jnp.asarray(weights),
                policy=ver_pol)
            ver = np.asarray(jnp.argmax(logits, axis=-1))      # (S, g+1)
            drafts = np.asarray(drafts_dev)
        t2 = eng._now("spec.t2")

        stats = eng.stats
        stats.spec_rounds += 1
        stats.spec_draft_steps += g
        stats.decode_steps += g
        stats.observe_spec_draft(t1 - t0)
        stats.observe_spec_verify(t2 - t1)
        tracer = eng.obs.tracer
        if tracer is not None:
            tracer.complete("spec_draft", t0, t1, gamma=g,
                            drafter_rung=self.drafter_rung,
                            active=len(decoding))
            tracer.complete("spec_verify", t1, t2, gamma=g,
                            verifier_rung=self.verifier_rung)

        # --- accept, then one batched rollback, then emit ----------------
        accept_fracs = []
        commits = {}
        rollbacks = {}
        for slot, rs in decoding.items():
            d, v = drafts[slot], ver[slot]
            n_acc = 0
            while n_acc < g and d[n_acc] == v[n_acc]:
                n_acc += 1
            # accepted drafts + the verifier's bonus token — exactly the
            # verifier's own greedy continuation
            cand = [int(t) for t in d[:n_acc]] + [int(v[n_acc])]
            # the request's budget and EOS truncate the commit so that
            # only the *last* committed token can finish the request
            # (matching plain decode's one-finish-check-per-step)
            m = min(len(cand), rs.request.max_new_tokens - len(rs.tokens))
            eos = rs.request.eos_id
            if eos is not None and eos in cand[:m]:
                m = cand[:m].index(eos) + 1
            # the verify wrote g+1 verifier-faithful positions at
            # [start, start+g]; keep the m committed ones (the last
            # committed token's own KV is written by the *next* round,
            # like plain decode), truncate the rest out of the cache
            eng.pool.commit(slot, g + 1)
            rollbacks[slot] = g + 1 - m
            commits[slot] = (rs, cand[:m], n_acc)
        with eng.obs.annotate("repro/spec_rollback"):
            eng.pool.rollback_many(rollbacks)
        t3 = eng._now("spec.t3")
        # the round's decode cost includes the rollback dispatch — it is
        # real per-round work plain decode doesn't pay
        stats.decode_time += t3 - t0
        if tracer is not None:
            tracer.complete("spec_commit", t2, t3,
                            rollback_tokens=sum(rollbacks.values()))
        events = eng.obs.events

        for slot, (rs, committed, n_acc) in commits.items():
            m = len(committed)
            accept_fracs.append(n_acc / g)
            stats.spec_verifies += 1
            stats.spec_draft_tokens += g
            stats.spec_accepted_tokens += n_acc
            stats.spec_committed_tokens += m
            stats.observe_spec_accepted(n_acc)
            if events is not None and rollbacks[slot] > 0:
                events.emit(
                    "kv_rollback", t=t3, slot=slot,
                    request=rs.request.request_id,
                    tokens=rollbacks[slot], accepted=n_acc,
                    committed=m, gamma=g)
            if rs.last_token_time is not None:
                gap = (t3 - rs.last_token_time) / m   # amortized TPOT
                for _ in range(m):
                    stats.observe_tpot(gap)
            rs.last_token_time = t3
            for tok in committed:
                eng._emit(rs, tok)
            eng._maybe_finish(rs, committed[-1])

        # --- adapt -------------------------------------------------------
        frac = float(np.mean(accept_fracs))
        if self.controller is not None:
            old_g, old_d = self.gamma, self.drafter_rung
            self.gamma, self.drafter_rung = self.controller.update(frac)
            if (self.gamma, self.drafter_rung) != (old_g, old_d):
                reason = self.controller.transitions[-1][3] \
                    if self.controller.transitions else None
                if events is not None:
                    events.emit(
                        "gamma_switch" if self.gamma != old_g
                        else "drafter_switch", t=t3,
                        from_gamma=old_g, to_gamma=self.gamma,
                        from_drafter=old_d, to_drafter=self.drafter_rung,
                        reason=reason,
                        accept_ewma=self.controller.accept_ewma)
                if tracer is not None:
                    tracer.instant(
                        "spec_switch", t=t3, gamma=self.gamma,
                        drafter_rung=self.drafter_rung, reason=reason)
                fr = eng.obs.flight
                if fr is not None:
                    fr.decision(
                        "gamma_switch" if self.gamma != old_g
                        else "drafter_switch",
                        from_gamma=old_g, to_gamma=self.gamma,
                        from_drafter=old_d, to_drafter=self.drafter_rung,
                        reason=reason)
        else:
            a = self.scfg.accept_ewma_alpha
            self._accept_ewma = frac if self._accept_ewma is None else \
                (1 - a) * self._accept_ewma + a * frac

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Spec state for the engine's JSONL snapshot record."""
        ewma = self.accept_ewma
        out = {
            "spec_gamma": self.gamma,
            "spec_drafter_rung": self.drafter_rung,
            "spec_accept_ewma": None if ewma is None else round(ewma, 4),
        }
        if self.controller is not None:
            out["spec_switches"] = len(self.controller.transitions)
        return out
