from repro.data.synthetic import DataConfig, SyntheticLM, eval_batch

__all__ = ["DataConfig", "SyntheticLM", "eval_batch"]
