"""Synthetic data pipeline.

Offline container => no Pile/CodeAlpaca/MetaMathQA; instead a structured
synthetic language over a configurable vocab that a small LM can actually
learn (so WiSparse calibration/eval on the trained model is meaningful):

  * Zipfian unigram base distribution,
  * first-order Markov "grammar" (sparse row-stochastic transitions),
  * periodic copy motifs (algorithmic structure -> non-trivial attention).

The stream is deterministic in (seed, host_id, num_hosts, step): each host
draws a disjoint slice of the global batch (straggler-deterministic, no
coordination needed) and any step can be regenerated exactly — together
with checkpointing this makes training restart bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    branch: int = 8               # Markov out-degree per state
    motif_len: int = 16           # copied motif length
    motif_period: int = 64        # every k tokens, repeat a recent span


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Zipf weights over the vocab
        w = 1.0 / np.arange(1, V + 1) ** cfg.zipf_a
        self.unigram = w / w.sum()
        # sparse Markov transitions: each token -> `branch` successors
        self.succ = rng.integers(0, V, size=(V, cfg.branch))
        self.succ_p = rng.dirichlet(np.ones(cfg.branch), size=V)

    def sample_sequence(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len, np.int32)
        tok = rng.choice(len(self.unigram), p=self.unigram)
        t = 0
        while t < cfg.seq_len:
            if t and t % cfg.motif_period == 0 and t >= cfg.motif_len:
                # algorithmic structure: copy a recent motif verbatim
                span = out[t - cfg.motif_len:t]
                n = min(cfg.motif_len, cfg.seq_len - t)
                out[t:t + n] = span[:n]
                t += n
                tok = int(out[t - 1])
                continue
            j = rng.choice(cfg.branch, p=self.succ_p[tok])
            tok = int(self.succ[tok, j])
            out[t] = tok
            t += 1
        return out

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1
              ) -> np.ndarray:
        """Deterministic (step, host) -> (local_batch, seq_len) int32."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local = cfg.global_batch // num_hosts
        rows = []
        for i in range(local):
            global_row = host_id * local + i
            rng = np.random.default_rng(
                (cfg.seed, step, global_row))
            rows.append(self.sample_sequence(rng))
        return np.stack(rows)

    def iterator(self, start_step: int = 0, host_id: int = 0,
                 num_hosts: int = 1) -> Iterator[np.ndarray]:
        step = start_step
        while True:
            yield self.batch(step, host_id, num_hosts)
            step += 1


def eval_batch(cfg: DataConfig, n: int = 4, step_offset: int = 10_000_000):
    """Held-out batch: same language (same Markov tables), sequence seeds
    disjoint from any reachable training step."""
    ds = SyntheticLM(dataclasses.replace(cfg, global_batch=n))
    return ds.batch(step_offset)
