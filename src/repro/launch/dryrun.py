import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with abstract inputs (ShapeDtypeStruct, no allocation), prove it
fits (memory_analysis) and extract the roofline terms (cost_analysis +
optimized-HLO collective bytes).

    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_67b \
        --shape decode_32k --mesh single --sparsity 0.5
"""
import argparse
import functools
import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, runnable_cells
from repro.obs.clock import now
from repro.core import sp_schema
from repro.sparsity import SparsityPolicy
from repro.distributed.sharding import (LOGICAL_RULES_SERVE,
                                        LOGICAL_RULES_TRAIN, param_shardings,
                                        sharding_context)
from repro.launch import hlo_analysis, roofline as R
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.params import logical_axes as schema_axes
from repro.optim import adamw


def _shardings_for(axes_tree, abstract_tree, ctx):
    return param_shardings(axes_tree, abstract_tree, ctx)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                sparsity: float = 0.0, remat: str = "dots",
                overrides=None, verbose: bool = True,
                save_hlo: str = None, aligned: bool = True,
                donate_cache: bool = True):
    """Lower+compile one cell.  Returns a result record (dict)."""
    t0 = now()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    rules = LOGICAL_RULES_TRAIN if shape.mode == "train" else LOGICAL_RULES_SERVE
    sparse = sparsity > 0.0 and shape.mode != "train"

    with sharding_context(mesh, rules, overrides) as ctx:
        abstract, axes, schema = api.abstract_model(cfg)
        p_sh = _shardings_for(axes, abstract, ctx)
        in_specs = api.input_specs(cfg, shape)
        in_axes = api.input_axes(cfg, shape)
        b_sh = _shardings_for(in_axes, in_specs, ctx)
        policy = SparsityPolicy.uniform(
            "topk_shared", k_max_frac=max(1.0 - sparsity, 1e-6)) \
            if sparse else SparsityPolicy.dense()
        step, kind = api.step_for_shape(
            cfg, shape, remat=remat, policy=policy,
            aligned=aligned and shape.mode == "decode")

        args, shardings, donate = [abstract], [p_sh], ()
        if shape.mode == "train":
            opt_abs = jax.eval_shape(
                functools.partial(adamw.init, cfg=adamw.AdamWConfig()), abstract)
            opt_axes = {"m": axes, "v": axes, "master": axes, "step": ()}
            o_sh = _shardings_for(opt_axes, opt_abs, ctx)
            args += [opt_abs, in_specs]
            shardings += [o_sh, b_sh]
            donate = (0, 1)
        else:
            args += [in_specs]
            shardings += [b_sh]
            if shape.mode == "decode" and donate_cache:
                donate = (1,)          # in-place KV-cache update

        if sparse:
            sp_abs, sp_axes = sp_schema.abstract_sp(cfg)
            sp_sh = _shardings_for(sp_axes, sp_abs, ctx)
            args += [sp_abs]
            shardings += [sp_sh]

        jitted = jax.jit(step, in_shardings=tuple(shardings),
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    xla_flops, xla_bytes = R.executable_costs(compiled)
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    # trip-count-aware analysis (XLA's cost_analysis visits loop bodies once)
    ana = hlo_analysis.analyze(hlo)
    coll = ana["collectives"]
    chips = int(np.prod(mesh.devices.shape))
    rl = R.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(ana["flops"]),
        hlo_bytes=float(ana["bytes"]),
        coll_bytes=R.wire_bytes(coll),
        model_flops_total=R.model_flops(cfg, shape),
    )
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "chips": chips,
        "sparsity": sparsity if sparse else 0.0,
        "remat": remat if shape.mode == "train" else None,
        "overrides": {k: list(map(list, v)) for k, v in (overrides or {}).items()},
        "status": "ok",
        "compile_s": round(now() - t0, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            # per-device peak from XLA buffer assignment (includes arguments)
            "peak_bytes_estimate": int(getattr(mem, "peak_memory_in_bytes", 0)),
        },
        "cost": {"flops_per_device": rl.hlo_flops,
                 "bytes_per_device": rl.hlo_bytes,
                 # XLA's own numbers (loop bodies counted once) for x-check
                 "xla_flops": xla_flops,
                 "xla_bytes": xla_bytes},
        "collectives": coll,
        "roofline": rl.row(),
    }
    if verbose:
        mb = rec["memory"]["peak_bytes_estimate"] / 2**30
        print(f"[{arch} x {shape_name} x {mesh_name}"
              f"{' sparse@%.2f' % sparsity if sparse else ''}] "
              f"compile={rec['compile_s']}s peak={mb:.2f}GiB/chip "
              f"compute={rl.compute_s*1e3:.2f}ms memory={rl.memory_s*1e3:.2f}ms "
              f"coll={rl.collective_s*1e3:.2f}ms -> {rl.bottleneck} "
              f"(useful={rl.useful_flops_ratio:.2f} mfu={rl.mfu:.3f})",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--no-aligned", dest="aligned", action="store_false",
                    help="per-sequence decode positions (scatter cache path)")
    ap.add_argument("--no-donate", dest="donate", action="store_false")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    if args.all:
        cells, skips = runnable_cells()
        for arch, shp, why in skips:
            print(f"SKIP {arch} x {shp}: {why}", flush=True)
    else:
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("sparsity", 0.0)))
                except Exception:
                    pass

    failures = 0
    for arch, shp in cells:
        for mp in meshes:
            mname = "multi" if mp else "single"
            key = (arch, shp, mname, args.sparsity
                   if SHAPES[shp].mode != "train" else 0.0)
            if key in done:
                print(f"skip (done): {key}", flush=True)
                continue
            try:
                rec = dryrun_cell(arch, shp, multi_pod=mp,
                                  sparsity=args.sparsity, remat=args.remat,
                                  save_hlo=args.save_hlo,
                                  aligned=args.aligned,
                                  donate_cache=args.donate)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shp, "mesh": mname,
                       "sparsity": args.sparsity, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[{arch} x {shp} x {mname}] FAILED: {e}", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
