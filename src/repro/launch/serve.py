"""Serving CLI: a thin driver over the continuous-batching engine
(``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve --arch llama31_8b --reduced \
        --sparsity 0.5 --prompt-len 64 --gen 32 --batch 4

Implements the paper's serving recipe: sparsify (by default) only half of
the prefill tokens and all decode tokens (§5.1), with the per-token mask
backend for accuracy-faithful numerics or the batched top-k backends for
TPU-shaped execution.  Greedy decoding over the slot-pool KV-cache path;
``--legacy`` runs the original static-batch loop (kept as the numerics
reference — the engine matches it token-for-token for equal-length
prompts under the whole-prompt prefill strategy).

Adaptive serving: ``--ladder plan.npz`` loads a calibrated
``PolicyLadder`` artifact (see ``repro.sparsity.calibrate_ladder`` /
``examples/calibrate_and_serve.py``) and ``--slo-tpot-p95`` arms the
feedback controller that moves between rungs under load; ``--rung`` pins
one rung instead.  ``--metrics-out`` appends JSONL engine/controller
snapshots while the engine runs.

Speculative decoding: ``--spec-gamma N`` (with ``--ladder``) drafts N
tokens per verify at the ``--spec-drafter`` rung and verifies at the
pinned ``--rung`` — token-identical output to plain decode at that rung,
fewer verifier passes per token.  The verifier rung must decode dense
(rung 0 of a calibrated ladder); the engine rejects sparse verifiers,
whose shared top-k saliency would break the parity guarantee.
``--spec-adaptive`` lets the acceptance EWMA tune gamma at runtime.

Prefix caching: ``--prefix-cache`` arms radix-tree KV reuse across
requests sharing a prompt prefix (``repro.serving.prefix_cache``) —
admissions copy the matched prefix into their slot and prefill only the
un-cached suffix.  ``--prefix-cache-tokens N`` bounds the cached tokens
(LRU eviction; 0 = unbounded).  Requires chunked prefill and a
prefix-deterministic prefill policy (dense or ``mask``) — the engine
validates and the hit path stays token-identical to cold prefill.

Gateway: ``--gateway`` serves the asyncio HTTP/1.1 + SSE front door
(``repro.serving.gateway``) on ``--gateway-host``/``--gateway-port``
instead of replaying synthetic prompts — ``POST /v1/generate``
(streaming and non-streaming), ``GET /v1/health``, ``GET /metrics``.
``--max-queue`` bounds the admission queue (rejects surface as HTTP 429
with ``Retry-After``) and ``--preemption`` lets a more important
arrival suspend the least-important decoding request to host memory,
resuming it bit-identically once a slot frees up.  SIGTERM/Ctrl-C
stops accepting connections and drains in-flight requests.

Observability (``repro.obs``): ``--metrics-out`` appends JSONL
snapshots by default; ``--metrics-format prom`` instead rewrites the
file with a Prometheus text-exposition dump (textfile-collector style),
and ``--metrics-port`` serves the same text live at
``http://127.0.0.1:PORT/metrics``.  ``--trace-out`` writes a Chrome
trace-event JSON of per-request spans (load it in Perfetto or
``chrome://tracing``), ``--events-out`` streams the structured event
log (rung/gamma switches with reasons, prefix evictions, KV rollbacks,
compile records) as JSONL, and ``--profile-dir`` captures a JAX
profiler trace of the whole run.  Tokens are bit-identical with
telemetry on or off.

Quality monitoring: ``--quality-probe-rate R`` (R in (0, 1]) arms the
:class:`repro.obs.QualityMonitor` — it samples that fraction of decode
steps through a shadow dense probe (token agreement + top-k logit
overlap vs the dense reference), measures online block reconstruction
error against calibration baselines, watches saliency drift per
(block, rung) and exports per-rung roofline counters.  Probes never
alter served tokens.  ``--quality-drift-threshold`` tunes the EWMA
saliency-overlap level below which a ``saliency_drift`` event fires.

Flight recorder (``repro.obs.flight``): ``--flight-record`` captures
every nondeterministic engine input (request submissions + clock
observations) and resulting decision into a bounded in-memory ring —
black-box mode, dumped on trigger (engine exception, SLO-breach
escalation, saliency-drift edge, SIGUSR1, or the gateway's
``GET /v1/debug/flight``) into ``--flight-dump-dir``.  Give
``--flight-record PATH`` to also stream the complete recording as JSONL
to PATH; that file replays bit-identically via
``python -m repro.obs.flight.replay PATH``.  ``--flight-ring`` sizes
the ring.
"""
from __future__ import annotations

import argparse
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config, reduced
from repro.core import pipeline as wis_pipeline
from repro.data import DataConfig, SyntheticLM
from repro.models import api, model as M
from repro.sparsity import PolicyLadder, SparsityPolicy


def _pad_caches(cfg, caches, batch, total_len):
    import repro.models.params as P
    schema = api.cache_schema(cfg, batch, total_len)
    target = P.abstract_params(schema, cfg.dtype)

    def fit(src, dst):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for s, d in zip(src.shape, dst.shape)]
        return jnp.pad(src, pads).astype(dst.dtype)

    return jax.tree_util.tree_map(fit, caches, target)


def generate(params, cfg, prompts, gen_tokens: int, sp_stacked=None, *,
             prefill_sparse_frac: float = 0.5, policy=None):
    """prompts: (B, P) int32.  Returns (B, gen_tokens) greedy tokens.

    ``policy``: the SparsityPolicy for the sparse phases (None = the
    paper-exact ``mask`` backend, which is dense-equivalent without
    calibrated thresholds in ``sp_stacked``)."""
    if policy is None:
        policy = SparsityPolicy.uniform("mask")
    B, P = prompts.shape
    total = P + gen_tokens

    # paper §5.1: sparsify only half the prefill tokens -> run the first
    # half dense, the second half sparse (per-token thresholds make this a
    # pure mask toggle; we approximate by prefilling dense, which is the
    # conservative accuracy choice, when no split point is given)
    prefill_sparse = prefill_sparse_frac >= 1.0
    logits, caches = M.forward(
        params, cfg, tokens=prompts, mode="prefill",
        sp=sp_stacked if prefill_sparse else None,
        policy=policy.for_phase(
            "prefill_sparse" if prefill_sparse else "prefill_dense"))
    caches = _pad_caches(cfg, caches, B, total)

    decode_policy = policy.for_phase("decode")
    decode = jax.jit(lambda p, b, sp: M.forward(
        p, cfg, tokens=b["tokens"], mode="decode", caches=b["caches"],
        positions=b["positions"], sp=sp, policy=decode_policy))

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [toks]
    for i in range(gen_tokens - 1):
        positions = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode(
            params, {"tokens": toks, "caches": caches,
                     "positions": positions}, sp_stacked)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(toks)
    return jnp.stack(out, axis=1)


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI parser — exposed (with :func:`validate_args`) so
    tests can drive flag validation without spawning a process."""
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="llama31_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--mode", default="mask",
                    choices=["mask", "topk_shared", "topk_block", "pallas"])
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--calib-quick", action="store_true",
                    help="tiny-budget WiSparse calibration (CPU demo)")
    ap.add_argument("--legacy", action="store_true",
                    help="static-batch reference loop instead of the engine")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="KV pool slots (0 = batch size)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="KV pool length (0 = prompt+gen)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (chunked strategy)")
    ap.add_argument("--prefill-strategy", default="auto",
                    choices=["auto", "chunked", "whole"])
    ap.add_argument("--sensitive-backend", default=None,
                    choices=["off", "mask"],
                    help="mixed per-block policy: run this backend on the "
                         "most sensitive blocks of a calibrated plan "
                         "(requires --calib-quick)")
    ap.add_argument("--sensitive-frac", type=float, default=0.25,
                    help="fraction of blocks treated as sensitive")
    ap.add_argument("--ladder", default=None,
                    help="PolicyLadder npz artifact for adaptive serving "
                         "(overrides --sparsity/--mode)")
    ap.add_argument("--rung", type=int, default=0,
                    help="ladder rung to start on (and to pin, without "
                         "--slo-tpot-p95)")
    ap.add_argument("--slo-tpot-p95", type=float, default=0.0,
                    help="target p95 inter-token latency in seconds; > 0 "
                         "arms the adaptive controller (needs --ladder)")
    ap.add_argument("--slo-max-queue", type=int, default=8,
                    help="queued requests beyond which the controller "
                         "escalates")
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="speculative decoding: draft tokens per verify "
                         "(> 0 arms spec decode; needs --ladder)")
    ap.add_argument("--spec-drafter", type=int, default=1,
                    help="ladder rung that drafts (must be sparser than "
                         "the verifier rung pinned by --rung)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="tune gamma from the acceptance EWMA at runtime")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse KV across requests sharing a prompt "
                         "prefix (radix tree over token ids; needs "
                         "chunked prefill + dense/mask prefill policy)")
    ap.add_argument("--prefix-cache-tokens", type=int, default=0,
                    help="cached-token budget for --prefix-cache "
                         "(LRU eviction; 0 = unbounded)")
    ap.add_argument("--metrics-out", default=None,
                    help="write engine/controller metrics to this file "
                         "while serving (format per --metrics-format)")
    ap.add_argument("--metrics-every", type=int, default=16,
                    help="engine steps between metrics writes")
    ap.add_argument("--metrics-format", default="jsonl",
                    choices=["jsonl", "prom"],
                    help="--metrics-out format: append JSONL snapshots, "
                         "or rewrite a Prometheus text-exposition dump "
                         "(textfile-collector style)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve live Prometheus exposition at "
                         "http://127.0.0.1:PORT/metrics (0 = off)")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request spans as Chrome trace-event "
                         "JSON (Perfetto-loadable) to this file")
    ap.add_argument("--events-out", default=None,
                    help="stream the structured event log (rung/gamma "
                         "switches, evictions, rollbacks, compiles) as "
                         "JSONL to this file")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of the run into "
                         "this directory")
    ap.add_argument("--quality-probe-rate", type=float, default=0.0,
                    help="sample this fraction of decode steps through a "
                         "shadow dense probe (token agreement, recon "
                         "error, saliency drift, roofline counters; "
                         "0 = off)")
    ap.add_argument("--quality-drift-threshold", type=float, default=None,
                    help="EWMA saliency-overlap level below which a "
                         "saliency_drift event fires, in (0, 1) (needs "
                         "--quality-probe-rate > 0; default 0.5)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve the HTTP/1.1 + SSE API front door "
                         "(repro.serving.gateway) instead of replaying "
                         "synthetic prompts; SIGTERM/Ctrl-C drains "
                         "in-flight requests before exiting")
    ap.add_argument("--gateway-host", default="127.0.0.1",
                    help="gateway listen address (needs --gateway)")
    ap.add_argument("--gateway-port", type=int, default=8080,
                    help="gateway listen port (0 = ephemeral; needs "
                         "--gateway)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: reject new submissions "
                         "(HTTP 429 + Retry-After through the gateway) "
                         "beyond this many queued requests (0 = unbounded)")
    ap.add_argument("--preemption", action="store_true",
                    help="suspend the least-important decoding request to "
                         "host memory when a more important arrival needs "
                         "its KV slot; the victim resumes bit-identically")
    ap.add_argument("--flight-record", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="arm the flight recorder (repro.obs.flight): "
                         "bare = black-box ring only; with PATH, also "
                         "stream the complete recording as JSONL to PATH "
                         "(replayable via python -m repro.obs.flight.replay)")
    ap.add_argument("--flight-ring", type=int, default=4096,
                    help="flight-recorder ring capacity in records "
                         "(needs --flight-record)")
    ap.add_argument("--flight-dump-dir", default=None,
                    help="directory for triggered black-box dumps "
                         "(exception / SLO breach / saliency drift / "
                         "SIGUSR1 / GET /v1/debug/flight; needs "
                         "--flight-record)")
    return ap


def validate_args(args) -> None:
    """Fail fast on bad flag combinations, before any model work.

    Every check here is driven purely by the parsed namespace; rung
    range checks need the loaded ladder and live in
    :func:`validate_rungs`.  Raises ``SystemExit`` with a message that
    names the offending flag and what to change."""
    if not 0.0 <= args.sparsity <= 1.0:
        raise SystemExit(f"--sparsity must be in [0, 1], got {args.sparsity}")
    for name in ("prompt-len", "gen", "batch", "chunk"):
        v = getattr(args, name.replace("-", "_"))
        if v <= 0:
            raise SystemExit(f"--{name} must be > 0, got {v}")
    if args.rung < 0:
        raise SystemExit(f"--rung must be >= 0, got {args.rung}")
    if args.max_queue < 0:
        raise SystemExit(f"--max-queue must be >= 0, got {args.max_queue}")
    if args.sensitive_backend is not None and not args.calib_quick:
        raise SystemExit("--sensitive-backend needs a calibrated plan: "
                         "add --calib-quick")
    if args.slo_tpot_p95 > 0 and args.ladder is None:
        raise SystemExit("--slo-tpot-p95 needs --ladder: the controller "
                         "switches between ladder rungs")
    if args.rung != 0 and args.ladder is None:
        raise SystemExit("--rung needs --ladder: a fixed-policy engine "
                         "has only rung 0")
    if args.spec_gamma > 0:
        if args.ladder is None:
            raise SystemExit("--spec-gamma needs --ladder: the drafter "
                             "and verifier are ladder rungs")
        if args.slo_tpot_p95 > 0:
            raise SystemExit("--spec-gamma conflicts with --slo-tpot-p95: "
                             "spec decoding pins the verifier rung")
    elif args.spec_adaptive or args.spec_drafter != 1:
        raise SystemExit("--spec-drafter/--spec-adaptive need "
                         "--spec-gamma > 0 to arm speculative decoding")
    if args.prefix_cache and args.legacy:
        raise SystemExit("--prefix-cache needs the engine path, not "
                         "--legacy")
    if args.legacy and (args.trace_out or args.events_out
                        or args.metrics_port or args.metrics_out):
        raise SystemExit("telemetry flags (--trace-out/--events-out/"
                         "--metrics-*) need the engine path, not --legacy")
    if args.prefix_cache_tokens and not args.prefix_cache:
        raise SystemExit("--prefix-cache-tokens needs --prefix-cache to "
                         "arm the prefix cache")
    if args.quality_probe_rate < 0 or args.quality_probe_rate > 1:
        raise SystemExit(
            f"--quality-probe-rate must be in (0, 1], or 0 to disable "
            f"probing, got {args.quality_probe_rate}")
    if args.quality_probe_rate > 0 and args.legacy:
        raise SystemExit("--quality-probe-rate needs the engine path, "
                         "not --legacy")
    if args.quality_drift_threshold is not None:
        if args.quality_probe_rate <= 0:
            raise SystemExit("--quality-drift-threshold needs "
                             "--quality-probe-rate > 0 to arm the "
                             "quality monitor")
        if not 0.0 < args.quality_drift_threshold < 1.0:
            raise SystemExit(
                f"--quality-drift-threshold must be in (0, 1), got "
                f"{args.quality_drift_threshold}")
    if args.gateway:
        if args.legacy:
            raise SystemExit("--gateway needs the engine path, not "
                             "--legacy")
        if args.metrics_out:
            raise SystemExit("--gateway owns the engine loop; drop "
                             "--metrics-out and scrape GET /metrics "
                             "instead")
        if args.metrics_port:
            raise SystemExit("--gateway already serves /metrics on its "
                             "own port; drop --metrics-port")
        if args.gateway_port < 0:
            raise SystemExit(f"--gateway-port must be >= 0 "
                             f"(0 = ephemeral), got {args.gateway_port}")
    elif (args.gateway_host != "127.0.0.1" or args.gateway_port != 8080):
        raise SystemExit("--gateway-host/--gateway-port need --gateway "
                         "to start the API front door")
    if (args.max_queue or args.preemption) and args.legacy:
        raise SystemExit("--max-queue/--preemption need the engine path, "
                         "not --legacy")
    if args.flight_ring <= 0:
        raise SystemExit(f"--flight-ring must be > 0, got "
                         f"{args.flight_ring}")
    if args.flight_record is not None and args.legacy:
        raise SystemExit("--flight-record needs the engine path, not "
                         "--legacy: the recorder captures the engine's "
                         "submission and clock streams")
    if args.flight_record is None:
        if args.flight_ring != 4096:
            raise SystemExit("--flight-ring needs --flight-record to arm "
                             "the flight recorder")
        if args.flight_dump_dir is not None:
            raise SystemExit("--flight-dump-dir needs --flight-record to "
                             "arm the flight recorder")
    if args.flight_dump_dir is not None:
        d = args.flight_dump_dir
        if os.path.exists(d):
            if not os.path.isdir(d):
                raise SystemExit(f"--flight-dump-dir {d!r} exists and is "
                                 "not a directory")
            if not os.access(d, os.W_OK):
                raise SystemExit(f"--flight-dump-dir {d!r} is not "
                                 "writable")


def validate_rungs(args, num_rungs: int) -> None:
    """Range-check rung-valued flags against the loaded ladder."""
    if not 0 <= args.rung < num_rungs:
        raise SystemExit(
            f"--rung {args.rung} out of range: the loaded ladder has "
            f"rungs 0..{num_rungs - 1}")
    if args.spec_gamma > 0 and not 0 <= args.spec_drafter < num_rungs:
        raise SystemExit(
            f"--spec-drafter {args.spec_drafter} out of range: the "
            f"loaded ladder has rungs 0..{num_rungs - 1}")


def main():
    args = build_parser().parse_args()
    validate_args(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = api.init_model(cfg, 0)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, args.prompt_len, args.batch))
    prompts = jnp.asarray(ds.batch(0))

    ladder = None
    if args.ladder is not None:
        ladder = PolicyLadder.load(args.ladder)
        print(f"loaded {len(ladder)}-rung ladder "
              f"(budgets {list(ladder.budgets)}) from {args.ladder}")
        validate_rungs(args, len(ladder))

    sp, policy = None, SparsityPolicy.dense()
    if ladder is None and args.sparsity > 0:
        if args.calib_quick:
            from repro.core.allocation import EvoConfig
            plan = wis_pipeline.run_pipeline(
                params, cfg, {"tokens": prompts}, args.sparsity,
                evo=EvoConfig(generations=2, offspring=4, eps=0.1),
                delta=0.25, coord_passes=0, log=print)
            sp = plan.stacked_sp
            policy = plan.to_policy(
                backend=args.mode, sensitive_backend=args.sensitive_backend,
                sensitive_frac=args.sensitive_frac)
        else:
            from repro.core.sp_schema import default_sp_stacked
            sp = default_sp_stacked(params, cfg,
                                    keep_frac=1.0 - args.sparsity)
            if args.mode == "mask":
                # mask mode needs calibrated thresholds (Eq. 7); without
                # calibration fall back to the budgeted top-k backend
                print("no calibration -> using topk_shared backend")
                args.mode = "topk_shared"
            # k_max_frac must be > 0; at 100% sparsity keep the top-k
            # backends' one-channel floor (matching the legacy mode path)
            policy = SparsityPolicy.uniform(
                args.mode, k_max_frac=max(1.0 - args.sparsity, 1e-6))

    if args.legacy:
        t0 = obs.now()
        toks = generate(params, cfg, prompts, args.gen, sp, policy=policy)
        dt = obs.now() - t0
        n = toks.size
        print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s on CPU)")
        print("sample:", np.asarray(toks[0])[:16])
        return

    from repro.serving import (Engine, EngineConfig, SchedulerConfig,
                               SLOConfig, SpecConfig)
    from repro.serving.metrics import latency_percentiles
    slo = None
    if args.slo_tpot_p95 > 0:
        slo = SLOConfig(tpot_p95=args.slo_tpot_p95,
                        max_queue=args.slo_max_queue)
    spec = None
    if args.spec_gamma > 0:
        spec = SpecConfig(gamma=args.spec_gamma,
                          drafter_rung=args.spec_drafter,
                          verifier_rung=args.rung,
                          adaptive=args.spec_adaptive,
                          gamma_max=max(4, args.spec_gamma))
    scheduler = None
    if args.max_queue or args.preemption:
        scheduler = SchedulerConfig(max_queue=args.max_queue,
                                    preemption=args.preemption)
    ecfg = EngineConfig(
        max_slots=args.max_slots or args.batch,
        max_len=args.max_len or args.prompt_len + args.gen,
        prefill_chunk=args.chunk,
        policy=None if ladder is not None else policy,
        prefill_strategy=args.prefill_strategy,
        slo=slo, initial_rung=args.rung, spec=spec,
        prefix_cache=args.prefix_cache,
        prefix_cache_tokens=args.prefix_cache_tokens,
        scheduler=scheduler)
    telemetry = None
    flight = None
    if args.flight_record is not None:
        flight = obs.FlightRecorder(
            capacity=args.flight_ring,
            sink=args.flight_record or None,
            dump_dir=args.flight_dump_dir,
            meta={"arch": args.arch, "reduced": args.reduced, "seed": 0,
                  "ladder_path": args.ladder})
    if (args.trace_out or args.events_out or args.profile_dir
            or args.quality_probe_rate > 0 or flight is not None):
        quality = None
        if args.quality_probe_rate > 0:
            qkw = dict(probe_rate=args.quality_probe_rate)
            if args.quality_drift_threshold is not None:
                qkw["drift_threshold"] = args.quality_drift_threshold
            quality = obs.QualityMonitor(obs.QualityConfig(**qkw))
        # trace_sink makes Engine.close() (context-manager exit) export
        # the Chrome trace even when the serving loop raises
        telemetry = obs.Telemetry(
            tracer=obs.SpanTracer() if args.trace_out else None,
            events=obs.EventLog(sink=args.events_out)
            if args.events_out else None,
            annotate_dispatch=args.profile_dir is not None,
            profiler=obs.ProfilerSession(args.profile_dir)
            if args.profile_dir else None,
            quality=quality,
            flight=flight,
            trace_sink=args.trace_out)
    engine = Engine(params, cfg, ecfg, sp, ladder=ladder,
                    telemetry=telemetry)
    if flight is not None and hasattr(signal, "SIGUSR1"):
        # operator-triggered black-box dump: kill -USR1 <pid>
        signal.signal(signal.SIGUSR1, lambda *_: flight.dump("sigusr1"))

    if args.gateway:
        from repro.serving.gateway import Gateway
        if (telemetry is not None and telemetry.profiler is not None
                and not telemetry.profiler.start()):
            print("profiler capture unavailable:",
                  telemetry.profiler.error)
        gw = Gateway(engine, host=args.gateway_host,
                     port=args.gateway_port)
        print(f"gateway starting on http://{args.gateway_host}:"
              f"{args.gateway_port or '<ephemeral>'} "
              f"(POST /v1/generate, GET /v1/health, GET /metrics); "
              f"SIGTERM/Ctrl-C drains")
        gw.serve_forever()
        print("gateway drained; engine stats:", engine.stats.summary())
        _report_telemetry(args, telemetry)
        return

    server = None
    if args.metrics_port:
        server = obs.serve_metrics(engine.metrics_exposition,
                                   port=args.metrics_port)
        print(f"serving metrics at "
              f"http://127.0.0.1:{server.server_port}/metrics")
    if (telemetry is not None and telemetry.profiler is not None
            and not telemetry.profiler.start()):
        print("profiler capture unavailable:",
              telemetry.profiler.error)
    t0 = obs.now()
    for b in range(args.batch):
        engine.submit(np.asarray(prompts[b]), args.gen)
    try:
        # the context manager closes the engine (and flushes every
        # telemetry sink) even when the loop raises
        with engine:
            out = run_with_metrics(engine, args.metrics_out,
                                   args.metrics_every, args.metrics_format)
    finally:
        if server is not None:
            server.shutdown()
        _report_telemetry(args, telemetry)
    dt = obs.now() - t0
    n = sum(len(t) for t in out.values())
    print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s on CPU)")
    print("engine stats:", engine.stats.summary())
    print("latency:", {k: round(v, 3) for k, v in
                       latency_percentiles(engine.states.values()).items()
                       if v is not None})
    if engine.controller is not None:
        print("controller:", engine.controller.snapshot())
        print("decode retraces after warmup:",
              engine.decode_retraces_after_warmup)
    if engine.spec_decoder is not None:
        print("spec:", engine.spec_decoder.snapshot())
        print("retraces after warmup: decode",
              engine.decode_retraces_after_warmup, "verify",
              engine.verify_retraces_after_warmup)
    if engine.prefix_cache is not None:
        print("prefix cache:", engine.prefix_cache.snapshot())
    print("sample:", out[0][:16])


def _report_telemetry(args, telemetry) -> None:
    """Say what ``Engine.close()`` flushed (the export itself already
    happened inside close — this only reports)."""
    if telemetry is None:
        return
    if telemetry.tracer is not None:
        print(f"wrote {len(telemetry.tracer.events)} trace events "
              f"to {args.trace_out}")
    if telemetry.events is not None:
        print(f"logged {telemetry.events.count} events"
              + (f" to {args.events_out}" if args.events_out else ""))
    if telemetry.profiler is not None and telemetry.profiler.error is None:
        print(f"wrote profiler trace to {args.profile_dir}")
    if telemetry.quality is not None and telemetry.quality.armed:
        q = telemetry.quality
        print(f"quality: {q.probes} probes ({q.probe_tokens} tokens), "
              f"{q.recon_passes} recon passes, {q.drift_events} drift "
              f"events, pressure {q.pressure:.3f}")
    if telemetry.flight is not None:
        fr = telemetry.flight
        print(f"flight: {fr.count} records ({fr.dropped} dropped from "
              f"the ring), {len(fr.dumps)} dumps"
              + (f", recording at {args.flight_record}"
                 if args.flight_record else ""))


def run_with_metrics(engine, metrics_out=None, every: int = 16,
                     fmt: str = "jsonl"):
    """Drive the engine to completion, writing metrics every ``every``
    steps (and once at the end) when ``metrics_out`` is set.

    ``fmt="jsonl"`` appends engine snapshots; ``fmt="prom"`` rewrites
    the file with the current Prometheus text exposition each time —
    the node-exporter textfile-collector pattern, scrapeable without a
    port."""
    if metrics_out is None:
        return engine.run()
    if fmt not in ("jsonl", "prom"):
        raise ValueError(f"unknown metrics format {fmt!r}")

    if fmt == "prom":
        def write(_f=None):
            with open(metrics_out, "w") as f:
                f.write(engine.metrics_exposition())
        steps = 0
        while engine.scheduler.has_work():
            engine.step()
            steps += 1
            if steps % every == 0:
                write()
        write()
        return {rid: rs.tokens for rid, rs in engine.states.items()}

    steps = 0
    with open(metrics_out, "a") as f:
        while engine.scheduler.has_work():
            engine.step()
            steps += 1
            if steps % every == 0:
                f.write(json.dumps(engine.snapshot()) + "\n")
        f.write(json.dumps(engine.snapshot()) + "\n")
    return {rid: rs.tokens for rid, rs in engine.states.items()}


if __name__ == "__main__":
    main()
