"""Serving CLI: a thin driver over the continuous-batching engine
(``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve --arch llama31_8b --reduced \
        --sparsity 0.5 --prompt-len 64 --gen 32 --batch 4

Implements the paper's serving recipe: sparsify (by default) only half of
the prefill tokens and all decode tokens (§5.1), with the per-token mask
backend for accuracy-faithful numerics or the batched top-k backends for
TPU-shaped execution.  Greedy decoding over the slot-pool KV-cache path;
``--legacy`` runs the original static-batch loop (kept as the numerics
reference — the engine matches it token-for-token for equal-length
prompts under the whole-prompt prefill strategy).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import pipeline as wis_pipeline
from repro.data import DataConfig, SyntheticLM
from repro.models import api, model as M
from repro.sparsity import SparsityPolicy


def _pad_caches(cfg, caches, batch, total_len):
    import repro.models.params as P
    schema = api.cache_schema(cfg, batch, total_len)
    target = P.abstract_params(schema, cfg.dtype)

    def fit(src, dst):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for s, d in zip(src.shape, dst.shape)]
        return jnp.pad(src, pads).astype(dst.dtype)

    return jax.tree_util.tree_map(fit, caches, target)


def generate(params, cfg, prompts, gen_tokens: int, sp_stacked=None,
             mode: str = None, k_max_frac: float = None,
             prefill_sparse_frac: float = 0.5, *, policy=None):
    """prompts: (B, P) int32.  Returns (B, gen_tokens) greedy tokens.

    ``policy`` (keyword-only): the SparsityPolicy for sparse phases.
    ``mode``/``k_max_frac`` are the deprecated string-mode parameters
    (one release, old positions preserved for positional callers): they
    build a uniform policy when no explicit policy is given."""
    if policy is None:
        if mode is not None or k_max_frac is not None:
            import warnings
            warnings.warn(
                "generate(mode=..., k_max_frac=...) is deprecated; pass "
                "policy=SparsityPolicy.uniform(...) instead",
                DeprecationWarning, stacklevel=2)
        policy = SparsityPolicy.uniform(
            mode or "mask", k_max_frac=1.0 if k_max_frac is None
            else k_max_frac)
    elif mode is not None or k_max_frac is not None:
        raise ValueError("pass either policy= or the deprecated "
                         "mode=/k_max_frac=, not both")
    B, P = prompts.shape
    total = P + gen_tokens

    # paper §5.1: sparsify only half the prefill tokens -> run the first
    # half dense, the second half sparse (per-token thresholds make this a
    # pure mask toggle; we approximate by prefilling dense, which is the
    # conservative accuracy choice, when no split point is given)
    prefill_sparse = prefill_sparse_frac >= 1.0
    logits, caches = M.forward(
        params, cfg, tokens=prompts, mode="prefill",
        sp=sp_stacked if prefill_sparse else None,
        policy=policy.for_phase(
            "prefill_sparse" if prefill_sparse else "prefill_dense"))
    caches = _pad_caches(cfg, caches, B, total)

    decode_policy = policy.for_phase("decode")
    decode = jax.jit(lambda p, b, sp: M.forward(
        p, cfg, tokens=b["tokens"], mode="decode", caches=b["caches"],
        positions=b["positions"], sp=sp, policy=decode_policy))

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [toks]
    for i in range(gen_tokens - 1):
        positions = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode(
            params, {"tokens": toks, "caches": caches,
                     "positions": positions}, sp_stacked)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(toks)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--mode", default="mask",
                    choices=["mask", "topk_shared", "topk_block", "pallas"])
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--calib-quick", action="store_true",
                    help="tiny-budget WiSparse calibration (CPU demo)")
    ap.add_argument("--legacy", action="store_true",
                    help="static-batch reference loop instead of the engine")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="KV pool slots (0 = batch size)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="KV pool length (0 = prompt+gen)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (chunked strategy)")
    ap.add_argument("--prefill-strategy", default="auto",
                    choices=["auto", "chunked", "whole"])
    ap.add_argument("--sensitive-backend", default=None,
                    choices=["off", "mask"],
                    help="mixed per-block policy: run this backend on the "
                         "most sensitive blocks of a calibrated plan "
                         "(requires --calib-quick)")
    ap.add_argument("--sensitive-frac", type=float, default=0.25,
                    help="fraction of blocks treated as sensitive")
    args = ap.parse_args()

    if not 0.0 <= args.sparsity <= 1.0:
        raise SystemExit(f"--sparsity must be in [0, 1], got {args.sparsity}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = api.init_model(cfg, 0)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, args.prompt_len, args.batch))
    prompts = jnp.asarray(ds.batch(0))

    if args.sensitive_backend is not None and not args.calib_quick:
        raise SystemExit("--sensitive-backend needs a calibrated plan: "
                         "add --calib-quick")

    sp, policy = None, SparsityPolicy.dense()
    if args.sparsity > 0:
        if args.calib_quick:
            from repro.core.allocation import EvoConfig
            plan = wis_pipeline.run_pipeline(
                params, cfg, {"tokens": prompts}, args.sparsity,
                evo=EvoConfig(generations=2, offspring=4, eps=0.1),
                delta=0.25, coord_passes=0, log=print)
            sp = plan.stacked_sp
            policy = plan.to_policy(
                backend=args.mode, sensitive_backend=args.sensitive_backend,
                sensitive_frac=args.sensitive_frac)
        else:
            from repro.core.sp_schema import default_sp_stacked
            sp = default_sp_stacked(params, cfg,
                                    keep_frac=1.0 - args.sparsity)
            if args.mode == "mask":
                # mask mode needs calibrated thresholds (Eq. 7); without
                # calibration fall back to the budgeted top-k backend
                print("no calibration -> using topk_shared backend")
                args.mode = "topk_shared"
            # k_max_frac must be > 0; at 100% sparsity keep the top-k
            # backends' one-channel floor (matching the legacy mode path)
            policy = SparsityPolicy.uniform(
                args.mode, k_max_frac=max(1.0 - args.sparsity, 1e-6))

    if args.legacy:
        t0 = time.time()
        toks = generate(params, cfg, prompts, args.gen, sp, policy=policy)
        dt = time.time() - t0
        n = toks.size
        print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s on CPU)")
        print("sample:", np.asarray(toks[0])[:16])
        return

    from repro.serving import Engine, EngineConfig
    from repro.serving.metrics import latency_percentiles
    ecfg = EngineConfig(
        max_slots=args.max_slots or args.batch,
        max_len=args.max_len or args.prompt_len + args.gen,
        prefill_chunk=args.chunk, policy=policy,
        prefill_strategy=args.prefill_strategy)
    engine = Engine(params, cfg, ecfg, sp)
    t0 = time.time()
    for b in range(args.batch):
        engine.submit(np.asarray(prompts[b]), args.gen)
    out = engine.run()
    dt = time.time() - t0
    n = sum(len(t) for t in out.values())
    print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s on CPU)")
    print("engine stats:", engine.stats.summary())
    print("latency:", {k: round(v, 3) for k, v in
                       latency_percentiles(engine.states.values()).items()
                       if v is not None})
    print("sample:", out[0][:16])


if __name__ == "__main__":
    main()
