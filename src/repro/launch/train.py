"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama31_8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On CPU this trains the reduced config end-to-end (the quickstart path); on
a real cluster the same driver runs the full config under the production
mesh (--mesh single|multi).  Fault tolerance comes from TrainingRunner
(checkpoint/restart, straggler flagging, deterministic data).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM, eval_batch
from repro.distributed.fault_tolerance import (FailureInjector, RunnerConfig,
                                               TrainingRunner)
from repro.distributed.sharding import (LOGICAL_RULES_TRAIN, sharding_context)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.optim import adamw


def train(arch: str = "llama31_8b", use_reduced: bool = True,
          steps: int = 100, batch: int = 8, seq: int = 128,
          lr: float = 1e-3, ckpt_dir: str = None, ckpt_every: int = 50,
          remat: str = "none", accum: int = 1, seed: int = 0,
          compress_grads: bool = False, fail_at: tuple = (),
          mesh=None, log=print):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    opt_cfg = adamw.AdamWConfig(lr_peak=lr, warmup_steps=max(steps // 20, 5),
                                decay_steps=steps,
                                compress_grads=compress_grads)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=seed)
    ds = SyntheticLM(data_cfg)

    params = api.init_model(cfg, seed)
    opt_state = adamw.init(params, opt_cfg)
    step_fn_raw = api.make_train_step(cfg, opt_cfg, remat=remat,
                                      accum_steps=accum)
    jstep = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    metrics_hist = []

    def step_fn(state, batch_tokens):
        params, opt_state = state
        params, opt_state, metrics = jstep(params, opt_state,
                                           {"tokens": batch_tokens})
        metrics_hist.append({k: float(v) for k, v in metrics.items()})
        return (params, opt_state), metrics

    def batch_fn(step):
        return jnp.asarray(ds.batch(step))

    if ckpt_dir:
        ckpt = CheckpointManager(ckpt_dir, keep=2, async_save=True)
        runner = TrainingRunner(
            RunnerConfig(total_steps=steps, checkpoint_every=ckpt_every),
            ckpt, injector=FailureInjector(fail_at) if fail_at else None,
            log=log)
        state = runner.run((params, opt_state), step_fn, batch_fn,
                           metadata={"arch": arch})
        params, opt_state = state
    else:
        state = (params, opt_state)
        for s in range(steps):
            state, m = step_fn(state, batch_fn(s))
            if s % max(steps // 10, 1) == 0:
                log(f"step {s} loss={float(m['loss']):.4f}")
        params, opt_state = state

    # held-out eval
    ev = jnp.asarray(eval_batch(data_cfg))
    loss_fn = jax.jit(api.make_loss_fn(cfg))
    final = float(loss_fn(params, {"tokens": ev}))
    log(f"final held-out loss: {final:.4f} "
        f"(init ~{np.log(cfg.vocab_size):.2f})")
    return params, cfg, data_cfg, metrics_hist, final


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    train(args.arch, args.reduced, args.steps, args.batch, args.seq,
          args.lr, args.ckpt_dir, args.ckpt_every, args.remat, args.accum,
          compress_grads=args.compress_grads)


if __name__ == "__main__":
    main()
