"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every computation exactly once, so a
``lax.scan`` over 95 layers contributes one body's FLOPs (verified
empirically — see EXPERIMENTS.md SSDry-run).  The optimized HLO, however,
annotates every while loop with ``known_trip_count``, so we recover exact
totals by walking the computation graph:

  * multiplier(ENTRY) = 1; while body/condition inherit caller x trip_count;
    fusion/to_apply/branch computations inherit the caller's multiplier.
  * FLOPs: dot ops (2 x result x contracted dims) wherever they appear,
    scaled by their computation's multiplier.
  * bytes: HloCostAnalysis-style operand+output bytes per *top-level* op of
    each computation (fusions are one op; internal traffic is free), with
    gather/dynamic-slice reading only the touched elements, and
    dynamic-update-slice writing only the update.  Control ops (while,
    tuple, parameter, ...) move no bytes themselves.
  * collectives: result bytes per op x multiplier (all-reduce counted 2x
    at the wire, see roofline.wire_bytes).

Validated against cost_analysis on scan-free modules (tests/test_roofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([\w-]+)")
_CALLREF_RE = re.compile(r"(calls|to_apply|body|condition|branch_computations)="
                         r"({[^}]*}|%[\w.-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":?\s*[{]\\?"n\\?":?\\?"(\d+)\\?"')
_TRIP_RE2 = re.compile(r'known_trip_count[^0-9]*(\d+)')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "while",
    "conditional", "call", "after-all", "bitcast", "partition-id",
    "replica-id", "custom-call", "copy-start", "copy-done", "rng",
    "iota", "get-dimension-size",
}


def _shape_bytes(text: str) -> int:
    """Sum bytes over all shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    line: str
    fusion_callee: Optional[str] = None


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    # edges: (callee_name, kind, trip)
    calls: List[Tuple[str, str, int]]


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header:  %name (args) -> type {   /  ENTRY %name ...
        if stripped.endswith("{") and ("(" in stripped) and "=" not in stripped.split("(")[0]:
            header = stripped.split("(")[0].strip()
            is_entry = header.startswith("ENTRY")
            name = header.replace("ENTRY", "").strip().lstrip("%")
            cur = Computation(name, [], [])
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.groups()
        mo = _OPCODE_RE.match(rhs)
        if not mo:
            continue
        result_type, opcode = mo.groups()
        # strip trailing ".N" numeric suffixes fused into opcode tokens
        op = Op(name, opcode, result_type, stripped)
        cur.ops.append(op)
        for ref in _CALLREF_RE.finditer(stripped):
            kind, val = ref.groups()
            callees = [c.strip().lstrip("%")
                       for c in val.strip("{}").split(",")]
            trip = 1
            if opcode == "while" and kind == "body":
                tm = _TRIP_RE.search(stripped) or _TRIP_RE2.search(stripped)
                trip = int(tm.group(1)) if tm else 1
            for c in callees:
                if c:
                    cur.calls.append((c, kind, trip))
                    if kind == "calls":
                        op.fusion_callee = c
    if entry and entry != "__ENTRY__":
        comps["__ENTRY__"] = comps[entry]
    return comps


def multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = comps.get("__ENTRY__")
    mult = {c: 0.0 for c in comps}
    if entry is None:
        # fall back: treat every computation once
        return {c: 1.0 for c in comps}
    mult[entry.name] = 1.0
    # propagate along call edges (HLO computation graph is a DAG)
    changed = True
    iters = 0
    while changed and iters < 10000:
        changed = False
        iters += 1
        for c in comps.values():
            if c.name == "__ENTRY__" or mult.get(c.name, 0.0) <= 0.0:
                continue
            m = mult[c.name]
            for callee, kind, trip in c.calls:
                if callee not in mult:
                    continue
                add = m * (trip if kind == "body" else 1.0)
                if add > mult[callee]:
                    mult[callee] = add
                    changed = True
    return mult


def _operand_list(line: str, opcode: str) -> List[str]:
    """Operand names of a top-level op, robust to typed operand lists:
    ``dot(f32[64,256]{1,0} %a, f32[256,256]{2,1,0} %b)`` -> [a, b].
    Splits only on commas outside brackets/braces/parens, then takes the
    last whitespace token of each piece (the %name)."""
    m = re.search(r"\b" + re.escape(opcode) + r"\(", line)
    if not m:
        return []
    depth, parts, cur = 0, [], []
    for ch in line[m.end():]:
        if ch == ")" and depth == 0:    # closes the operand list
            break
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    names = []
    for p in parts:
        toks = p.strip().split()
        if toks:
            names.append(toks[-1].lstrip("%"))
    return names


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    result_bytes_dims = _SHAPE_RE.findall(op.result_type)
    if not result_bytes_dims:
        return 0.0
    _, dims = result_bytes_dims[0]
    out_elems = 1
    for d in dims.split(","):
        if d:
            out_elems *= int(d)
    # contracted size from lhs shape + lhs_contracting_dims
    opnds = _operand_list(op.line, op.opcode)
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contract = 1
    if opnds and mcd:
        lhs_type = shapes.get(opnds[0], "")
        sh = _SHAPE_RE.findall(lhs_type)
        if sh:
            lhs_dims = [int(d) for d in sh[0][1].split(",") if d]
            for ci in mcd.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contract *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contract


_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape", "transpose")


def _sliced_param_bytes(callee: "Computation", param_idx: int) -> Optional[float]:
    """If fusion parameter `param_idx` is only consumed (possibly through
    elementwise pass-through ops) by dynamic-slice/gather reads or as the
    in-place destination of dynamic-update-slice, return the touched bytes
    (sum of slice outputs); else None (count the full operand)."""
    pname = None
    for o in callee.ops:
        if o.opcode == "parameter" and re.search(
                rf"parameter\({param_idx}\)", o.line):
            pname = o.name
            break
    if pname is None:
        return None
    names = {pname}
    touched = 0.0
    # ops are in dependency order; one forward pass suffices
    for o in callee.ops:
        if o.name in names:
            continue
        rhs = o.line.split("=", 1)[-1]
        used = any(re.search(rf"%{re.escape(n)}\b", rhs) for n in names)
        if not used:
            continue
        if o.opcode in _PASSTHROUGH:
            names.add(o.name)
        elif o.opcode in ("dynamic-slice", "gather", "slice"):
            touched += _shape_bytes(o.result_type)
        elif o.opcode == "dynamic-update-slice":
            refs = _operand_list(o.line, o.opcode)
            if refs and refs[0] in names:
                names.add(o.name)            # aliased in-place destination
            else:
                return None                  # param is the update itself
        else:
            return None
    return touched


def _op_bytes(op: Op, shapes: Dict[str, str],
              comps: Optional[Dict[str, "Computation"]] = None) -> float:
    if op.opcode in _NO_BYTES:
        return 0.0
    out_b = _shape_bytes(op.result_type)
    refs = _operand_list(op.line, op.opcode)
    callee = comps.get(op.fusion_callee) if (comps and op.fusion_callee) else None
    in_b = 0.0
    for i, ref in enumerate(refs):
        t = shapes.get(ref)
        if not t:
            continue
        b = _shape_bytes(t)
        if callee is not None:
            sliced = _sliced_param_bytes(callee, i)
            if sliced is not None:
                b = min(b, sliced)
        in_b += b
    if op.opcode in ("gather", "dynamic-slice", "slice"):
        in_b = min(in_b, 2 * out_b)            # touched elements only
    if op.opcode == "dynamic-update-slice":
        upd = _shape_bytes(shapes[refs[1]]) if len(refs) >= 2 and refs[1] in shapes else 0
        return 2.0 * upd                        # read+write the update only
    if callee is not None:
        # in-place DUS fusions: output bytes = update written, not the array
        root_dus = [o for o in callee.ops if o.opcode == "dynamic-update-slice"]
        if root_dus and _shape_bytes(root_dus[-1].result_type) >= out_b:
            upd_b = 0.0
            for o in callee.ops:
                if o.opcode == "dynamic-update-slice":
                    rs = _operand_list(o.line, o.opcode)
                    local = {x.name: x.result_type for x in callee.ops}
                    if len(rs) >= 2 and rs[1] in local:
                        upd_b += _shape_bytes(local[rs[1]])
            if upd_b:
                out_b = min(out_b, upd_b)
    return float(in_b + out_b)


def _is_pure_convert(callee: Computation) -> bool:
    """Fusions that only cast dtypes are free on TPU (folded into consumers;
    the CPU backend materializes f32 copies of bf16 weights, which would
    otherwise inflate the memory term — see DESIGN.md SS6)."""
    for o in callee.ops:
        if o.opcode not in ("parameter", "convert", "bitcast", "copy",
                            "transpose", "reshape"):
            return False
    return True


def analyze(hlo: str) -> dict:
    comps = parse_module(hlo)
    mult = multipliers(comps)
    # computations reached via fusion `calls=` / reducer `to_apply=` are
    # internal: their data movement is accounted at the call site
    internal = set()
    for c in comps.values():
        for callee, kind, _ in c.calls:
            if kind in ("calls", "to_apply"):
                internal.add(callee)
    flops = 0.0
    bytes_accessed = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    for key, c in comps.items():
        if key == "__ENTRY__":        # alias of the entry computation
            continue
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        count_bytes = c.name not in internal
        shapes = {op.name: op.result_type for op in c.ops}
        for op in c.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, shapes)
            if op.opcode in _COLLECTIVES or any(
                    op.opcode.startswith(k) for k in _COLLECTIVES):
                base = next(k for k in _COLLECTIVES if op.opcode.startswith(k))
                coll[base] += m * _shape_bytes(op.result_type)
                if count_bytes:
                    bytes_accessed += m * 2 * _shape_bytes(op.result_type)
                continue
            if not count_bytes:
                continue
            if op.fusion_callee and op.fusion_callee in comps and \
                    _is_pure_convert(comps[op.fusion_callee]):
                continue
            bytes_accessed += m * _op_bytes(op, shapes, comps)
    return {"flops": flops, "bytes": bytes_accessed, "collectives": coll}
