"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell:

    compute    = HLO_FLOPs            / (chips x 197 TF/s bf16)
    memory     = HLO_bytes            / (chips x 819 GB/s HBM)
    collective = collective_bytes     / (chips x 50 GB/s/link ICI)

``compiled.cost_analysis()`` supplies FLOPs/bytes; collective bytes are
parsed from the *optimized* HLO (``compiled.as_text()`` — the collectives
only exist post-SPMD-partitioning).  For each collective op we count the
result-shape bytes (equal to operand bytes for all-reduce; the standard
proxy for the per-device wire bytes), with all-reduce counted twice
(reduce-scatter + all-gather decomposition).

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (serve); the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/causal-overcount/redundancy.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import constants as C

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %foo = bf16[16,4096]{1,0} all-reduce(...)
_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")[\.\(]")
# tuple-result collectives: = (bf16[..], bf16[..]) all-to-all(
_RE_TUPLE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")[\.\(]")
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def executable_costs(compiled) -> "tuple[float, float]":
    """(FLOPs, bytes accessed) from a compiled executable's
    ``cost_analysis()``, normalized across jax versions (some return the
    per-device dict directly, some a one-element list) and backends
    (missing keys read as 0 — the interpreter path reports no bytes).
    The reusable core of the ``benchmarks/roofline_report`` extraction,
    shared with the serving-time per-rung roofline counters
    (``repro.obs.quality``)."""
    try:
        cost = compiled.cost_analysis()
    except Exception:                     # backend without cost analysis
        return 0.0, 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return 0.0, 0.0
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _RE.search(line)
        if m:
            dtype, dims, op = m.groups()
            out[op] += _shape_bytes(dtype, dims)
            continue
        mt = _RE_TUPLE.search(line)
        if mt:
            shapes, op = mt.groups()
            for dtype, dims in _RE_SHAPE.findall(shapes):
                out[op] += _shape_bytes(dtype, dims)
    return out


def wire_bytes(coll: Dict[str, int]) -> float:
    """Per-device wire bytes: all-reduce counts 2x (RS+AG decomposition)."""
    total = 0.0
    for k, v in coll.items():
        total += 2 * v if k == "all-reduce" else v
    return total


def active_matmul_params(cfg: ModelConfig) -> float:
    """N_active: per-token matmul params (MoE scaled by k/E), head included."""
    from repro.models import model as M
    from repro.models.params import _flatten

    schema = M.model_schema(cfg)
    total = 0.0
    for path, spec in _flatten(schema)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1]
        if name in ("embed", "lm_head"):
            continue                      # head counted separately below
        if len(spec.shape) < 2:
            continue
        shape = spec.shape
        # drop the stacked-layers dim from the product, multiply back reps
        if keys and any(k.startswith("l") and k[1:].isdigit() for k in keys):
            reps, shape = shape[0], shape[1:]
        else:
            reps = 1
        p = float(np.prod(shape)) * reps
        if len(shape) == 3:               # MoE expert weight (E, n, m)
            p *= cfg.num_experts_per_tok / cfg.num_experts
        total += p
    total += float(cfg.vocab_size) * cfg.d_model   # unembedding matmul
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = active_matmul_params(cfg)
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token per seq


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # per-device (XLA analyses the SPMD module)
    hlo_bytes: float
    coll_bytes: float          # per-device wire bytes
    model_flops_total: float

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / C.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / C.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / C.ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (full overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        return (self.model_flops_total
                / (self.step_time_s * self.chips * C.PEAK_FLOPS_BF16))

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "useful_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }
