"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharding plumbing."""
    return jax.make_mesh((1, 1), ("data", "model"))
