"""Sparsity *quality* observability: how much accuracy is the active
rung costing on live traffic, right now?

The serving stack's other telemetry (metrics/trace/events) observes
latency and throughput; the :class:`AdaptiveController` is blind to
quality — it will happily park at the sparsest rung as long as TPOT
holds.  WiSparse's own quality machinery (Eq. 6 block reconstruction
error, weight-aware channel saliency) runs once at calibration time and
is never measured again, even though saliency statistics drift when the
serving distribution stops matching the calibration set.  The
:class:`QualityMonitor` closes that loop with four probes, all riding
the engine's existing compile-once discipline:

1. **Shadow dense probes** — a configurable fraction of decode steps is
   re-run through a dense single-token verify executable (PR 4's
   ``mode="verify"`` machinery with a window of one) *before* the real
   decode dispatch.  The probe writes dense K/V only at each slot's
   current position, which the immediately following serving-policy
   decode overwrites — so served tokens and cache state are bit-exactly
   those of a probe-free run.  Per-rung token-agreement and top-k
   logit-overlap histograms come out the other end.
2. **Online block reconstruction error** — the exact Eq. 6 metric from
   ``core/calibration.py`` evaluated on a window of recently served
   tokens: one dense unstacked forward collects every block's dense
   input/output, each block re-runs under the active rung's sp tree with
   the paper's per-token ``mask`` numerics, and the per-block MSE is
   exported as histograms and compared against the calibration-time
   baselines a v4 ladder artifact carries.
3. **Saliency drift detection** — per (block, rung) EWMA Jaccard overlap
   between the live top-k saliency channel set (``|x| * g^alpha`` on the
   block input, the calibration scoring rule) and the calibration-time
   set from the ladder artifact (first live observation seeds the
   reference when the artifact predates v4).  Crossing below the
   threshold emits a ``saliency_drift`` event with (block, rung)
   attribution and raises the ``pressure`` gauge the controller can read
   as an advisory de-escalation hint (``SLOConfig.quality_aware``).
4. **Per-rung roofline counters** — at ``warmup()`` every rung's
   decode/chunk (and spec verify) executable is AOT-lowered and its
   ``cost_analysis()`` FLOPs/bytes captured
   (:func:`repro.launch.roofline.executable_costs`), exported as gauges
   plus an achieved-vs-roofline decode utilization estimate.

Zero-cost when off: ``NULL_TELEMETRY.quality is None`` and the engine's
only hot-path touch is one ``is not None`` check.  Retrace-free when on:
the probe and reconstruction executables are jitted once and precompiled
by :meth:`attach` (called from ``Engine.warmup()``); their trace
counters are baselined exactly like the engine's
(``retraces_after_warmup``).  Spec engines never run the plain decode
step, so they expose roofline counters but do not probe.

Module import stays light (stdlib + numpy + ``obs.metrics``); jax and
the model stack load lazily at :meth:`attach`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import clock
from repro.obs.metrics import Histogram, log_buckets

# dedicated Chrome-trace track for quality probes (requests own tids
# request_id+1; this sits far above any realistic request count)
QUALITY_TID = 999_983

# [0, 1] fractions (agreement, top-k overlap) at 1/16 resolution —
# exact means via _sum/_count, bounded exposition cardinality
FRACTION_BUCKETS = tuple(i / 16 for i in range(17))

# Eq. 6 block MSEs span many decades; one bucket per decade
RECON_BUCKETS = log_buckets(1e-9, 1e3, per_decade=1)


@dataclasses.dataclass(frozen=True)
class QualityConfig:
    """Quality-probe tuning.

    probe_rate       fraction of decode steps shadow-probed, in (0, 1]
                     (deterministic stride — no RNG on the hot path).
    topk             k for the probe's logit-overlap metric.
    drift_threshold  EWMA Jaccard overlap below which a block is
                     drifting, in (0, 1).
    drift_alpha      EWMA smoothing for the per-(block, rung) overlap.
    recon_every      run the reconstruction/saliency pass on every Nth
                     probe (it costs a full window forward; 0 disables).
    recon_window     token window for the reconstruction pass; sampled
                     from the live request with the longest history
                     (skipped until one has at least this many tokens).
    saliency_topk    channel-set size for the live-vs-calibration
                     Jaccard overlap.
    """

    probe_rate: float = 0.05
    topk: int = 8
    drift_threshold: float = 0.5
    drift_alpha: float = 0.2
    recon_every: int = 4
    recon_window: int = 16
    saliency_topk: int = 32

    def __post_init__(self):
        if not 0.0 < self.probe_rate <= 1.0:
            raise ValueError(
                f"probe_rate must be in (0, 1], got {self.probe_rate}")
        if not 0.0 < self.drift_threshold < 1.0:
            raise ValueError(
                f"drift_threshold must be in (0, 1), "
                f"got {self.drift_threshold}")
        if not 0.0 < self.drift_alpha <= 1.0:
            raise ValueError(
                f"drift_alpha must be in (0, 1], got {self.drift_alpha}")
        if self.topk < 1:
            raise ValueError(f"topk must be >= 1, got {self.topk}")
        if self.recon_every < 0:
            raise ValueError(
                f"recon_every must be >= 0, got {self.recon_every}")
        if self.recon_window < 1:
            raise ValueError(
                f"recon_window must be >= 1, got {self.recon_window}")
        if self.saliency_topk < 1:
            raise ValueError(
                f"saliency_topk must be >= 1, got {self.saliency_topk}")


# ---------------------------------------------------------------------------
# shared helpers (the calibration side of the ladder uses these too, so
# live scores and stored baselines are computed by the same rule)
# ---------------------------------------------------------------------------

def rep_saliency_leaf(sp_d, d_model: int):
    """First sparsifiable leaf of a per-depth sp dict whose ``g`` norms
    live on the block-input channel axis -> (g, alpha) as numpy, or
    ``None`` when the block has no such leaf.  Deterministic (sorted
    walk), so calibration and serving always pick the same leaf."""
    def walk(node):
        if not isinstance(node, dict):
            return None
        if "g" in node and "alpha" in node:
            g = np.asarray(node["g"], np.float32)
            if g.ndim == 1 and g.shape[0] == d_model:
                return g, float(np.asarray(node["alpha"]))
            return None
        for k in sorted(node):
            found = walk(node[k])
            if found is not None:
                return found
        return None
    return walk(sp_d)


def saliency_channels(x_mean_abs: np.ndarray, g: np.ndarray, alpha: float,
                      k: int) -> np.ndarray:
    """Top-k channel indices of the WiSparse saliency score
    ``|x| * max(g, 1e-12)^alpha`` (sorted, for stable set compares)."""
    scores = np.asarray(x_mean_abs, np.float32) \
        * np.maximum(np.asarray(g, np.float32), 1e-12) ** float(alpha)
    k = min(int(k), scores.shape[0])
    return np.sort(np.argpartition(-scores, k - 1)[:k]).astype(np.int64)


def unstack_sp(cfg, sp):
    """Stacked group sp tree -> per-depth sp list (inverse of
    ``repro.core.unstacked.restack_sp``; trace-safe — slicing works on
    tracers and concrete arrays alike)."""
    import jax
    per_depth = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        gsp = sp[gi]
        for r in range(reps):
            for j in range(len(pattern)):
                per_depth.append(jax.tree_util.tree_map(
                    lambda a, r=r: a[r], gsp[f"l{j}"]))
    return per_depth


def _jaccard(a: np.ndarray, b: np.ndarray) -> float:
    union = np.union1d(a, b)
    if union.size == 0:
        return 1.0
    return float(np.intersect1d(a, b).size) / float(union.size)


# ---------------------------------------------------------------------------

class QualityMonitor:
    """Live sparsity-quality probes for one engine.

    Construct with a :class:`QualityConfig` (or kwargs), hand it to the
    engine via ``Telemetry(quality=...)``; ``Engine.warmup()`` calls
    :meth:`attach`, which builds and precompiles the probe executables
    and captures the roofline counters.  Until then the monitor is inert
    (``armed`` is False and ``should_probe`` always says no)."""

    def __init__(self, cfg: Optional[QualityConfig] = None, **kw):
        if cfg is None:
            cfg = QualityConfig(**kw)
        elif kw:
            raise TypeError("pass a QualityConfig or kwargs, not both")
        self.cfg = cfg
        self.armed = False
        self._stride = max(1, int(round(1.0 / cfg.probe_rate)))
        self._step_idx = 0
        # probe counters/aggregates
        self.probes = 0
        self.probe_tokens = 0
        self.recon_passes = 0
        self.drift_events = 0
        self.pressure = 0.0
        self.agreement_hists: Tuple[Histogram, ...] = ()
        self.overlap_hists: Tuple[Histogram, ...] = ()
        self.recon_hists: Tuple[Histogram, ...] = ()
        # per-(rung, block) saliency state
        self.saliency_ref: Dict[Tuple[int, int], np.ndarray] = {}
        self.saliency_ewma: Dict[Tuple[int, int], float] = {}
        self._drifting: Dict[Tuple[int, int], bool] = {}
        # calibration-time baselines (from a v4 ladder artifact)
        self.recon_baseline: Optional[np.ndarray] = None   # (rungs, blocks)
        self.recon_last: Optional[np.ndarray] = None       # (blocks,)
        self.recon_ratio: Optional[float] = None
        # roofline counters: (phase, rung) -> {"flops", "bytes"}
        self.roofline: Dict[Tuple[str, int], Dict[str, float]] = {}
        # executables (built at attach)
        self._vstep = None
        self._rstep = None
        self._ref_sp = None
        self._ref_policy = None
        self._g_alpha = None            # [rung][depth] -> (g, alpha) | None
        self._probe_traces = 0
        self._recon_traces = 0
        self._warm: Optional[Tuple[int, int]] = None
        self._named_track = False
        self._probe_span: Optional[Tuple[float, float]] = None

    # ------------------------------------------------------------------
    # wiring
    # ------------------------------------------------------------------
    def attach(self, engine) -> None:
        """Build + precompile the probe executables against ``engine``
        and capture the per-rung roofline counters.  Called from
        ``Engine.warmup()`` on an idle engine (the precompile dispatches
        write only scratch/overwritten cache positions, exactly like the
        rest of warmup)."""
        import jax
        import jax.numpy as jnp

        from repro.launch.roofline import executable_costs
        from repro.models import api
        from repro.sparsity import SparsityPolicy

        R = engine.num_rungs
        if len(self.agreement_hists) != R:
            self.agreement_hists = tuple(
                Histogram(FRACTION_BUCKETS) for _ in range(R))
            self.overlap_hists = tuple(
                Histogram(FRACTION_BUCKETS) for _ in range(R))
            self.recon_hists = tuple(
                Histogram(RECON_BUCKETS) for _ in range(R))

        # -- dense reference for shadow probes --------------------------
        # ladder engines probe against rung 0 (the quality reference the
        # ladder was calibrated to); if rung 0 itself is not dense, or
        # the engine runs a fixed policy, a plain dense policy over the
        # same sp tree is the reference.
        self._ref_sp = engine._rung_sp[0]
        ref_pol = engine._rung_phases[0][2]
        if not ref_pol.is_dense:
            ref_pol = SparsityPolicy.dense().for_phase("decode")
        self._ref_policy = ref_pol

        verify = api.make_verify_step(engine.cfg)

        def _probe(params, tokens, positions, caches, sp, weights, *,
                   policy):
            self._probe_traces += 1          # runs only while tracing
            engine._record_compile("quality_probe")
            return verify(params, tokens, positions, caches, sp, weights,
                          policy=policy)

        self._vstep = jax.jit(_probe, static_argnames=("policy",),
                              donate_argnums=(3,))

        S = engine.ecfg.max_slots
        t1 = jnp.zeros((S, 1), jnp.int32)
        p1 = jnp.full((S,), engine.pool_len - 1, jnp.int32)
        w1 = jnp.zeros((S, 1), jnp.float32)
        out, engine.pool.caches = self._vstep(
            engine.params, t1, p1, engine.pool.caches, self._ref_sp, w1,
            policy=self._ref_policy)
        out.block_until_ready()

        # -- reconstruction / saliency executable -----------------------
        # one jit covers every rung: the sp tree is a *traced* argument
        # and ladder rungs share one sp schema.
        self._rstep = None
        self._g_alpha = None
        if self.cfg.recon_every > 0 and all(
                sp is not None for sp in engine._rung_sp):
            from repro.core import unstacked as U
            cfg = engine.cfg
            mask_pol = SparsityPolicy.uniform("mask")

            def _recon(params, tokens, sp):
                self._recon_traces += 1
                engine._record_compile("quality_recon")
                layers = U.unstack_layers(cfg, params)
                per_depth = unstack_sp(cfg, sp)
                _, block_io = U.forward_unstacked(
                    params, cfg, tokens, layers=layers,
                    collect_block_inputs=True)
                y_last = U.block_forward(layers[-1], block_io[-1], cfg,
                                         None, None)
                refs = list(block_io[1:]) + [y_last]
                errs, feats = [], []
                for d, dl in enumerate(layers):
                    x_in = block_io[d]
                    y = U.block_forward(dl, x_in, cfg, per_depth[d], None,
                                        policy=mask_pol)
                    errs.append(jnp.mean(jnp.square(
                        y.astype(jnp.float32)
                        - refs[d].astype(jnp.float32))))
                    feats.append(jnp.mean(
                        jnp.abs(x_in.astype(jnp.float32)), axis=(0, 1)))
                return jnp.stack(errs), jnp.stack(feats)

            self._rstep = jax.jit(_recon)
            tok = jnp.zeros((1, self.cfg.recon_window), jnp.int32)
            errs, feats = self._rstep(engine.params, tok,
                                      engine._rung_sp[0])
            errs.block_until_ready()
            # host-side (g, alpha) of each block's representative leaf,
            # per rung — the live saliency scoring inputs
            self._g_alpha = []
            for sp in engine._rung_sp:
                per_depth = unstack_sp(cfg, sp)
                self._g_alpha.append([
                    rep_saliency_leaf(
                        jax.tree_util.tree_map(np.asarray, sp_d),
                        cfg.d_model)
                    for sp_d in per_depth])

        # -- calibration baselines from the ladder artifact (v4) --------
        ladder = getattr(engine, "ladder", None)
        qb = getattr(ladder, "baselines", None) if ladder is not None \
            else None
        if qb is not None:
            recon = qb.get("recon")
            if recon is not None:
                self.recon_baseline = np.asarray(recon, np.float64)
            channels = qb.get("channels")
            if channels is not None:
                for r, per_block in enumerate(channels):
                    for d, ch in enumerate(per_block):
                        ch = np.asarray(ch, np.int64)
                        if ch.size:
                            self.saliency_ref[(r, d)] = ch

        # -- per-rung roofline counters (AOT: lower + compile only; no
        # execution, so cache donation never actually happens) ----------
        t0 = jnp.zeros((S,), jnp.int32)
        inactive = jnp.zeros((S,), jnp.float32)
        C = engine.ecfg.prefill_chunk
        for r, ((pd, _ps, dec), sp) in enumerate(
                zip(engine._rung_phases, engine._rung_sp)):
            compiled = engine._dstep.lower(
                engine.params, t0, p1, engine.pool.caches, sp, inactive,
                policy=dec).compile()
            flops, byts = executable_costs(compiled)
            self.roofline[("decode", r)] = {"flops": flops, "bytes": byts}
            if engine.prefill_strategy == "chunked":
                compiled = engine._cstep.lower(
                    engine.params, jnp.zeros((1, C), jnp.int32),
                    jnp.zeros((1,), jnp.int32), jnp.int32(0),
                    engine.pool.caches, sp, jnp.zeros((C,), jnp.float32),
                    policy=pd).compile()
                flops, byts = executable_costs(compiled)
                self.roofline[("chunk", r)] = {"flops": flops,
                                               "bytes": byts}
        if engine.spec_decoder is not None:
            sd = engine.spec_decoder
            _, _, ver_pol = engine._rung_phases[sd.verifier_rung]
            ver_sp = engine._rung_sp[sd.verifier_rung]
            for g in engine.ecfg.spec.gammas():
                compiled = sd._vstep.lower(
                    engine.params, jnp.zeros((S, g + 1), jnp.int32),
                    jnp.full((S,), engine.pool_len - (g + 1), jnp.int32),
                    engine.pool.caches, ver_sp,
                    jnp.zeros((S, g + 1), jnp.float32),
                    policy=ver_pol).compile()
                flops, byts = executable_costs(compiled)
                self.roofline[(f"verify{g}", sd.verifier_rung)] = {
                    "flops": flops, "bytes": byts}

        self._warm = (self._probe_traces, self._recon_traces)
        self.armed = True

    @property
    def retraces_after_warmup(self) -> Optional[int]:
        """Probe + recon (re)traces since :meth:`attach`; the quality
        invariant is that this stays 0 under live probing."""
        if self._warm is None:
            return None
        return (self._probe_traces - self._warm[0]) \
            + (self._recon_traces - self._warm[1])

    # ------------------------------------------------------------------
    # hot path (engine._decode_step)
    # ------------------------------------------------------------------
    def should_probe(self) -> bool:
        """Deterministic stride sampling over decode steps."""
        if not self.armed:
            return False
        hit = self._step_idx % self._stride == 0
        self._step_idx += 1
        return hit

    def run_probe(self, engine, tokens, positions, active) -> np.ndarray:
        """Shadow dense probe for one decode step, run *before* the real
        dispatch: a window-1 dense verify whose K/V writes land exactly
        on the positions the immediately following serving-policy decode
        overwrites — served tokens and cache are bit-identical to a
        probe-free run.  Returns host logits (slots, vocab)."""
        import jax.numpy as jnp
        t0 = clock.now()
        out, engine.pool.caches = self._vstep(
            engine.params, jnp.asarray(tokens).reshape(-1, 1),
            jnp.asarray(positions), engine.pool.caches, self._ref_sp,
            jnp.asarray(active, jnp.float32).reshape(-1, 1),
            policy=self._ref_policy)
        probe = np.asarray(out[:, 0])            # syncs the dispatch
        self._probe_span = (t0, clock.now())
        return probe

    def observe(self, engine, probe: np.ndarray, logits, nxt: np.ndarray,
                active: np.ndarray, t: float) -> None:
        """Score one probed step (post real-decode, host side): per-rung
        agreement and top-k overlap, plus — every ``recon_every`` probes
        — the reconstruction/saliency pass."""
        slots = np.nonzero(np.asarray(active) > 0)[0]
        if slots.size == 0:
            return
        rung = engine.rung
        self.probes += 1
        self.probe_tokens += int(slots.size)
        serving = np.asarray(logits)
        k = min(self.cfg.topk, probe.shape[-1])
        agree = 0
        overlap = 0.0
        for s in slots:
            if int(np.argmax(probe[s])) == int(nxt[s]):
                agree += 1
            pa = np.argpartition(-probe[s], k - 1)[:k]
            sa = np.argpartition(-serving[s], k - 1)[:k]
            overlap += np.intersect1d(pa, sa).size / k
        agreement = agree / slots.size
        overlap /= slots.size
        self.agreement_hists[rung].observe(agreement)
        self.overlap_hists[rung].observe(overlap)
        tr = engine.obs.tracer
        if tr is not None:
            if not self._named_track:
                tr.thread_name(QUALITY_TID, "quality")
                self._named_track = True
            span = self._probe_span or (t, t)
            tr.complete("quality_probe", span[0], span[1],
                        tid=QUALITY_TID, rung=rung,
                        agreement=round(agreement, 4),
                        topk_overlap=round(overlap, 4),
                        slots=int(slots.size))
        if self._rstep is not None and self.cfg.recon_every > 0 \
                and self.probes % self.cfg.recon_every == 0:
            self._recon_pass(engine, rung, t)

    # ------------------------------------------------------------------
    # reconstruction + saliency drift
    # ------------------------------------------------------------------
    def _live_window(self, engine) -> Optional[np.ndarray]:
        """The last ``recon_window`` tokens of the live request with the
        longest prompt+generated history (fixed shape keeps the recon
        executable retrace-free); None until one is long enough."""
        W = self.cfg.recon_window
        best = None
        for rs in engine.scheduler.decoding.values():
            n = rs.request.prompt_len + len(rs.tokens)
            if n >= W and (best is None or n > best[0]):
                best = (n, rs)
        if best is None:
            return None
        rs = best[1]
        seq = np.concatenate([np.asarray(rs.request.prompt, np.int32),
                              np.asarray(rs.tokens, np.int32)])
        return seq[-W:].reshape(1, W)

    def _recon_pass(self, engine, rung: int, t: float) -> None:
        import jax.numpy as jnp
        window = self._live_window(engine)
        if window is None:
            return
        errs, feats = self._rstep(engine.params, jnp.asarray(window),
                                  engine._rung_sp[rung])
        errs = np.asarray(errs, np.float64)
        feats = np.asarray(feats, np.float32)
        self.recon_passes += 1
        self.recon_last = errs
        for e in errs:
            self.recon_hists[rung].observe(float(e))
        if self.recon_baseline is not None \
                and rung < self.recon_baseline.shape[0]:
            base = float(np.mean(self.recon_baseline[rung]))
            self.recon_ratio = float(np.mean(errs)) / max(base, 1e-12)
        self._saliency_pass(engine, rung, feats, t)

    def _saliency_pass(self, engine, rung: int, feats: np.ndarray,
                       t: float) -> None:
        cfg = self.cfg
        ga = self._g_alpha[rung] if self._g_alpha is not None else None
        if ga is None:
            return
        for d in range(feats.shape[0]):
            if d >= len(ga) or ga[d] is None:
                continue
            g, alpha = ga[d]
            live = saliency_channels(feats[d], g, alpha, cfg.saliency_topk)
            key = (rung, d)
            ref = self.saliency_ref.get(key)
            if ref is None:
                # no calibration baseline (pre-v4 artifact / uniform
                # ladder): the first live observation is the reference
                self.saliency_ref[key] = live
                self.saliency_ewma[key] = 1.0
                continue
            jac = _jaccard(live, ref)
            a = cfg.drift_alpha
            prev = self.saliency_ewma.get(key)
            ewma = jac if prev is None else (1 - a) * prev + a * jac
            self.saliency_ewma[key] = ewma
            below = ewma < cfg.drift_threshold
            if below and not self._drifting.get(key, False):
                self.drift_events += 1
                ev = engine.obs.events
                if ev is not None:
                    ev.emit("saliency_drift", t=t, block=d, rung=rung,
                            overlap=round(ewma, 4),
                            threshold=cfg.drift_threshold)
                tr = engine.obs.tracer
                if tr is not None:
                    tr.instant("saliency_drift", t=t, tid=QUALITY_TID,
                               block=d, rung=rung,
                               overlap=round(ewma, 4))
                fr = engine.obs.flight
                if fr is not None:
                    # drift edge is a black-box trigger (see
                    # FlightRecorder.decision)
                    fr.decision("saliency_drift", block=d, rung=rung,
                                overlap=round(ewma, 4),
                                threshold=cfg.drift_threshold)
            self._drifting[key] = below
        self._update_pressure(rung)

    def _update_pressure(self, rung: int) -> None:
        """Quality pressure in [0, 1]: how far below the drift threshold
        the active rung's worst block EWMA sits (0 = no drift)."""
        thr = self.cfg.drift_threshold
        worst = 0.0
        for (r, _d), ewma in self.saliency_ewma.items():
            if r == rung:
                worst = max(worst, (thr - ewma) / thr)
        self.pressure = float(np.clip(worst, 0.0, 1.0))

    def seed_reference(self, rung: int, block: int,
                       channels: np.ndarray) -> None:
        """Install a saliency reference channel set for (rung, block) —
        what loading a v4 ladder does; exposed for tests and for
        operators re-baselining a drifted deployment."""
        self.saliency_ref[(rung, block)] = \
            np.sort(np.asarray(channels, np.int64))
        self.saliency_ewma.pop((rung, block), None)
        self._drifting.pop((rung, block), None)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def recon_baseline_mean(self, rung: int) -> Optional[float]:
        if self.recon_baseline is None \
                or rung >= self.recon_baseline.shape[0]:
            return None
        return float(np.mean(self.recon_baseline[rung]))

    def decode_utilization(self, measured_step_s: float) -> Dict[int, float]:
        """Per-rung achieved-vs-roofline decode utilization: the
        executable's roofline step time (max of compute and memory
        terms) over the measured mean decode step latency.  One measured
        mean covers all rungs — a per-rung latency split would need
        per-rung timing state the hot path deliberately doesn't keep."""
        from repro.launch import constants as C
        out: Dict[int, float] = {}
        if measured_step_s <= 0:
            return out
        for (phase, r), cost in self.roofline.items():
            if phase != "decode":
                continue
            ideal = max(cost["flops"] / C.PEAK_FLOPS_BF16,
                        cost["bytes"] / C.HBM_BW)
            out[r] = ideal / measured_step_s
        return out

    def snapshot(self) -> dict:
        def hist_mean(hists):
            count = sum(h.count for h in hists)
            if not count:
                return None
            return round(sum(h.sum for h in hists) / count, 6)
        out = {
            "quality_probes": self.probes,
            "quality_probe_tokens": self.probe_tokens,
            "quality_agreement_mean": hist_mean(self.agreement_hists),
            "quality_topk_overlap_mean": hist_mean(self.overlap_hists),
            "quality_recon_mean": hist_mean(self.recon_hists),
            "quality_drift_events": self.drift_events,
            "quality_pressure": round(self.pressure, 4),
        }
        if self.recon_ratio is not None:
            out["quality_recon_vs_baseline"] = round(self.recon_ratio, 4)
        return out
