"""One monotonic clock for the whole serving stack.

Every serving timestamp — engine stats, controller ticks, spans, events,
JSONL snapshots, benchmark replay — routes through :func:`now`, so the
different telemetry streams are mutually orderable.  Historically the
engine used ``time.monotonic()`` while the serve CLI timed runs with
``time.time()``; a span at monotonic ``t`` and a log line at epoch ``t'``
could not be correlated.  :func:`to_wall` maps a monotonic timestamp to
approximate epoch seconds for human-facing output only — never compare
``to_wall`` results across processes or use them for durations.

Injectable clocks: the serving engine reads time through an injected
clock object (``Engine(..., clock=...)``), defaulting to the shared
:data:`SYSTEM_CLOCK` singleton.  That indirection is what makes the
flight recorder (``repro.obs.flight``) possible — a recording run wraps
the clock to log every observation, and a replay run substitutes a
:class:`ReplayClock` that feeds the recorded timestamps back verbatim,
so every controller input (inter-token gaps, deadline sweeps, EWMA
updates) is bit-identical to the recorded incident."""
from __future__ import annotations

import time
from typing import Optional, Sequence

# captured once at import: the (approximate, NTP-drift-affected) offset
# between the monotonic clock and the wall clock
_WALL_OFFSET = time.time() - time.monotonic()


def now() -> float:
    """Monotonic seconds — THE serving timestamp source."""
    return time.monotonic()


def to_wall(t_mono: float) -> float:
    """Approximate wall-clock epoch seconds for a :func:`now` timestamp
    (human-facing logs only; durations must subtract monotonic stamps)."""
    return t_mono + _WALL_OFFSET


class SystemClock:
    """The live clock: every ``now(site)`` is a fresh monotonic read.
    ``site`` is a call-site tag (e.g. ``"decode.t1"``) that the flight
    recorder logs next to each observation so a replay divergence names
    the exact consuming site; the live clock ignores it."""

    __slots__ = ()

    def now(self, site: str = "") -> float:
        return time.monotonic()


# the shared default — engines constructed without an explicit clock use
# this exact object, so the clock-off path is `is`-identity testable
# (same standard as NULL_TELEMETRY / NULL_CONTEXT)
SYSTEM_CLOCK = SystemClock()


class ReplayDivergence(RuntimeError):
    """Replay consumed the recording differently than the live run:
    the engine asked for a clock read where the recording holds a
    different record kind (or no record at all), or the consuming call
    site changed.  ``detail`` is the structured first-divergence report
    (record index, expected vs got) the replay CLI prints."""

    def __init__(self, message: str, detail: Optional[dict] = None):
        super().__init__(message)
        self.detail = detail or {}


class ReplayClock:
    """Feeds recorded timestamps back to the engine, positionally.

    Holds the recording's ordered *input* records (clock reads and
    request submissions, as loaded by ``repro.obs.flight``) and a shared
    cursor: the replay driver advances the cursor over ``submit``
    records (re-issuing each submission), and every engine clock read
    consumes the ``clock`` record at the cursor.  Because the engine is
    deterministic given its submissions and clock observations, feeding
    both back in recorded order reproduces every decision bit-exactly.

    Any mismatch — the engine reads the clock where the recording has a
    submission, reads past the end, or reads from a different call site
    than the recorded one — raises :class:`ReplayDivergence` with a
    structured detail dict instead of silently desynchronizing."""

    def __init__(self, inputs: Sequence[dict]):
        self.inputs = list(inputs)
        self.cursor = 0

    @property
    def exhausted(self) -> bool:
        return self.cursor >= len(self.inputs)

    def peek(self) -> Optional[dict]:
        if self.exhausted:
            return None
        return self.inputs[self.cursor]

    def now(self, site: str = "") -> float:
        rec = self.peek()
        if rec is None:
            raise ReplayDivergence(
                f"replay clock exhausted: the engine read the clock at "
                f"site {site!r} but all {len(self.inputs)} recorded "
                f"inputs are already consumed",
                detail={"record": self.cursor, "expected": None,
                        "got": {"k": "clock", "s": site}})
        if rec.get("k") != "clock":
            raise ReplayDivergence(
                f"replay desynchronized at record {self.cursor}: the "
                f"engine read the clock at site {site!r} but the "
                f"recording holds a {rec.get('k')!r} record there",
                detail={"record": self.cursor, "expected": rec,
                        "got": {"k": "clock", "s": site}})
        want = rec.get("s", "")
        if want and site and want != site:
            raise ReplayDivergence(
                f"replay desynchronized at record {self.cursor}: clock "
                f"read from site {site!r} but the recording's read came "
                f"from {want!r}",
                detail={"record": self.cursor, "expected": rec,
                        "got": {"k": "clock", "s": site}})
        self.cursor += 1
        return float(rec["t"])
