"""One monotonic clock for the whole serving stack.

Every serving timestamp — engine stats, controller ticks, spans, events,
JSONL snapshots, benchmark replay — routes through :func:`now`, so the
different telemetry streams are mutually orderable.  Historically the
engine used ``time.monotonic()`` while the serve CLI timed runs with
``time.time()``; a span at monotonic ``t`` and a log line at epoch ``t'``
could not be correlated.  :func:`to_wall` maps a monotonic timestamp to
approximate epoch seconds for human-facing output only — never compare
``to_wall`` results across processes or use them for durations."""
from __future__ import annotations

import time

# captured once at import: the (approximate, NTP-drift-affected) offset
# between the monotonic clock and the wall clock
_WALL_OFFSET = time.time() - time.monotonic()


def now() -> float:
    """Monotonic seconds — THE serving timestamp source."""
    return time.monotonic()


def to_wall(t_mono: float) -> float:
    """Approximate wall-clock epoch seconds for a :func:`now` timestamp
    (human-facing logs only; durations must subtract monotonic stamps)."""
    return t_mono + _WALL_OFFSET
