"""``repro.obs`` — unified, zero-overhead-when-disabled engine telemetry.

Four surfaces behind one :class:`Telemetry` facade threaded through the
serving stack (``Engine(..., telemetry=...)``):

* **metrics** (:mod:`repro.obs.metrics`) — Counter/Gauge/Histogram with
  fixed log-spaced buckets, Prometheus text exposition adapted from the
  engine's live :class:`~repro.serving.metrics.EngineStats`, an optional
  stdlib ``/metrics`` endpoint, and the shared exposition validator;
* **tracing** (:mod:`repro.obs.trace`) — per-request span timelines in
  Chrome trace-event JSON, loadable in Perfetto;
* **events** (:mod:`repro.obs.events`) — structured ring-buffered event
  log (rung switches with reasons, gamma changes, prefix evictions, KV
  rollbacks, compile/retrace records) with an optional JSONL sink;
* **profiler** (:mod:`repro.obs.profiler`) — JAX dispatch annotations
  and an opt-in ``jax.profiler`` capture window;
* **quality** (:mod:`repro.obs.quality`) — live sparsity-quality probes:
  shadow dense probes, online Eq. 6 reconstruction error vs calibration
  baselines, saliency-drift detection, per-rung roofline counters.

The default engine configuration uses :data:`NULL_TELEMETRY`: every
surface is ``None``, every hot-path emit site is an ``is not None``
check, and :meth:`Telemetry.annotate` returns a shared reusable null
context — the disabled path allocates nothing.

Clock discipline: all serving timestamps come from :func:`now`
(monotonic; :mod:`repro.obs.clock`), so spans, events, stats, and
snapshots are mutually orderable; :func:`to_wall` converts for
human-facing output only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.clock import (SYSTEM_CLOCK, ReplayClock, ReplayDivergence,
                             SystemClock, now, to_wall)
from repro.obs.events import EventLog
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               engine_exposition, engine_registry,
                               log_buckets, parse_exposition, serve_metrics,
                               validate_exposition)
from repro.obs.profiler import NULL_CONTEXT, ProfilerSession, annotation
from repro.obs.quality import QualityConfig, QualityMonitor
from repro.obs.trace import SpanTracer, validate_chrome_trace


@dataclasses.dataclass
class Telemetry:
    """Per-engine telemetry bundle.  Any surface may be ``None`` (off);
    the all-``None`` default is :data:`NULL_TELEMETRY` and costs nothing
    on the hot path.

    ``annotate_dispatch`` arms per-dispatch
    ``jax.profiler.TraceAnnotation`` labels; ``profiler`` is an opt-in
    capture-window session the driver starts/stops around the region it
    wants profiled."""

    tracer: Optional[SpanTracer] = None
    events: Optional[EventLog] = None
    annotate_dispatch: bool = False
    profiler: Optional[ProfilerSession] = None
    # when set (and a tracer is armed), close() exports the Chrome trace
    # JSON here — so Engine.close() flushes *every* sink, even when the
    # driving loop raised
    trace_sink: Optional[str] = None
    # sparsity-quality probes (repro.obs.quality): shadow dense probes,
    # online reconstruction error, saliency drift, roofline counters.
    # Armed by Engine.warmup(); None (the default) keeps the engine's
    # quality path to a single `is not None` check per decode step.
    quality: Optional[QualityMonitor] = None
    # flight recorder (repro.obs.flight): deterministic capture of the
    # engine's nondeterministic inputs (submissions + clock reads) and
    # resulting decisions for bit-identical incident replay.  The engine
    # attaches it at construction (wrapping its injected clock); None
    # keeps every emit site to an `is not None` check.
    flight: Optional[FlightRecorder] = None

    @property
    def enabled(self) -> bool:
        return (self.tracer is not None or self.events is not None
                or self.annotate_dispatch or self.profiler is not None
                or self.quality is not None or self.flight is not None)

    def annotate(self, name: str):
        """Context manager for one dispatch: a profiler TraceAnnotation
        when armed, the shared null context (no allocation) otherwise."""
        if not self.annotate_dispatch:
            return NULL_CONTEXT
        return annotation(name)

    @classmethod
    def full(cls, events_sink=None, profile_dir: Optional[str] = None,
             event_capacity: int = 4096,
             quality: Optional[QualityConfig] = None) -> "Telemetry":
        """Everything on: tracer + event log (+ optional JSONL sink) +
        dispatch annotations (+ a capture session when ``profile_dir``
        is given, left for the caller to start; + quality probes when a
        :class:`QualityConfig` is given)."""
        return cls(
            tracer=SpanTracer(),
            events=EventLog(capacity=event_capacity, sink=events_sink),
            annotate_dispatch=True,
            profiler=ProfilerSession(profile_dir) if profile_dir else None,
            quality=QualityMonitor(quality) if quality is not None else None)

    def close(self) -> None:
        """Flush and close every armed sink.  Idempotent: profiler stop,
        event-log close and trace re-export all tolerate repeat calls."""
        if self.profiler is not None:
            self.profiler.stop()
        if self.tracer is not None and self.trace_sink is not None:
            self.tracer.export(self.trace_sink)
        if self.events is not None:
            self.events.close()
        if self.flight is not None:
            self.flight.close()


NULL_TELEMETRY = Telemetry()

__all__ = [
    "Telemetry", "NULL_TELEMETRY", "now", "to_wall",
    "SystemClock", "SYSTEM_CLOCK", "ReplayClock", "ReplayDivergence",
    "SpanTracer", "validate_chrome_trace",
    "EventLog", "FlightRecorder",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "log_buckets",
    "engine_registry", "engine_exposition", "parse_exposition",
    "validate_exposition", "serve_metrics",
    "ProfilerSession", "annotation", "NULL_CONTEXT",
    "QualityConfig", "QualityMonitor",
]
