"""Prometheus-style metrics: Counter/Gauge/Histogram instruments, a
registry rendering the text exposition format, and the adapter that
exposes a serving :class:`~repro.serving.engine.Engine`'s live stats.

The :class:`Histogram` is the load-bearing piece: fixed log-spaced
buckets observed in O(log buckets) per sample, with *whole-run* exact
``count``/``sum``/per-bucket counts at any run length.  That fixes two
long-standing metrics bugs at once:

* ring-buffer percentiles silently become *windowed* estimates once a
  series outgrows its 4096-sample capacity — wrong for long-run p95
  gates (the histogram never drops a sample; its quantiles are exact up
  to bucket resolution);
* ``percentile(RingBuffer)`` re-sorts the full ring on every
  ``summary()``/``snapshot()`` call (O(n log n) per snapshot) — the
  histogram quantile walks the cumulative bucket counts, O(buckets).

Rendering is snapshot-style: :func:`engine_registry` builds a fresh
registry from the engine's live counters at scrape time (off the hot
path), registering the engine's *live* histogram objects directly so
bucket counts are never copied.  Counter monotonicity in the exposition
follows from the underlying stats counters being append-only.

``validate_exposition`` is the parser the tests and the CI artifact
check share: it asserts the text parses, counters are non-negative, and
every histogram's ``+Inf`` bucket equals its ``_count`` with monotone
cumulative buckets.
"""
from __future__ import annotations

import math
import re
import threading
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple


def log_buckets(lo: float = 1e-5, hi: float = 10.0,
                per_decade: int = 5) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds covering [lo, hi]."""
    if not 0 < lo < hi:
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


# 10 microseconds .. 10 seconds, 5 buckets per decade: resolves a
# sub-millisecond decode step and a multi-second cold prefill with the
# same fixed 31-bucket layout (fixed = every engine's histograms are
# mergeable and the exposition cardinality is bounded)
DEFAULT_LATENCY_BUCKETS = log_buckets(1e-5, 10.0, per_decade=5)


class Counter:
    """Monotone counter (float-valued; Prometheus counter semantics)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up, got inc({v})")
        self.value += v


class Gauge:
    """Set-anywhere instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with exact whole-run aggregates.

    ``bounds`` are the bucket *upper* bounds (``le`` in the exposition);
    an implicit +Inf bucket catches overflow.  ``observe`` is one bisect
    plus three increments — cheap enough to run unconditionally next to
    the engine's ring buffers.  ``quantile`` is exact at bucket
    resolution over the whole run (it never windows), reporting the
    selected bucket's upper bound."""

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: Optional[Sequence[float]] = None):
        bs = tuple(DEFAULT_LATENCY_BUCKETS if bounds is None else bounds)
        if not bs or list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(
                f"bounds must be non-empty and strictly increasing: {bs}")
        self.bounds = bs
        self.counts = [0] * (len(bs) + 1)      # last slot = +Inf overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v

    def __bool__(self) -> bool:
        return self.count > 0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative(self) -> List[int]:
        """Cumulative bucket counts (exposition ``le`` semantics; the
        final entry equals ``count``)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, p: float) -> float:
        """Nearest-rank percentile (``p`` in [0, 100], matching
        :func:`repro.serving.metrics.percentile`'s rank convention) at
        bucket resolution: returns the selected bucket's *upper* bound —
        conservative (never under-reports a latency percentile), and
        exact when bounds are the observable values themselves (e.g.
        unit-width integer buckets).  O(buckets)."""
        if not self.count:
            return float("nan")
        rank = max(1, min(self.count,
                          int(round(p / 100.0 * (self.count - 1))) + 1))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                # overflow bucket: clamp to the last finite bound
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class MetricsRegistry:
    """Ordered name -> instrument mapping with text-exposition rendering.

    Instruments can be created by the registry (``counter``/``gauge``/
    ``histogram``) or attached (``register_histogram``) so a live,
    externally-owned histogram — e.g. one inside ``EngineStats`` — is
    rendered without copying its buckets."""

    def __init__(self):
        self._metrics: Dict[str, Tuple[str, str, object]] = {}

    def _add(self, name: str, kind: str, help_: str, inst):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        if name in self._metrics:
            prev_kind, _, prev = self._metrics[name]
            if prev_kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev_kind}")
            return prev
        self._metrics[name] = (kind, help_, inst)
        return inst

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._add(name, "counter", help_, Counter())

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._add(name, "gauge", help_, Gauge())

    def histogram(self, name: str, help_: str = "",
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._add(name, "histogram", help_, Histogram(bounds))

    def register_histogram(self, name: str, hist: Histogram,
                           help_: str = "") -> Histogram:
        return self._add(name, "histogram", help_, hist)

    @staticmethod
    def _fmt(v: float) -> str:
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(float(v))

    def render(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        lines: List[str] = []
        for name, (kind, help_, inst) in self._metrics.items():
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            if kind in ("counter", "gauge"):
                lines.append(f"{name} {self._fmt(inst.value)}")
            else:
                cum = inst.cumulative()
                for bound, c in zip(inst.bounds, cum):
                    lines.append(
                        f'{name}_bucket{{le="{self._fmt(bound)}"}} {c}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {inst.count}')
                lines.append(f"{name}_sum {self._fmt(inst.sum)}")
                lines.append(f"{name}_count {inst.count}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# engine adapter
# ---------------------------------------------------------------------------

def engine_registry(engine) -> MetricsRegistry:
    """Snapshot registry over a live engine's stats (duck-typed: anything
    with ``.stats``/``.scheduler``/``.pool`` shaped like the serving
    engine).  Built fresh per scrape — cheap, and off the hot path."""
    reg = MetricsRegistry()
    s = engine.stats

    def c(name, value, help_=""):
        reg.counter(name, help_).inc(value)

    def g(name, value, help_=""):
        reg.gauge(name, help_).set(value)

    c("repro_requests_submitted_total", s.submitted, "requests accepted")
    c("repro_requests_finished_total", s.finished, "requests completed")
    c("repro_prefill_chunks_total", s.prefill_chunks, "prefill steps run")
    c("repro_prefill_tokens_total", s.prefill_tokens,
      "real (non-pad) prompt tokens prefilled")
    c("repro_decode_steps_total", s.decode_steps, "batched decode steps")
    c("repro_decode_tokens_total", s.decode_tokens, "generated tokens")
    c("repro_prefill_seconds_total", s.prefill_time,
      "seconds spent in prefill steps")
    c("repro_decode_seconds_total", s.decode_time,
      "seconds spent in decode steps")
    g("repro_queue_depth", engine.scheduler.queue_depth,
      "requests waiting for a slot")
    g("repro_slot_occupancy", engine.pool.num_occupied, "occupied KV slots")
    g("repro_rung", engine.rung, "active ladder rung (0 = densest)")
    retr = engine.decode_retraces_after_warmup
    if retr is not None:
        c("repro_decode_retraces_after_warmup_total", retr,
          "decode executable (re)traces since warmup (invariant: 0)")

    reg.register_histogram("repro_tpot_seconds", s.tpot_hist,
                           "inter-token latency (whole-run, exact)")
    reg.register_histogram("repro_ttft_seconds", s.ttft_hist,
                           "time to first token (whole-run, exact)")
    reg.register_histogram("repro_decode_step_seconds", s.decode_step_hist,
                           "batched decode step latency")
    reg.register_histogram("repro_prefill_step_seconds", s.prefill_step_hist,
                           "prefill step latency")

    if s.spec_rounds:
        c("repro_spec_rounds_total", s.spec_rounds, "draft+verify rounds")
        c("repro_spec_draft_tokens_total", s.spec_draft_tokens,
          "drafted tokens")
        c("repro_spec_accepted_tokens_total", s.spec_accepted_tokens,
          "drafts surviving verification")
        c("repro_spec_committed_tokens_total", s.spec_committed_tokens,
          "tokens emitted by spec rounds (incl. bonus)")
        reg.register_histogram("repro_spec_draft_seconds", s.spec_draft_hist,
                               "per-round draft phase latency")
        reg.register_histogram("repro_spec_verify_seconds",
                               s.spec_verify_hist,
                               "per-round verify forward latency")
        reg.register_histogram("repro_spec_accepted_per_verify",
                               s.spec_accepted_hist,
                               "accepted draft tokens per slot per verify")
    if s.prefix_lookups:
        c("repro_prefix_lookups_total", s.prefix_lookups,
          "admissions that consulted the prefix cache")
        c("repro_prefix_hits_total", s.prefix_hits,
          "admissions that reused cached KV")
        c("repro_prefix_tokens_saved_total", s.prefix_tokens_saved,
          "prompt tokens not re-prefilled")
        c("repro_prefix_evicted_segments_total", s.prefix_evicted_segments,
          "segments dropped by LRU eviction")
    if engine.prefix_cache is not None:
        g("repro_prefix_cached_tokens", engine.prefix_cache.cached_tokens,
          "physical tokens held by the prefix cache")
        g("repro_prefix_segments", engine.prefix_cache.num_segments,
          "payload segments in the radix tree")
    if getattr(engine.ecfg, "scheduler", None) is not None:
        g("repro_suspended_requests", len(engine.scheduler.suspended),
          "preempted requests holding KV state on the host")
        c("repro_preemptions_total", s.preemptions,
          "decoding requests suspended to admit higher-priority work")
        c("repro_resumes_total", s.resumes,
          "suspended requests restored into a slot")
        c("repro_requests_rejected_total", s.rejected,
          "submissions refused with queue-full backpressure")
        c("repro_requests_expired_total", s.expired,
          "queued requests dropped at their queue-wait deadline")
        reg.register_histogram("repro_queue_wait_seconds", s.queue_wait_hist,
                               "seconds queued before admission")
        reg.register_histogram("repro_preempted_seconds", s.preempted_hist,
                               "seconds suspended before resume")
    fr = getattr(getattr(engine, "obs", None), "flight", None)
    if fr is not None:
        c("repro_flight_records_total", fr.count,
          "records captured by the flight recorder")
        c("repro_flight_dropped_total", fr.dropped,
          "records evicted from the flight ring (0 = ring-replayable)")
        c("repro_flight_dumps_total", len(fr.dumps),
          "triggered black-box dumps written")
    q = getattr(getattr(engine, "obs", None), "quality", None)
    if q is not None and q.armed:
        # per-rung families are name-suffixed: the registry renders
        # label-free samples, and rung cardinality is small and fixed
        c("repro_quality_probes_total", q.probes,
          "decode steps shadow-probed against the dense reference")
        c("repro_quality_probe_tokens_total", q.probe_tokens,
          "tokens compared by shadow probes")
        c("repro_quality_recon_passes_total", q.recon_passes,
          "online block-reconstruction evaluations")
        c("repro_quality_drift_events_total", q.drift_events,
          "saliency-drift threshold crossings")
        g("repro_quality_pressure", q.pressure,
          "active-rung saliency-drift pressure in [0, 1]")
        for r in range(len(q.agreement_hists)):
            reg.register_histogram(
                f"repro_quality_probe_agreement_rung{r}",
                q.agreement_hists[r],
                f"probe argmax-token agreement vs dense, rung {r}")
            reg.register_histogram(
                f"repro_quality_topk_overlap_rung{r}", q.overlap_hists[r],
                f"probe top-k logit-set overlap vs dense, rung {r}")
            reg.register_histogram(
                f"repro_quality_recon_error_rung{r}", q.recon_hists[r],
                f"online Eq.6 block reconstruction MSE, rung {r}")
            base = q.recon_baseline_mean(r)
            if base is not None:
                g(f"repro_quality_recon_baseline_rung{r}", base,
                  f"calibration-time mean block reconstruction MSE, "
                  f"rung {r}")
        for (phase, r), cost in sorted(q.roofline.items()):
            g(f"repro_quality_roofline_flops_{phase}_rung{r}",
              cost["flops"], f"executable FLOPs, {phase} at rung {r}")
            g(f"repro_quality_roofline_bytes_{phase}_rung{r}",
              cost["bytes"],
              f"executable bytes accessed, {phase} at rung {r}")
        step_mean = s.decode_step_hist.mean if s.decode_step_hist else 0.0
        for r, util in sorted(q.decode_utilization(step_mean).items()):
            g(f"repro_quality_decode_utilization_rung{r}", util,
              f"roofline step time over measured mean decode step, "
              f"rung {r}")
    return reg


def engine_exposition(engine) -> str:
    """Prometheus text exposition for a live engine (one scrape)."""
    return engine_registry(engine).render()


# ---------------------------------------------------------------------------
# exposition validation (shared by tests and the CI artifact check)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$')


def parse_exposition(text: str):
    """Parse exposition text into ``(types, samples)`` where ``types``
    maps metric name -> declared type and ``samples`` is a list of
    ``(name, labels_dict, value)``."""
    types: Dict[str, str] = {}
    samples: List[Tuple[str, Dict[str, str], float]] = []
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: unparseable sample {line!r}")
        labels = {}
        if m.group("labels"):
            for part in m.group("labels").split(","):
                k, _, v = part.partition("=")
                labels[k.strip()] = v.strip().strip('"')
        samples.append((m.group("name"), labels, float(m.group("value"))))
    return types, samples


def validate_exposition(text: str) -> int:
    """Assert the exposition text is well-formed: every sample belongs to
    a declared metric family, counters/gauges are finite (counters
    non-negative), and each histogram has monotone cumulative buckets
    whose ``+Inf`` entry equals its ``_count``.  Returns the number of
    samples checked; raises ``ValueError`` on any violation."""
    types, samples = parse_exposition(text)
    if not samples:
        raise ValueError("no samples in exposition")
    hist: Dict[str, Dict[str, float]] = {}
    buckets: Dict[str, List[Tuple[float, float]]] = {}
    for name, labels, value in samples:
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types \
                    and types[name[:-len(suffix)]] == "histogram":
                base = name[:-len(suffix)]
                break
        if base not in types:
            raise ValueError(f"sample {name!r} has no # TYPE declaration")
        kind = types[base]
        if not math.isfinite(value):
            raise ValueError(f"{name}: non-finite value {value}")
        if kind == "counter" and value < 0:
            raise ValueError(f"{name}: negative counter {value}")
        if kind == "histogram":
            h = hist.setdefault(base, {})
            if name.endswith("_bucket"):
                le = labels.get("le")
                bound = math.inf if le == "+Inf" else float(le)
                buckets.setdefault(base, []).append((bound, value))
            elif name.endswith("_sum"):
                h["sum"] = value
            elif name.endswith("_count"):
                h["count"] = value
            else:
                raise ValueError(f"bare sample {name!r} for histogram")
    for base, bs in buckets.items():
        bs.sort(key=lambda bv: bv[0])
        cum = [v for _, v in bs]
        if any(b > a for a, b in zip(cum[1:], cum)):
            raise ValueError(f"{base}: cumulative buckets not monotone")
        if not bs or bs[-1][0] != math.inf:
            raise ValueError(f"{base}: missing +Inf bucket")
        h = hist.get(base, {})
        if "count" not in h or "sum" not in h:
            raise ValueError(f"{base}: missing _sum/_count")
        if bs[-1][1] != h["count"]:
            raise ValueError(
                f"{base}: +Inf bucket {bs[-1][1]} != count {h['count']}")
    return len(samples)


# ---------------------------------------------------------------------------
# optional stdlib /metrics endpoint
# ---------------------------------------------------------------------------

def serve_metrics(render_fn, port: int = 0, host: str = "127.0.0.1"):
    """Start a daemon-thread ``http.server`` exposing ``render_fn()`` at
    ``/metrics`` (and ``/``).  Returns the live ``HTTPServer`` — read
    ``server_port`` for the bound port (``port=0`` picks one), call
    ``shutdown()`` to stop."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                      # noqa: N802 (stdlib API)
            if self.path not in ("/metrics", "/"):
                self.send_error(404)
                return
            body = render_fn().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):             # quiet: no per-scrape stderr
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="repro-metrics")
    thread.start()
    return server
