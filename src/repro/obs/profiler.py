"""JAX profiler integration: dispatch annotations + capture windows.

Two layers, both opt-in:

* :func:`annotation` — a ``jax.profiler.TraceAnnotation`` labelling the
  host-side dispatch of one executable (decode, chunk, verify, prefix
  extract/write) so engine phases show up as named slices in a captured
  profile.  When telemetry is off the engine gets the shared
  :data:`NULL_CONTEXT` instead — a reusable, reentrant
  ``contextlib.nullcontext`` (no allocation on the hot path).
  Annotations wrap the *dispatch*, never the traced function, so they
  cannot perturb jit cache keys — ``decode_retraces_after_warmup == 0``
  holds with annotations enabled (tested).

* :class:`ProfilerSession` — an explicit capture window around
  ``jax.profiler.start_trace``/``stop_trace`` writing a TensorBoard-
  loadable profile to a directory.  Wrapped defensively: profile
  capture depends on optional runtime pieces (libtpu / profiler plugin),
  and a missing one must degrade to a warning, not kill serving.
"""
from __future__ import annotations

import contextlib
from typing import Optional

NULL_CONTEXT = contextlib.nullcontext()


def annotation(name: str):
    """A profiler trace annotation context for one dispatch."""
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)


class ProfilerSession:
    """Opt-in profiler capture window writing to ``out_dir``.

    ``start()``/``stop()`` are idempotent and swallow profiler-backend
    errors (recorded on ``.error``) — telemetry must never take down the
    engine it observes."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.active = False
        self.error: Optional[str] = None

    def start(self) -> bool:
        if self.active:
            return True
        try:
            import jax.profiler
            jax.profiler.start_trace(self.out_dir)
        except Exception as e:                      # noqa: BLE001
            self.error = f"start_trace failed: {e}"
            return False
        self.active = True
        return True

    def stop(self) -> bool:
        if not self.active:
            return False
        self.active = False
        try:
            import jax.profiler
            jax.profiler.stop_trace()
        except Exception as e:                      # noqa: BLE001
            self.error = f"stop_trace failed: {e}"
            return False
        return True

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
