"""Bit-identical replay of a flight recording.

    PYTHONPATH=src python -m repro.obs.flight.replay dump.jsonl

:func:`replay` re-drives a *fresh* engine from a recording: a
:class:`~repro.obs.clock.ReplayClock` feeds every recorded clock
observation back verbatim, the driver re-issues every recorded
submission in order, and the replay engine records its own flight
stream — which must match the recording record for record.  Gates:

* whole-trace token bit-identity (every ``finish`` record's tokens),
* matching rung residency (every ``finish`` record's ``token_rungs``),
* identical decision stream (rung/gamma/drafter switches, preemptions,
  resumes, rejects, evictions — same order, same fields),
* zero post-warmup retraces (decode / verify / probe / segment),
* the recording fully consumed (no leftover inputs, engine idle).

On failure the report carries a structured first-divergence diff —
for a token mismatch: request id, first differing token index, and the
rung delta at that index; otherwise: the first differing record index
with both sides.  The CLI prints the report as JSON and exits nonzero.

Engine reconstruction: the CLI rebuilds the engine from the recording's
header — ``meta.arch``/``meta.reduced``/``meta.seed`` re-init the
params, ``meta.ladder_path`` reloads the ladder npz (fingerprint-
checked against the recording), and the serialized ``ecfg`` restores
the engine config.  Library callers with exotic setups (calibrated
policies not load-able from an artifact) pass ``engine_factory``
instead: a callable ``(clock, telemetry) -> Engine``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Callable, List, Optional

from repro.obs.clock import ReplayClock, ReplayDivergence
from repro.obs.flight import (FLIGHT_SCHEMA_VERSION, FlightRecorder,
                              ladder_fingerprint)

# record kinds that drive replay (inputs) vs those verified against it
_INPUT_KINDS = ("clock", "submit")


@dataclasses.dataclass
class Recording:
    """A parsed flight recording: the header plus the ordered records
    (header/dump/end framing stripped)."""
    header: dict
    records: List[dict]

    @property
    def inputs(self) -> List[dict]:
        return [r for r in self.records if r.get("k") in _INPUT_KINDS]


@dataclasses.dataclass
class ReplayReport:
    """Outcome of one replay.  ``ok`` is the conjunction of every gate;
    ``failures`` names the broken ones; ``divergence`` is the
    structured first-divergence diff (None when identical)."""
    ok: bool
    failures: List[str]
    divergence: Optional[dict]
    requests: int
    tokens: int
    records_compared: int
    retraces: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def load_recording(path: str) -> Recording:
    """Parse a flight JSONL file (full sink or triggered ring dump).
    Refuses dumps whose ring overflowed — an incomplete history cannot
    be replayed — and recordings from a different flight schema."""
    with open(path) as f:
        records = [json.loads(ln) for ln in f if ln.strip()]
    if not records:
        raise ValueError(f"{path}: empty flight recording")
    if records[0].get("k") == "dump":
        prologue, records = records[0], records[1:]
        if not prologue.get("complete"):
            raise ValueError(
                f"{path}: ring dump is incomplete ({prologue['count']} "
                f"records recorded, {prologue['retained']} retained) — "
                "replay needs the full history; arm a JSONL sink "
                "(--flight-record PATH) or a larger --flight-ring")
    if records and records[-1].get("k") == "end":
        records = records[:-1]
    if not records or records[0].get("k") != "header":
        raise ValueError(
            f"{path}: not a flight recording (no header record)")
    header = records[0]
    version = header.get("flight_schema_version")
    if version != FLIGHT_SCHEMA_VERSION:
        raise ValueError(
            f"{path}: flight schema v{version} != supported "
            f"v{FLIGHT_SCHEMA_VERSION}")
    return Recording(header=header, records=records[1:])


# ---------------------------------------------------------------------------
# engine reconstruction from the header
# ---------------------------------------------------------------------------

def engine_factory_from_header(header: dict) -> Callable:
    """Build a ``(clock, telemetry) -> Engine`` factory from a
    recording's header.  Covers engines the serve CLI / benchmarks can
    construct: synthetic-init params (arch + seed) with an optional
    ladder npz; fixed-policy engines must prefill/decode dense (a
    calibrated non-dense fixed policy needs a caller factory)."""
    from repro.configs import get_config, reduced
    from repro.models import api
    from repro.serving.controller import SLOConfig
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.scheduler import SchedulerConfig
    from repro.serving.spec import SpecConfig
    from repro.sparsity import PolicyLadder

    meta = header.get("meta", {})
    if "arch" not in meta:
        raise ValueError(
            "recording header has no meta.arch — re-record with "
            "reconstruction metadata, or call replay() with an explicit "
            "engine_factory")
    cfg = get_config(meta["arch"])
    if meta.get("reduced", True):
        cfg = reduced(cfg)
    params = api.init_model(cfg, meta.get("seed", 0))

    ladder = None
    if header.get("ladder_fingerprint") is not None:
        path = meta.get("ladder_path")
        if not path:
            raise ValueError(
                "recording used a ladder but meta.ladder_path is unset — "
                "pass an engine_factory that rebuilds it")
        ladder = PolicyLadder.load(path)
        got = ladder_fingerprint(ladder)
        want = header["ladder_fingerprint"]
        if got != want:
            raise ValueError(
                f"ladder artifact {path} fingerprint {got} != recorded "
                f"{want}: the artifact changed since the recording")

    e = dict(header["ecfg"])
    if ladder is None and not e.pop("policy_dense", True):
        raise ValueError(
            "recording used a non-dense fixed policy, which the header "
            "cannot reconstruct — pass an engine_factory")
    e.pop("policy_dense", None)
    for name, cls in (("slo", SLOConfig), ("spec", SpecConfig),
                      ("scheduler", SchedulerConfig)):
        if e.get(name) is not None:
            # JSON round-trip turns tuples into lists; the configs are
            # tuple-typed, possibly nested (and the config fingerprint
            # hashes reprs)
            def detuple(v):
                return tuple(detuple(x) for x in v) \
                    if isinstance(v, list) else v
            e[name] = cls(**{k: detuple(v) for k, v in e[name].items()})
    ecfg = EngineConfig(**e)

    def factory(clock, telemetry):
        return Engine(params, cfg, ecfg, None, ladder=ladder,
                      telemetry=telemetry, clock=clock)

    return factory


# ---------------------------------------------------------------------------
# divergence diffing
# ---------------------------------------------------------------------------

def _first_divergence(recorded: List[dict],
                      replayed: List[dict]) -> Optional[dict]:
    """Record-by-record diff; token mismatches get the request-level
    deep diff (request id, token index, rung delta)."""
    n = min(len(recorded), len(replayed))
    for i in range(n):
        a, b = recorded[i], replayed[i]
        if a == b:
            continue
        out = {"record": i, "recorded": a, "replayed": b}
        if a.get("k") == "finish" and b.get("k") == "finish" \
                and a.get("request") == b.get("request"):
            ta, tb = a.get("tokens", []), b.get("tokens", [])
            ra, rb = a.get("token_rungs", []), b.get("token_rungs", [])
            idx = next((j for j in range(min(len(ta), len(tb)))
                        if ta[j] != tb[j]), min(len(ta), len(tb)))
            out.update({
                "request": a["request"], "token_index": idx,
                "recorded_token": ta[idx] if idx < len(ta) else None,
                "replayed_token": tb[idx] if idx < len(tb) else None,
                "recorded_rung": ra[idx] if idx < len(ra) else None,
                "replayed_rung": rb[idx] if idx < len(rb) else None,
            })
        return out
    if len(recorded) != len(replayed):
        i = n
        return {"record": i,
                "recorded": recorded[i] if i < len(recorded) else None,
                "replayed": replayed[i] if i < len(replayed) else None}
    return None


def _retraces(engine) -> dict:
    return {k: v for k, v in (
        ("decode", engine.decode_retraces_after_warmup),
        ("verify", engine.verify_retraces_after_warmup),
        ("probe", engine.probe_retraces_after_warmup),
        ("segment", engine.segment_retraces_after_warmup),
    ) if v is not None}


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------

def replay(recording, engine_factory: Optional[Callable] = None,
           ) -> ReplayReport:
    """Re-drive a fresh engine from ``recording`` (a path or a
    :class:`Recording`) and gate bit-identity.

    ``engine_factory(clock, telemetry) -> Engine`` builds the replay
    engine — it must pass both arguments through to the Engine
    constructor and arm no other nondeterministic telemetry.  When
    None, the factory is reconstructed from the recording header."""
    from repro.obs import Telemetry
    from repro.serving.scheduler import QueueFull

    if not isinstance(recording, Recording):
        recording = load_recording(recording)
    if engine_factory is None:
        engine_factory = engine_factory_from_header(recording.header)

    inputs = recording.inputs
    clock = ReplayClock(inputs)
    mirror = FlightRecorder(capacity=len(recording.records) + 64)
    engine = engine_factory(clock, Telemetry(flight=mirror))

    failures: List[str] = []
    divergence: Optional[dict] = None
    try:
        if engine._warm_traces is None:
            engine.warmup()
        while not clock.exhausted:
            rec = clock.peek()
            if rec["k"] == "submit":
                clock.cursor += 1
                try:
                    engine.submit(
                        rec["prompt"], rec["max_new_tokens"],
                        eos_id=rec["eos_id"],
                        arrival_time=rec["arrival_time"],
                        priority=rec["priority"], tenant=rec["tenant"],
                        queue_deadline_s=rec["queue_deadline_s"])
                except QueueFull:
                    pass            # the recorded run was rejected too —
                #                     the mirrored reject decision proves it
            else:
                # a clock record at the cursor belongs to the next
                # engine step; step() consumes it (and its successors)
                # through the ReplayClock
                engine.step()
        # recorded streams end at an idle engine (close() flushes after
        # the driving loop); drain any deterministic leftovers — none
        # read the clock once the inputs are exhausted, or the
        # ReplayClock raises
        while engine.scheduler.has_work():
            engine.step()
    except ReplayDivergence as e:
        failures.append(f"desynchronized: {e}")
        divergence = e.detail or None
    finally:
        engine.close()

    # fingerprint gates: same config, same params, same ladder content
    for key in ("config_fingerprint", "params_fingerprint",
                "ladder_fingerprint"):
        if mirror._header is not None \
                and recording.header.get(key) != mirror._header.get(key):
            failures.append(
                f"{key} mismatch: recorded "
                f"{recording.header.get(key)} != replayed "
                f"{mirror._header.get(key)}")

    if not clock.exhausted and not failures:
        failures.append(
            f"replay stalled: {len(inputs) - clock.cursor} recorded "
            f"inputs left unconsumed at record {clock.cursor}")
    if engine.scheduler.has_work():
        failures.append("replay engine not idle after the recording")

    replayed = mirror.records()[1:]         # drop the header record
    if divergence is None:
        divergence = _first_divergence(recording.records, replayed)
        if divergence is not None:
            failures.append(
                f"stream divergence at record {divergence['record']}")

    retr = _retraces(engine)
    if any(v != 0 for v in retr.values()):
        failures.append(f"post-warmup retraces: {retr}")

    finishes = [r for r in recording.records if r.get("k") == "finish"]
    return ReplayReport(
        ok=not failures, failures=failures, divergence=divergence,
        requests=len(finishes),
        tokens=sum(len(r.get("tokens", ())) for r in finishes),
        records_compared=min(len(recording.records), len(replayed)),
        retraces=retr)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.flight.replay",
        description="Re-drive an engine from a flight recording and "
                    "gate token bit-identity, rung residency, and "
                    "zero post-warmup retraces.")
    ap.add_argument("dump", help="flight JSONL (full sink or ring dump)")
    ap.add_argument("--inject-divergence", action="store_true",
                    help="corrupt one recorded token before comparing "
                         "(exercises the first-divergence report; the "
                         "replay must then exit nonzero)")
    args = ap.parse_args(argv)

    recording = load_recording(args.dump)
    if args.inject_divergence:
        fin = next((r for r in recording.records
                    if r.get("k") == "finish" and r.get("tokens")), None)
        if fin is None:
            raise SystemExit(
                "--inject-divergence needs a finish record with tokens")
        fin["tokens"][len(fin["tokens"]) // 2] += 1
    report = replay(recording)
    print(json.dumps(report.to_dict(), indent=2))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
