"""``repro.obs.flight`` — deterministic capture of serving incidents.

The engine is a deterministic state machine over two nondeterministic
input streams: request submissions and clock observations.  The repo's
bit-identity gates (cache-hit == cold prefill, rollback == never-
drafted, resume == never-preempted) mean that feeding both streams back
verbatim reproduces every runtime decision — which rung the controller
picked, when spec decoding switched gamma, who got preempted — and
every served token, bit for bit.  The :class:`FlightRecorder` is the
capture side of that invariant; ``repro.obs.flight.replay`` is the
re-drive side.

One ordered JSONL stream of records:

* ``header`` — schema version, engine config fingerprint, ladder
  artifact fingerprint, and caller-supplied reconstruction metadata
  (arch / seed / ladder path) so the replay CLI can rebuild the engine.
* ``clock`` — one record per engine clock read (``t`` plus the
  consuming call-site tag ``s``), captured by wrapping the engine's
  injected clock (``repro.obs.clock``).
* ``submit`` — the raw arguments of each ``Engine.submit`` call (token
  ids, budget, priority, tenant, deadline, explicit-or-derived arrival).
* ``decision`` — every resulting runtime decision (rung / gamma /
  drafter switches, preemptions, resumes, rejects, prefix evictions,
  saliency-drift edges), recorded for verification on replay.
* ``finish`` — each request's terminal record: finish reason, the full
  token stream, and the per-token rung residency — the payload replay
  gates bit-identity against.

Black-box mode: records land in a bounded in-memory ring (zero-cost
when the recorder is off — the engine's emit sites are ``is not None``
checks, same standard as the rest of ``repro.obs``) and are written out
only on a trigger: engine exception, SLO-breach escalation,
``saliency_drift`` edge, SIGUSR1, or the gateway's
``GET /v1/debug/flight``.  An optional full JSONL ``sink`` streams every
record from the start — that file is *complete* and therefore
replayable; a ring dump that overflowed the ring is marked
``complete: false`` and the replay loader refuses it.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional

# Flight recording format version; the replay loader gates on it.
FLIGHT_SCHEMA_VERSION = 1

# SLO-breach reasons that trigger a black-box dump: the controller
# escalated because latency or queue pressure broke the objective.
_SLO_BREACH_REASONS = ("tpot", "queue")


def config_fingerprint(ecfg) -> str:
    """Stable hash of an :class:`EngineConfig` — frozen dataclass reprs
    are deterministic, and every field that shapes engine decisions is
    in the repr."""
    return hashlib.sha256(repr(ecfg).encode()).hexdigest()[:16]


def params_fingerprint(params) -> str:
    """Content hash of the model parameters.  Replay gates on it so a
    reconstruction mismatch (different arch/seed, or nondeterministic
    re-init) is diagnosed by name instead of surfacing as a token
    divergence at index 0."""
    import jax
    import numpy as np
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()[:16]


def ladder_fingerprint(ladder) -> Optional[str]:
    """Hash of a :class:`PolicyLadder`'s decision-relevant content:
    budgets, per-rung policy reprs, and every sp-tree leaf's bytes.  Two
    ladders with equal fingerprints drive the engine identically."""
    if ladder is None:
        return None
    import jax
    import numpy as np
    h = hashlib.sha256()
    h.update(repr(tuple(ladder.budgets)).encode())
    for pol in ladder.policies:
        h.update(repr(pol).encode())
    for sp in ladder.sps:
        for leaf in jax.tree_util.tree_leaves(sp):
            arr = np.asarray(leaf)
            h.update(str(arr.dtype).encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
    return h.hexdigest()[:16]


def _config_to_dict(ecfg) -> dict:
    """JSON-serializable EngineConfig image for the replay CLI.  The
    policy itself is covered by the fingerprint; only whether it is
    dense is recorded (non-dense fixed-policy engines need a factory,
    see ``replay.py``)."""
    out = {f: getattr(ecfg, f) for f in (
        "max_slots", "max_len", "prefill_chunk", "prefill_dense_frac",
        "prefill_strategy", "eos_id", "initial_rung", "prefix_cache",
        "prefix_cache_tokens")}
    out["policy_dense"] = ecfg.policy.is_dense
    for name in ("slo", "spec", "scheduler"):
        sub = getattr(ecfg, name)
        out[name] = None if sub is None else dataclasses.asdict(sub)
    return out


class _RecordingClock:
    """Wraps the engine's base clock: every read is logged to the
    recorder (with its call-site tag) before being returned."""

    __slots__ = ("_base", "_recorder")

    def __init__(self, base, recorder: "FlightRecorder"):
        self._base = base
        self._recorder = recorder

    def now(self, site: str = "") -> float:
        t = self._base.now(site)
        self._recorder._append({"k": "clock", "t": t, "s": site})
        return t


class FlightRecorder:
    """Engine-boundary capture into a bounded ring (+ optional full
    JSONL sink) with dump-on-trigger.

    One recorder serves one engine: :meth:`attach_engine` (called by
    the engine at construction when ``Telemetry.flight`` is set) writes
    the header record and returns the recording clock wrapper the
    engine must read time through.

    ``capacity``   ring size in records (black-box retention window).
    ``sink``       optional path: stream every record as JSONL from the
                   start — the *complete* recording replay needs.
    ``dump_dir``   where triggered ring dumps land
                   (``flight-<reason>-<n>.jsonl``); None disables dumps.
    ``max_dumps``  cap on triggered dumps per run (a flapping SLO must
                   not fill the disk).
    ``meta``       caller-supplied reconstruction info for the replay
                   CLI (arch, reduced, seed, ladder_path, ...).
    """

    def __init__(self, capacity: int = 4096, sink: Optional[str] = None,
                 dump_dir: Optional[str] = None, max_dumps: int = 16,
                 meta: Optional[dict] = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if max_dumps < 0:
            raise ValueError(f"max_dumps must be >= 0, got {max_dumps}")
        self.capacity = capacity
        self.dump_dir = dump_dir
        self.max_dumps = max_dumps
        self.meta = dict(meta or {})
        self._ring = collections.deque(maxlen=capacity)
        self.count = 0
        self.dumps: List[str] = []
        self._header: Optional[dict] = None
        self._attached = False
        self._fh = None
        self._sink_path = sink
        if sink:
            # held for the recorder's lifetime, closed in close()
            self._fh = open(sink, "w")  # noqa: SIM115

    # ------------------------------------------------------------------
    # wiring
    # ------------------------------------------------------------------
    def attach_engine(self, engine) -> _RecordingClock:
        """Write the header record for ``engine`` and return the
        recording clock it must read time through.  One engine per
        recorder — the stream is a single totally-ordered history."""
        if self._attached:
            raise RuntimeError(
                "FlightRecorder already attached: one recorder records "
                "one engine's history")
        self._attached = True
        self._header = {
            "k": "header",
            "flight_schema_version": FLIGHT_SCHEMA_VERSION,
            "config_fingerprint": config_fingerprint(engine.ecfg),
            "params_fingerprint": params_fingerprint(engine.params),
            "ladder_fingerprint": ladder_fingerprint(engine.ladder),
            "num_rungs": engine.num_rungs,
            "ecfg": _config_to_dict(engine.ecfg),
            "meta": self.meta,
        }
        self._append(self._header)
        return _RecordingClock(engine.clock, self)

    @property
    def dropped(self) -> int:
        """Records evicted from the ring (0 ⇔ the ring alone still
        holds the complete history)."""
        return max(0, self.count - self.capacity)

    # ------------------------------------------------------------------
    # record kinds
    # ------------------------------------------------------------------
    def _append(self, rec: dict) -> None:
        self._ring.append(rec)
        self.count += 1
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def record_submit(self, prompt, max_new_tokens: int, eos_id,
                      arrival_time, priority, tenant: str,
                      queue_deadline_s) -> None:
        """The raw ``Engine.submit`` arguments, recorded *before* the
        admission decision and before any clock read the call makes —
        so the stream order is submit-intent, then its clock reads,
        then the decision, and the replay driver can re-issue the call
        verbatim."""
        self._append({
            "k": "submit",
            "prompt": [int(t) for t in prompt],
            "max_new_tokens": int(max_new_tokens),
            "eos_id": None if eos_id is None else int(eos_id),
            "arrival_time": None if arrival_time is None
            else float(arrival_time),
            "priority": int(priority),
            "tenant": tenant,
            "queue_deadline_s": None if queue_deadline_s is None
            else float(queue_deadline_s),
        })

    def decision(self, kind: str, **fields) -> None:
        """A runtime decision (rung_switch, preempt, resume, reject,
        gamma_switch, drafter_switch, prefix_evict, saliency_drift...).
        Recorded for replay verification; SLO-breach escalations and
        saliency-drift edges additionally trigger a black-box dump."""
        rec = {"k": "decision", "kind": kind}
        rec.update(fields)
        self._append(rec)
        if kind == "rung_switch" \
                and fields.get("reason") in _SLO_BREACH_REASONS \
                and fields.get("to_rung", 0) > fields.get("from_rung", 0):
            self.dump("slo_breach")
        elif kind == "saliency_drift":
            self.dump("saliency_drift")

    def finish(self, request_id: int, reason: Optional[str],
               tokens: List[int], token_rungs: List[int]) -> None:
        """A request's terminal record — the bit-identity payload."""
        self._append({
            "k": "finish", "request": int(request_id), "reason": reason,
            "tokens": [int(t) for t in tokens],
            "token_rungs": [int(r) for r in token_rungs],
        })

    # ------------------------------------------------------------------
    # dump-on-trigger
    # ------------------------------------------------------------------
    def dump(self, reason: str) -> Optional[str]:
        """Write the ring's current contents to
        ``dump_dir/flight-<reason>-<n>.jsonl``.  First line is a dump
        prologue naming the trigger and whether the ring still holds
        the complete history (the replay loader refuses incomplete
        dumps).  Returns the path, or None when dumping is disabled or
        the per-run cap is reached."""
        if self.dump_dir is None or len(self.dumps) >= self.max_dumps:
            return None
        os.makedirs(self.dump_dir, exist_ok=True)
        path = os.path.join(
            self.dump_dir, f"flight-{reason}-{len(self.dumps)}.jsonl")
        records = list(self._ring)          # snapshot; GIL-atomic enough
        #                                     for the signal/HTTP readers
        with open(path, "w") as f:
            f.write(json.dumps({
                "k": "dump", "reason": reason, "count": self.count,
                "retained": len(records),
                "complete": self.dropped == 0}) + "\n")
            for rec in records:
                f.write(json.dumps(rec) + "\n")
        self.dumps.append(path)
        return path

    def debug_snapshot(self) -> Dict[str, Any]:
        """Ring contents + counters for the gateway's
        ``GET /v1/debug/flight`` (cross-thread read of a bounded deque —
        the same torn-read stance as ``/metrics``)."""
        return {
            "flight_schema_version": FLIGHT_SCHEMA_VERSION,
            "count": self.count,
            "retained": len(self._ring),
            "dropped": self.dropped,
            "complete": self.dropped == 0,
            "sink": self._sink_path,
            "dumps": list(self.dumps),
            "records": list(self._ring),
        }

    def records(self, kind: Optional[str] = None) -> List[dict]:
        """Retained records, oldest first, optionally filtered by ``k``."""
        if kind is None:
            return list(self._ring)
        return [r for r in self._ring if r.get("k") == kind]

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Seal the sink with an end record (replay uses it to assert
        the stream wasn't truncated mid-write).  Idempotent."""
        if self._fh is not None:
            self._fh.write(json.dumps(
                {"k": "end", "count": self.count, "complete": True}) + "\n")
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "FLIGHT_SCHEMA_VERSION", "FlightRecorder",
    "config_fingerprint", "params_fingerprint", "ladder_fingerprint",
]

# re-exported for symmetric import ergonomics with the capture side
from repro.obs.clock import ReplayClock, ReplayDivergence  # noqa: E402

__all__ += ["ReplayClock", "ReplayDivergence"]
