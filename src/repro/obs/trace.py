"""Per-request span tracing in Chrome trace-event JSON (Perfetto-loadable).

The engine owns one :class:`SpanTracer` per run (via
``Telemetry.tracer``).  Spans are *complete* events ("ph": "X") recorded
after the fact from the engine's existing ``t0``/``t1`` monotonic stamps
— no context managers on the hot path, one dict append per span.  The
track layout maps the serving model directly:

* ``tid 0`` ("engine") — batched phase steps: decode steps, spec
  draft/verify/commit phases, with batch size / rung / gamma as args;
* ``tid request_id + 1`` ("req-<id>") — each request's timeline:
  ``submit`` → ``admit`` (slot) → ``prefix_lookup`` (matched length) →
  per-chunk ``prefill_chunk`` spans → ``first_token`` → ``finish``
  (reason), plus per-round ``rollback`` instants under spec decoding.

Counter events ("ph": "C") chart queue depth and slot occupancy as
Perfetto counter tracks.  Timestamps are microseconds since the
tracer's creation, taken from the shared monotonic clock
(:mod:`repro.obs.clock`) so spans, events, and stats are mutually
orderable.  Load the exported file at https://ui.perfetto.dev (legacy
JSON is auto-detected) or ``chrome://tracing``.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.obs import clock

ENGINE_TID = 0
TRACE_PID = 1

_ALLOWED_PH = {"X", "i", "I", "C", "M", "B", "E"}


class SpanTracer:
    """In-memory Chrome trace-event builder.  Append-only; ``export``
    (or ``to_dict``) at end of run.  One list append per span — cheap
    enough for per-chunk/per-step granularity, and absent entirely when
    tracing is off (the engine checks ``tracer is not None``)."""

    def __init__(self, origin: Optional[float] = None):
        self.origin = clock.now() if origin is None else origin
        self.events = []
        self._named: Dict[int, str] = {}
        self.thread_name(ENGINE_TID, "engine")

    # ------------------------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self.origin) * 1e6      # trace-event ts unit: us

    def thread_name(self, tid: int, name: str) -> None:
        """Label a track (emitted once per tid; later names are kept)."""
        if tid in self._named:
            return
        self._named[tid] = name
        self.events.append({"ph": "M", "name": "thread_name",
                            "pid": TRACE_PID, "tid": tid,
                            "args": {"name": name}})

    def complete(self, name: str, t0: float, t1: float,
                 tid: int = ENGINE_TID, **args) -> None:
        """One finished span [t0, t1] (monotonic seconds)."""
        self.events.append({"ph": "X", "name": name, "pid": TRACE_PID,
                            "tid": tid, "ts": self._ts(t0),
                            "dur": max(0.0, (t1 - t0) * 1e6),
                            "args": args})

    def instant(self, name: str, t: Optional[float] = None,
                tid: int = ENGINE_TID, **args) -> None:
        self.events.append({"ph": "i", "name": name, "pid": TRACE_PID,
                            "tid": tid, "s": "t",
                            "ts": self._ts(clock.now() if t is None else t),
                            "args": args})

    def counter(self, name: str, t: Optional[float] = None, **values) -> None:
        """Counter track sample (Perfetto draws these as line charts)."""
        self.events.append({"ph": "C", "name": name, "pid": TRACE_PID,
                            "tid": ENGINE_TID,
                            "ts": self._ts(clock.now() if t is None else t),
                            "args": values})

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


def validate_chrome_trace(doc) -> int:
    """Assert ``doc`` (a parsed trace JSON object) is schema-valid
    Chrome trace-event JSON: a ``traceEvents`` list whose entries carry
    the per-phase required keys with sane types (non-negative ``dur`` on
    complete events, ``ts`` on every timed event).  Returns the event
    count; raises ``ValueError`` on violations.  Shared by the tests and
    the CI artifact check."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with a traceEvents list")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        ph = ev.get("ph")
        if ph not in _ALLOWED_PH:
            raise ValueError(f"event {i}: bad phase {ph!r}")
        for key in ("name", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i}: missing {key!r}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                raise ValueError(f"event {i}: missing/bad ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: complete event needs "
                                 f"dur >= 0, got {dur!r}")
        if "args" in ev:
            json.dumps(ev["args"])       # args must be JSON-serializable
    return len(events)
