"""Structured event log: the *why* behind the metrics.

Latency series say a TPOT regression happened; the event log says what
the engine *did* at that moment — a rung switch and the controller's
reason (TPOT-over-target vs queue pressure vs de-escalation), a gamma
or drafter change, a prefix-cache eviction, a speculative-decode KV
rollback, a warmup compile, or (the invariant-violation case) a
post-warmup retrace.

Events are plain dicts ``{"t": monotonic_s, "kind": str, ...fields}``
ring-buffered in memory (bounded — a long-running server cannot grow it
without limit) with an optional always-flushed JSONL sink for offline
analysis.  Timestamps come from the shared monotonic clock so events
line up with spans and stats.  Emission is one dict build + deque
append; when no :class:`EventLog` is armed the engine's emit sites are
``if events is not None`` checks — allocation-free.

The JSONL sink can itself be bounded (``max_sink_bytes``): when the log
owns the file (path sink) and a write pushes it past the budget, the
file rotates once to ``<path>.1`` (replacing any previous rotation) and
a fresh file continues — a long-running serve keeps at most ~2x the
budget on disk, and the in-memory ring is never touched by rotation.
"""
from __future__ import annotations

import collections
import json
import os
from typing import List, Optional

from repro.obs import clock


class EventLog:
    """Bounded in-memory event ring with an optional JSONL sink.

    ``sink`` is a path (opened append) or a file-like with ``write``;
    each event is written and flushed immediately so a crash loses
    nothing.  ``count`` is the whole-run total; the ring keeps the most
    recent ``capacity`` events.

    ``max_sink_bytes`` (path sinks only — the log must own the file to
    rotate it) caps the JSONL file: when a write would exceed the
    budget, the current file moves to ``<path>.1`` and writing restarts
    on an empty file.  0 means unbounded (the historical behaviour)."""

    def __init__(self, capacity: int = 4096, sink=None,
                 max_sink_bytes: int = 0):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if max_sink_bytes < 0:
            raise ValueError(
                f"max_sink_bytes must be >= 0, got {max_sink_bytes}")
        if max_sink_bytes and not isinstance(sink, str):
            raise ValueError(
                "max_sink_bytes needs a path sink: rotation renames the "
                "file, which only the log-owned (path) sink allows")
        self.capacity = capacity
        self.max_sink_bytes = max_sink_bytes
        self.sink_rotations = 0
        self._ring = collections.deque(maxlen=capacity)
        self.count = 0
        self._fh = None
        self._owns_fh = False
        self._sink_path: Optional[str] = None
        self._sink_bytes = 0
        if isinstance(sink, str):
            # held for the log's lifetime, closed in close()/rotation
            self._fh = open(sink, "a")  # noqa: SIM115
            self._owns_fh = True
            self._sink_path = sink
            self._sink_bytes = self._fh.tell()
        elif sink is not None:
            self._fh = sink

    # ------------------------------------------------------------------
    def emit(self, kind: str, t: Optional[float] = None, **fields) -> None:
        rec = {"t": clock.now() if t is None else t, "kind": kind}
        rec.update(fields)
        self._ring.append(rec)
        self.count += 1
        if self._fh is not None:
            line = json.dumps(rec) + "\n"
            if (self.max_sink_bytes and self._sink_path is not None
                    and self._sink_bytes
                    and self._sink_bytes + len(line) > self.max_sink_bytes):
                self._rotate()
            self._fh.write(line)
            self._fh.flush()
            self._sink_bytes += len(line)

    def _rotate(self) -> None:
        """Move the full sink file aside to ``<path>.1`` and continue on
        a fresh one.  The in-memory ring is untouched — rotation bounds
        only the on-disk history."""
        self._fh.close()
        os.replace(self._sink_path, self._sink_path + ".1")
        self._fh = open(self._sink_path, "w")  # noqa: SIM115
        self._sink_bytes = 0
        self.sink_rotations += 1

    def events(self, kind: Optional[str] = None) -> List[dict]:
        """Retained events, oldest first, optionally filtered by kind."""
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e["kind"] == kind]

    def __len__(self) -> int:
        return len(self._ring)

    def close(self) -> None:
        if self._fh is not None and self._owns_fh:
            self._fh.close()
        self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
