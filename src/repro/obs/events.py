"""Structured event log: the *why* behind the metrics.

Latency series say a TPOT regression happened; the event log says what
the engine *did* at that moment — a rung switch and the controller's
reason (TPOT-over-target vs queue pressure vs de-escalation), a gamma
or drafter change, a prefix-cache eviction, a speculative-decode KV
rollback, a warmup compile, or (the invariant-violation case) a
post-warmup retrace.

Events are plain dicts ``{"t": monotonic_s, "kind": str, ...fields}``
ring-buffered in memory (bounded — a long-running server cannot grow it
without limit) with an optional always-flushed JSONL sink for offline
analysis.  Timestamps come from the shared monotonic clock so events
line up with spans and stats.  Emission is one dict build + deque
append; when no :class:`EventLog` is armed the engine's emit sites are
``if events is not None`` checks — allocation-free.
"""
from __future__ import annotations

import collections
import json
from typing import List, Optional

from repro.obs import clock


class EventLog:
    """Bounded in-memory event ring with an optional JSONL sink.

    ``sink`` is a path (opened append) or a file-like with ``write``;
    each event is written and flushed immediately so a crash loses
    nothing.  ``count`` is the whole-run total; the ring keeps the most
    recent ``capacity`` events."""

    def __init__(self, capacity: int = 4096, sink=None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._ring = collections.deque(maxlen=capacity)
        self.count = 0
        self._fh = None
        self._owns_fh = False
        if isinstance(sink, str):
            self._fh = open(sink, "a")
            self._owns_fh = True
        elif sink is not None:
            self._fh = sink

    # ------------------------------------------------------------------
    def emit(self, kind: str, t: Optional[float] = None, **fields) -> None:
        rec = {"t": clock.now() if t is None else t, "kind": kind}
        rec.update(fields)
        self._ring.append(rec)
        self.count += 1
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def events(self, kind: Optional[str] = None) -> List[dict]:
        """Retained events, oldest first, optionally filtered by kind."""
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e["kind"] == kind]

    def __len__(self) -> int:
        return len(self._ring)

    def close(self) -> None:
        if self._fh is not None and self._owns_fh:
            self._fh.close()
        self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
