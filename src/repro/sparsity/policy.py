"""First-class sparsity execution policy.

``SparsityPolicy`` is the *static* execution config for WiSparse: which
projection backend runs where (globally, per layer-role, or per block/depth
range), the static top-k bound ``k_max_frac``, the Pallas block size and
interpret flag.  It is a frozen, hashable dataclass so it can ride through
``jax.jit`` as a static argument — each distinct policy owns its executable
and two engines with different policies can never share (or leak) a trace,
unlike the retired thread-local ``sparsity_mode`` context.

The *traced* per-layer WiSparse parameters (``g``, ``alpha``, ``tau``,
``keep_frac``) stay in the ``sp`` pytree that flows next to the weights;
the policy only decides how each projection consumes them.

Backends (dispatching in ``repro.core.sparse_linear.project``):

    off          dense matmul (baseline)
    mask         per-token threshold mask, dense compute (paper-exact
                 numerics; the calibration/eval path)
    topk_shared  batched-serving gather path: one weight-aware channel set
                 per layer per step, shared across the batch; FLOPs and
                 weight bytes shrink with sparsity and the op stays
                 XLA-partitionable.
    topk_block   like topk_shared but whole 128-channel blocks (the TPU
                 block-granular scheme the Pallas kernel implements).
    pallas       Pallas block-gather kernel (TPU target; interpret on CPU).

Typical lifecycle::

    pol = SparsityPolicy.dense()                          # baseline
    pol = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5)
    pol = SparsityPolicy.from_plan(plan,                  # calibrated,
            backend="topk_shared",                        # mixed per-block
            sensitive_backend="mask", sensitive_frac=0.25)
    pol.save("plan.npz", sp=plan.stacked_sp)              # self-contained
    pol, sp = SparsityPolicy.load("plan.npz")             # no checkpoint
    engine = Engine(params, cfg, EngineConfig(policy=pol), sp)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import numpy as np

VALID_BACKENDS = ("off", "mask", "topk_shared", "topk_block", "pallas")

# serving phases (paper §5.1 recipe: dense first fraction of prefill,
# sparse later prefill chunks and all decode steps)
PHASES = ("prefill_dense", "prefill_sparse", "decode")

# v1: single policy + sp tree.  v2 adds a "kind" discriminator so one
# format carries either a single policy ("policy") or a whole calibrated
# ladder of rungs with shared sp trees ("ladder", repro.sparsity.ladder).
# v3: "interpret" may be null (= auto-detect from the backend at kernel
# call time).  Artifacts saved at v<=2 unconditionally baked the old
# default interpret=true, so the loader normalizes it to auto — without
# this, a pre-v3 ladder would silently force interpreter mode on TPU.
# v4: ladder artifacts may carry calibration-time quality baselines
# (per-rung per-block Eq. 6 reconstruction MSE in the meta, per-rung
# per-block saliency channel sets as "qc{rung}/d{depth}" arrays) for the
# serving-time QualityMonitor (repro.obs.quality); absent in older
# artifacts and optional in v4 — loaders treat them as None.
ARTIFACT_VERSION = 4
_READABLE_VERSIONS = (1, 2, 3, 4)


class CaptureSink:
    """Eager-only calibration hook: when attached to a policy, every
    projection executed eagerly records ``(id(w), x)`` here, so
    ``repro.core.calibration`` can gather per-linear input activations
    without instrumenting the models.  Traced executions record nothing.

    Identity-hashed, so a policy carrying a sink stays hashable."""

    __slots__ = ("records",)

    def __init__(self, records=None):
        self.records = [] if records is None else records

    def record(self, w, x):
        import jax
        if not isinstance(x, jax.core.Tracer):
            self.records.append((id(w), x))

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)


def _check_backend(b, where: str):
    if b not in VALID_BACKENDS:
        raise ValueError(
            f"unknown sparsity backend {b!r} in {where}; "
            f"valid backends: {', '.join(VALID_BACKENDS)}")


@dataclasses.dataclass(frozen=True)
class SparsityPolicy:
    """Static, hashable execution policy for every ``project()`` call.

    backend        default backend for every projection
    k_max_frac     static upper bound on the kept channel fraction
                   (gather/pallas backends size their output by it)
    block          channel-block size (TPU lane width)
    interpret      Pallas interpret mode.  ``None`` (the default)
                   auto-detects from the JAX backend at kernel-call time
                   — compiled on TPU, interpreted everywhere else — so a
                   caller that never thinks about it gets the right mode
                   on real hardware.  ``True``/``False`` force it.
    role_backends  ((role, backend), ...) overrides by projection role;
                   a role is the sp-leaf path within a layer (``"attn/wq"``,
                   ``"mlp/wo"``, ``"mamba/out_proj"``) and an entry matches
                   either the full path or just the leaf name (``"wo"``
                   matches both ``attn/wo`` and ``mlp/wo``).  Role matches
                   win over block ranges.
    block_backends ((start, end, backend), ...) overrides by model depth
                   (transformer-block index, half-open ranges) — the mixed
                   per-block execution the paper's non-monotonic block
                   sensitivity motivates, e.g. ``mask`` on the most
                   sensitive blocks and ``topk_block`` elsewhere.
    dense_phases   serving phases forced dense by :meth:`for_phase`.
    capture        optional :class:`CaptureSink` calibration hook.

    Validation is eager: a typo'd backend fails here, at construction,
    with the list of valid backends — not deep inside a jit trace.
    """

    backend: str = "off"
    k_max_frac: float = 1.0
    block: int = 128
    interpret: Optional[bool] = None     # None = auto: interpret off-TPU
    role_backends: Tuple[Tuple[str, str], ...] = ()
    block_backends: Tuple[Tuple[int, int, str], ...] = ()
    dense_phases: Tuple[str, ...] = ("prefill_dense",)
    capture: Optional[CaptureSink] = None

    def __post_init__(self):
        # normalize accidental lists (e.g. json round-trips) to tuples so
        # the policy stays hashable as a static jit argument
        for f in ("role_backends", "block_backends", "dense_phases"):
            v = getattr(self, f)
            if not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(
                    tuple(e) if isinstance(e, list) else e for e in v))
        _check_backend(self.backend, "SparsityPolicy.backend")
        for role, b in self.role_backends:
            _check_backend(b, f"role_backends[{role!r}]")
        for s, e, b in self.block_backends:
            _check_backend(b, f"block_backends[{s}:{e}]")
            if not (isinstance(s, int) and isinstance(e, int) and s < e):
                raise ValueError(
                    f"block_backends range ({s}, {e}) must be a half-open "
                    "int range with start < end")
        for ph in self.dense_phases:
            if ph not in PHASES:
                raise ValueError(
                    f"unknown phase {ph!r} in dense_phases; "
                    f"valid phases: {', '.join(PHASES)}")
        if not (0.0 < self.k_max_frac <= 1.0):
            raise ValueError(
                f"k_max_frac must be in (0, 1], got {self.k_max_frac}")
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    @classmethod
    def dense(cls, **kw) -> "SparsityPolicy":
        """All-dense execution (every projection runs the plain matmul)."""
        return cls(backend="off", **kw)

    @classmethod
    def uniform(cls, backend: str, k_max_frac: float = 1.0,
                **kw) -> "SparsityPolicy":
        """One backend for every projection (the legacy ``sparsity_mode``
        semantics, as an explicit value)."""
        return cls(backend=backend, k_max_frac=k_max_frac, **kw)

    @classmethod
    def from_plan(cls, plan, backend: str = "topk_shared",
                  sensitive_backend: Optional[str] = None,
                  sensitive_frac: float = 0.25,
                  k_max_frac: Optional[float] = None,
                  **kw) -> "SparsityPolicy":
        """Policy for a calibrated :class:`repro.core.pipeline.SparsePlan`.

        ``k_max_frac`` defaults to the plan's largest per-layer keep ratio
        (the tightest static bound that never truncates the traced
        ``keep_frac``).  With ``sensitive_backend`` set, the blocks with
        the *lowest* prune ratios — the ones the evolutionary search found
        most sensitive — get that backend (e.g. ``"mask"`` for paper-exact
        numerics) while the rest run ``backend``: a mixed per-block map
        derived from ``plan.block_ratios``.
        """
        ratios = np.asarray(plan.block_ratios, dtype=float)
        if k_max_frac is None:
            layer_ratios = getattr(plan, "layer_ratios", None) or {}
            prune_min = min(layer_ratios.values()) if layer_ratios \
                else (float(ratios.min()) if ratios.size else 0.0)
            k_max_frac = float(np.clip(1.0 - prune_min, 1e-3, 1.0))
        block_backends = ()
        if sensitive_backend is not None and ratios.size:
            n_sens = max(1, int(round(ratios.size * sensitive_frac)))
            order = np.argsort(ratios, kind="stable")
            sens = sorted(int(i) for i in order[:n_sens])
            block_backends = _merge_ranges(sens, sensitive_backend)
        return cls(backend=backend, k_max_frac=k_max_frac,
                   block_backends=block_backends, **kw)

    # ------------------------------------------------------------------
    # resolution
    # ------------------------------------------------------------------
    def backend_at(self, depth: Optional[int] = None,
                   role: Optional[str] = None) -> str:
        """Backend for a projection at ``depth`` with role ``role``.
        Role overrides win, then depth ranges, then the default."""
        if role is not None:
            leaf = role.rsplit("/", 1)[-1]
            for r, b in self.role_backends:
                if role == r or leaf == r:
                    return b
        if depth is not None:
            for s, e, b in self.block_backends:
                if s <= depth < e:
                    return b
        return self.backend

    def resolve_depth(self, depth: int) -> "SparsityPolicy":
        """Fold the depth-range map into the default backend for one
        block — the per-layer policy the scan body dispatches on."""
        if not self.block_backends:
            return self
        return dataclasses.replace(
            self, backend=self.backend_at(depth=depth), block_backends=())

    def off(self) -> "SparsityPolicy":
        """This policy with every projection forced dense (phase/shape
        config like ``block``/``interpret`` is preserved)."""
        if self.backend == "off" and not self.role_backends \
                and not self.block_backends:
            return self
        return dataclasses.replace(self, backend="off", role_backends=(),
                                   block_backends=())

    def for_phase(self, phase: str) -> "SparsityPolicy":
        """Policy for one serving phase — the §5.1 switch, expressed as a
        value instead of mode-string surgery.  Phases listed in
        ``dense_phases`` (default: just ``"prefill_dense"``) run dense;
        the others run this policy unchanged.  Equal policies stay equal
        (and hash-equal), so each (phase, policy) pair compiles once."""
        if phase not in PHASES:
            raise ValueError(
                f"unknown phase {phase!r}; valid phases: {', '.join(PHASES)}")
        return self.off() if phase in self.dense_phases else self

    @property
    def is_dense(self) -> bool:
        return self.backend == "off" and not self.role_backends \
            and not self.block_backends

    def prefix_deterministic(self) -> bool:
        """True when every projection this policy can select runs a
        *per-token* backend (``off`` dense or the paper-exact ``mask``),
        so a position's output depends only on the token prefix — never
        on chunk boundaries, batch composition, or later tokens.  This
        is the precondition for KV prefix-cache reuse
        (``repro.serving.prefix_cache``): the shared top-k backends
        aggregate saliency over the whole call, which would bake the
        donor request's chunking into the cached KV."""
        backends = {self.backend}
        backends.update(b for _, b in self.role_backends)
        backends.update(b for _, _, b in self.block_backends)
        return backends <= {"off", "mask"}

    # ------------------------------------------------------------------
    # self-contained artifact (policy + sp tree, including g)
    # ------------------------------------------------------------------
    def save(self, path: str, sp=None) -> None:
        """Persist a versioned, *self-contained* npz artifact: the policy
        config plus (optionally) the stacked sp tree — ratios, alphas,
        taus **and the weight-column norms g** — so a plan calibrated
        offline ships to a serving fleet without the model checkpoint."""
        meta = {
            "version": ARTIFACT_VERSION,
            "kind": "policy",
            "policy": self.to_dict(),
        }
        arrays = {}
        if sp is not None:
            arrays = {f"sp/{k}": v for k, v in _flatten_sp(sp).items()}
        with open(path, "wb") as f:
            np.savez(f, __meta__=np.array(json.dumps(meta)), **arrays)

    def to_dict(self) -> dict:
        """JSON-serializable policy config (artifact meta form)."""
        return {
            "backend": self.backend,
            "k_max_frac": self.k_max_frac,
            "block": self.block,
            "interpret": self.interpret,
            "role_backends": [list(e) for e in self.role_backends],
            "block_backends": [list(e) for e in self.block_backends],
            "dense_phases": list(self.dense_phases),
        }

    @classmethod
    def from_dict(cls, p: dict) -> "SparsityPolicy":
        return cls(
            backend=p["backend"], k_max_frac=p["k_max_frac"],
            block=p["block"], interpret=p["interpret"],
            role_backends=tuple(tuple(e) for e in p["role_backends"]),
            block_backends=tuple(tuple(e) for e in p["block_backends"]),
            dense_phases=tuple(p["dense_phases"]))

    @classmethod
    def from_artifact_dict(cls, p: dict, version: int) -> "SparsityPolicy":
        """:meth:`from_dict` with artifact-version normalization: v<=2
        artifacts unconditionally baked the old default
        ``interpret=True`` (there was no auto mode), so loading one on a
        TPU would silently force interpreter mode — normalize it back to
        auto.  An explicit ``interpret`` in a v3+ artifact is honored."""
        if version <= 2 and p.get("interpret") is True:
            p = {**p, "interpret": None}
        return cls.from_dict(p)

    @classmethod
    def load(cls, path: str):
        """Load a saved artifact -> ``(policy, sp_or_None)``.  Needs no
        model params: the sp tree (g included) comes from the file."""
        meta, z = _read_artifact(path)
        if meta.get("kind", "policy") != "policy":
            raise ValueError(
                f"{path} is a {meta['kind']!r} artifact; load it with "
                "repro.sparsity.PolicyLadder.load")
        pol = cls.from_artifact_dict(meta["policy"], meta["version"])
        flat = {k[len("sp/"):]: z[k] for k in z.files if k.startswith("sp/")}
        return pol, (_unflatten_sp(flat) if flat else None)


def _read_artifact(path: str):
    """Shared npz artifact reader -> (meta dict, npz handle); validates
    the version gate for both policy and ladder kinds."""
    z = np.load(path)
    if "__meta__" not in z.files:
        raise ValueError(f"{path} is not a repro.sparsity artifact")
    meta = json.loads(str(z["__meta__"][()]))
    version = meta.get("version")
    if version not in _READABLE_VERSIONS:
        raise ValueError(
            f"unsupported sparsity artifact version {version!r} "
            f"(this build reads versions {_READABLE_VERSIONS})")
    return meta, z


def _merge_ranges(depths, backend: str):
    """Sorted depth list -> ((start, end, backend), ...) contiguous runs."""
    out, start, prev = [], None, None
    for d in depths:
        if start is None:
            start = prev = d
        elif d == prev + 1:
            prev = d
        else:
            out.append((start, prev + 1, backend))
            start = prev = d
    if start is not None:
        out.append((start, prev + 1, backend))
    return tuple(out)


def _flatten_sp(sp) -> dict:
    """Nested list/dict sp tree -> {"0/l0/attn/wq/g": ndarray, ...}."""
    flat = {}

    def rec(node, prefix):
        if node is None:
            return
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{prefix}{k}/")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{prefix}{i}/")
        else:
            flat[prefix[:-1]] = np.asarray(node)

    rec(sp, "")
    return flat


def _unflatten_sp(flat: dict):
    """Inverse of :func:`_flatten_sp` for stacked sp trees (a list over
    layer groups of nested dicts of arrays)."""
    import jax.numpy as jnp
    groups = {}
    for key, arr in flat.items():
        parts = key.split("/")
        gi = int(parts[0])
        node = groups.setdefault(gi, {})
        for p in parts[1:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)
    return [groups[i] for i in range(len(groups))]
