"""First-class sparsity policies: the static execution config for WiSparse
projections, threaded explicitly through the model/serving stack instead of
ambient thread-local mode state."""
from repro.sparsity.policy import (ARTIFACT_VERSION, PHASES, VALID_BACKENDS,
                                   CaptureSink, SparsityPolicy)

__all__ = ["SparsityPolicy", "CaptureSink", "VALID_BACKENDS", "PHASES",
           "ARTIFACT_VERSION"]
