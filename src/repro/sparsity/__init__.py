"""First-class sparsity policies: the static execution config for WiSparse
projections, threaded explicitly through the model/serving stack — plus the
calibrated policy *ladder* that makes the sparsity level a runtime resource
(``repro.serving.controller`` switches rungs against SLOs)."""
from repro.sparsity.ladder import PolicyLadder, calibrate_ladder
from repro.sparsity.policy import (ARTIFACT_VERSION, PHASES, VALID_BACKENDS,
                                   CaptureSink, SparsityPolicy)

__all__ = ["SparsityPolicy", "CaptureSink", "VALID_BACKENDS", "PHASES",
           "ARTIFACT_VERSION", "PolicyLadder", "calibrate_ladder"]
