"""Policy ladder: a monotone family of calibrated sparsity policies.

WiSparse's mixed-granularity allocation (paper §4.3) turns a global
sparsity budget into a quality-ranked execution policy.  A
:class:`PolicyLadder` calibrates that trade-off at *several* budgets at
once — rung 0 is the densest (usually fully dense), the last rung the
sparsest — so a serving controller (``repro.serving.controller``) can
treat sparsity as a runtime resource and move between rungs as load
changes.

Calibration cost stays near a single cold search: each rung's
evolutionary block allocation warm-starts from the adjacent rung's block
ratios (uniformly shifted to the new budget) with the previous ratios as
a per-block floor, and its greedy intra-block stage starts from the
previous rung's per-linear ratios.  The floor also *guarantees* the
ladder invariant: a higher-budget rung never keeps more channels than a
lower one in any block.

The whole ladder ships as one self-contained versioned npz artifact
(policy-artifact ``kind="ladder"``): rung policies in the JSON meta,
rung 0's full sp tree plus per-rung deltas for the calibrated leaves
(``alpha``/``tau``/``keep_frac``) — the weight-column norms ``g`` are a
property of the checkpoint, identical across rungs, and stored once.  A
serving fleet loads the ladder without the model checkpoint.  Since
artifact v4 a calibrated ladder also carries quality baselines (per-rung
per-block Eq. 6 reconstruction MSE and saliency channel sets) that the
serving-time QualityMonitor (``repro.obs.quality``) compares live
traffic against.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.sparsity.policy import (ARTIFACT_VERSION, SparsityPolicy,
                                   _flatten_sp, _read_artifact,
                                   _unflatten_sp)


@dataclasses.dataclass(frozen=True)
class PolicyLadder:
    """Ordered rungs of (budget, policy, stacked sp tree), densest first.

    budgets       global prune-ratio targets, strictly ascending
    policies      one :class:`SparsityPolicy` per rung
    sps           one stacked sp tree per rung (rungs share ``g`` arrays)
    block_ratios  per-rung per-block prune ratios from calibration
                  (None for uniform/uncalibrated ladders)
    baselines     calibration-time quality baselines for the serving
                  QualityMonitor (artifact v4): ``{"recon": (rungs,
                  blocks) Eq. 6 MSE array, "channels": per-rung tuple of
                  per-block saliency channel-index arrays}``; None for
                  uniform ladders and pre-v4 artifacts
    """

    budgets: Tuple[float, ...]
    policies: Tuple[SparsityPolicy, ...]
    sps: tuple
    block_ratios: Optional[tuple] = None
    baselines: Optional[dict] = None

    def __post_init__(self):
        for f in ("budgets", "policies", "sps"):
            v = getattr(self, f)
            if not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(v))
        if self.block_ratios is not None and \
                not isinstance(self.block_ratios, tuple):
            object.__setattr__(self, "block_ratios",
                               tuple(self.block_ratios))
        n = len(self.budgets)
        if n == 0:
            raise ValueError("a ladder needs at least one rung")
        if len(self.policies) != n or len(self.sps) != n:
            raise ValueError(
                f"ladder rung count mismatch: {n} budgets, "
                f"{len(self.policies)} policies, {len(self.sps)} sp trees")
        for a, b in zip(self.budgets, self.budgets[1:]):
            if not a < b:
                raise ValueError(
                    f"ladder budgets must be strictly ascending, got "
                    f"{self.budgets}")
        for i, pol in enumerate(self.policies):
            if not isinstance(pol, SparsityPolicy):
                raise TypeError(
                    f"rung {i} policy must be a SparsityPolicy, "
                    f"got {type(pol)!r}")

    def __len__(self) -> int:
        return len(self.budgets)

    def rung(self, i: int):
        """(policy, sp) for rung ``i`` (0 = densest)."""
        return self.policies[i], self.sps[i]

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    @classmethod
    def uniform(cls, params, cfg, budgets: Sequence[float] = (0.0, 0.5, 0.7),
                backend: str = "topk_shared", **kw) -> "PolicyLadder":
        """Uncalibrated ladder: uniform keep ratios per rung over the
        default sp schema — sparsity as a pure speed dial, no offline
        calibration (rung 0 at budget 0.0 runs dense).  The calibrated
        path is :func:`calibrate_ladder`."""
        from repro.core.sp_schema import default_sp_stacked
        budgets = tuple(float(b) for b in budgets)
        policies, sps = [], []
        for b in budgets:
            sps.append(default_sp_stacked(params, cfg, keep_frac=1.0 - b))
            if b <= 0.0:
                policies.append(SparsityPolicy.dense(**kw))
            else:
                policies.append(SparsityPolicy.uniform(
                    backend, k_max_frac=max(1.0 - b, 1e-6), **kw))
        return cls(budgets=budgets, policies=tuple(policies),
                   sps=tuple(sps))

    # ------------------------------------------------------------------
    # self-contained artifact (v2, kind="ladder")
    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        """One versioned npz for the whole ladder.  Rung 0's sp tree is
        stored in full; later rungs store only the leaves that differ
        from rung 0 (in practice the calibrated ``alpha``/``tau``/
        ``keep_frac`` scalars — the ``g`` norms are shared)."""
        meta = {
            "version": ARTIFACT_VERSION,
            "kind": "ladder",
            "budgets": list(self.budgets),
            "policies": [p.to_dict() for p in self.policies],
            "block_ratios": None if self.block_ratios is None else
            [np.asarray(r, float).tolist() for r in self.block_ratios],
            # v4: quality baselines — recon MSEs ride the JSON meta,
            # channel index sets go in as qc{rung}/d{depth} arrays
            "quality": None if self.baselines is None else {
                "recon":
                np.asarray(self.baselines["recon"], float).tolist()},
        }
        arrays = {}
        if self.baselines is not None:
            for r, per_block in enumerate(self.baselines["channels"]):
                for d, ch in enumerate(per_block):
                    arrays[f"qc{r}/d{d}"] = np.asarray(ch, np.int64)
        base = _flatten_sp(self.sps[0])
        for k, v in base.items():
            arrays[f"sp0/{k}"] = v
        for i, sp in enumerate(self.sps[1:], start=1):
            flat = _flatten_sp(sp)
            if flat.keys() != base.keys():
                raise ValueError(
                    f"rung {i} sp tree structure differs from rung 0; "
                    "ladder rungs must share one sp schema")
            for k, v in flat.items():
                if not np.array_equal(v, base[k]):
                    arrays[f"sp{i}/{k}"] = v
        with open(path, "wb") as f:
            np.savez(f, __meta__=np.array(json.dumps(meta)), **arrays)

    @classmethod
    def load(cls, path: str) -> "PolicyLadder":
        """Rebuild a ladder from its artifact — no checkpoint needed."""
        meta, z = _read_artifact(path)
        if meta.get("kind") != "ladder":
            raise ValueError(
                f"{path} is a {meta.get('kind', 'policy')!r} artifact; "
                "load it with repro.sparsity.SparsityPolicy.load")
        policies = tuple(
            SparsityPolicy.from_artifact_dict(p, meta["version"])
            for p in meta["policies"])
        base = {k[len("sp0/"):]: z[k] for k in z.files
                if k.startswith("sp0/")}
        sps = [_unflatten_sp(base)]
        for i in range(1, len(policies)):
            flat = dict(base)
            pre = f"sp{i}/"
            for k in z.files:
                if k.startswith(pre):
                    flat[k[len(pre):]] = z[k]
            sps.append(_unflatten_sp(flat))
        br = meta.get("block_ratios")
        baselines = None
        qb = meta.get("quality")        # absent in pre-v4 artifacts
        if qb is not None:
            recon = np.asarray(qb["recon"], float)
            channels = tuple(
                tuple(z[f"qc{r}/d{d}"] for d in range(recon.shape[1]))
                for r in range(recon.shape[0]))
            baselines = {"recon": recon, "channels": channels}
        return cls(budgets=tuple(meta["budgets"]), policies=policies,
                   sps=tuple(sps),
                   block_ratios=None if br is None else
                   tuple(np.asarray(r) for r in br),
                   baselines=baselines)


def calibrate_ladder(params, cfg, calib_batch,
                     budgets: Sequence[float] = (0.0, 0.3, 0.5, 0.7), *,
                     backend: str = "topk_shared",
                     sensitive_backend: Optional[str] = None,
                     sensitive_frac: float = 0.25,
                     evo=None, warm_generations: Optional[int] = None,
                     delta: float = 0.05, coord_passes: int = 0,
                     ctx=None, log=None, quality_baselines: bool = True,
                     saliency_topk: int = 32) -> PolicyLadder:
    """Calibrate a monotone policy ladder at several global budgets.

    The calibration context is built once; the first sparse rung runs the
    full evolutionary search and every later rung warm-starts from the
    previous rung's plan with ``warm_generations`` generations (default:
    a quarter of the cold budget).  Budget 0.0 is the dense rung: no
    search, alphas 0, keep 1 — but the *same* sp tree schema, so a
    serving engine can swap rung sp trees without retracing.

    ``quality_baselines`` additionally records, per rung and block, the
    Eq. 6 reconstruction MSE on the calibration batch and the top
    ``saliency_topk`` saliency channels (``|x| * g^alpha`` on the block
    input), shipped in the v4 artifact so the serving-time
    QualityMonitor can compare live traffic against calibration
    (``saliency_topk`` should match ``QualityConfig.saliency_topk`` —
    mismatched set sizes depress the Jaccard overlap even without
    drift).
    """
    from repro.core import unstacked as U
    from repro.core.allocation import EvoConfig
    from repro.core.calibration import build_context
    from repro.core.pipeline import run_pipeline

    log = log or (lambda *_: None)
    evo = evo or EvoConfig()
    budgets = tuple(float(b) for b in budgets)
    if any(b < 0.0 or b >= 1.0 for b in budgets):
        raise ValueError(f"ladder budgets must be in [0, 1), got {budgets}")

    if ctx is None:
        log("building calibration context ...")
        ctx = build_context(params, cfg, calib_batch)

    policies, sps, block_ratios = [], [], []
    prev_plan = None
    for i, b in enumerate(sorted(budgets)):
        if b <= 0.0:
            log(f"rung {i}: dense (budget 0)")
            ratios = {(d, p): 1.0 for d in range(ctx.num_blocks)
                      for p in ctx.keys_by_depth[d]}
            sp = U.restack_sp(cfg, ctx.make_sp({}, ratios))
            policies.append(SparsityPolicy.dense())
            sps.append(sp)
            block_ratios.append(np.zeros(ctx.num_blocks))
            continue
        gens = None if prev_plan is None else (
            warm_generations if warm_generations is not None
            else max(1, evo.generations // 4))
        log(f"rung {i}: budget {b:.2f} "
            f"({'warm, %d gens' % gens if gens is not None else 'cold'})")
        plan = run_pipeline(params, cfg, calib_batch, b, evo=evo,
                            delta=delta, coord_passes=coord_passes,
                            log=log, ctx=ctx, warm_start=prev_plan,
                            generations=gens)
        policies.append(plan.to_policy(
            backend=backend, sensitive_backend=sensitive_backend,
            sensitive_frac=sensitive_frac))
        sps.append(plan.stacked_sp)
        block_ratios.append(np.asarray(plan.block_ratios, float))
        prev_plan = plan

    baselines = None
    if quality_baselines:
        log("recording quality baselines (Eq. 6 recon + saliency) ...")
        baselines = _quality_baselines(cfg, ctx, sps, saliency_topk)

    return PolicyLadder(budgets=tuple(sorted(budgets)),
                        policies=tuple(policies), sps=tuple(sps),
                        block_ratios=tuple(block_ratios),
                        baselines=baselines)


def _quality_baselines(cfg, ctx, sps, saliency_topk: int) -> dict:
    """Per-rung per-block calibration-time quality references: the Eq. 6
    reconstruction MSE under each rung's sp tree, and the top-k saliency
    channel set of each block's calibration input — the same scoring
    rule (and representative leaf choice) the live QualityMonitor
    applies, so serving-time Jaccard overlap is 1.0 by construction on
    in-distribution traffic."""
    import jax
    from repro.obs.quality import (rep_saliency_leaf, saliency_channels,
                                   unstack_sp)

    feats = [np.mean(np.abs(np.asarray(ctx.block_io[d], np.float32)),
                     axis=(0, 1)) for d in range(ctx.num_blocks)]
    recon = np.zeros((len(sps), ctx.num_blocks))
    channels = []
    for i, sp in enumerate(sps):
        per_depth = unstack_sp(cfg, sp)
        per_block = []
        for d in range(ctx.num_blocks):
            recon[i, d] = float(ctx.block_mse(d, per_depth[d]))
            leaf = rep_saliency_leaf(
                jax.tree_util.tree_map(np.asarray, per_depth[d]),
                cfg.d_model)
            per_block.append(
                np.zeros((0,), np.int64) if leaf is None else
                saliency_channels(feats[d], leaf[0], leaf[1],
                                  saliency_topk))
        channels.append(tuple(per_block))
    return {"recon": recon, "channels": tuple(channels)}
