"""Fault-tolerant training runner.

1000+-node posture (DESIGN.md SS4):
  * checkpoint/restart — periodic async checkpoints (atomic publish), exact
    resume: the data stream is deterministic in (seed, host, step), so a
    restart replays from the checkpointed step bit-identically;
  * preemption handling — the runner traps failures (a `FailureInjector`
    simulates SIGTERM-style preemptions in tests), restores the latest
    checkpoint and continues; crash loops are bounded by `max_restarts`;
  * straggler mitigation — per-step wall-clock watchdog records slow steps;
    on a real cluster the controller uses these reports to evict/replace
    the slow host, and because data sharding is deterministic-by-host-id a
    replacement host picks up exactly the evicted host's stream (no
    resharding barrier);
  * elastic rescale — checkpoints are mesh-agnostic (unsharded arrays), so
    a restart may resolve shardings on a different mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.obs.clock import now


class Preemption(Exception):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministic simulated preemptions (for tests/demos)."""
    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise Preemption(f"simulated preemption at step {step}")


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    checkpoint_every: int = 50
    max_restarts: int = 10
    straggler_factor: float = 3.0   # step slower than factor x median -> flag


class TrainingRunner:
    def __init__(self, cfg: RunnerConfig, ckpt: CheckpointManager,
                 injector: Optional[FailureInjector] = None, log=print):
        self.cfg = cfg
        self.ckpt = ckpt
        self.injector = injector
        self.log = log
        self.straggler_events = []
        self.restarts = 0

    def run(self, state, step_fn: Callable, batch_fn: Callable,
            state_axes=None, metadata: Optional[dict] = None):
        """state: pytree; step_fn(state, batch) -> (state, metrics);
        batch_fn(step) -> batch.  Returns final state."""
        restored, meta = self.ckpt.restore(state, axes_tree=state_axes)
        start = 0
        if restored is not None:
            state, start = restored, int(meta["step"])
            self.log(f"resumed from step {start}")
        step = start
        durations = []
        while step < self.cfg.total_steps:
            try:
                t0 = now()
                if self.injector:
                    self.injector.check(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                dt = now() - t0
                durations.append(dt)
                med = float(np.median(durations[-20:]))
                if len(durations) > 5 and dt > self.cfg.straggler_factor * med:
                    self.straggler_events.append((step, dt, med))
                    self.log(f"straggler: step {step} took {dt:.3f}s "
                             f"(median {med:.3f}s)")
                step += 1
                if step % self.cfg.checkpoint_every == 0 \
                        or step == self.cfg.total_steps:
                    self.ckpt.save(step, state, metadata)
            except Preemption as e:
                self.restarts += 1
                self.log(f"{e} -> restart {self.restarts}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored, meta = self.ckpt.restore(state, axes_tree=state_axes)
                if restored is not None:
                    state, step = restored, int(meta["step"])
                else:
                    step = 0
        self.ckpt.wait()
        return state
