from repro.distributed.sharding import (
    LOGICAL_RULES_SERVE,
    LOGICAL_RULES_TRAIN,
    ShardingCtx,
    constrain,
    current_ctx,
    mesh_axes_for,
    named_sharding,
    param_shardings,
    sharding_context,
)

__all__ = [
    "LOGICAL_RULES_SERVE",
    "LOGICAL_RULES_TRAIN",
    "ShardingCtx",
    "constrain",
    "current_ctx",
    "mesh_axes_for",
    "named_sharding",
    "param_shardings",
    "sharding_context",
]
