"""Logical-axis sharding rules with divisibility fallback.

Params and activations carry *logical* axis names (``"embed"``, ``"heads"``,
``"mlp"``, ``"vocab"``, ``"experts"``, ``"batch"``, ``"kv_seq"``, ...).  A
rules table maps each logical name to an ordered list of candidate mesh-axis
tuples; the first candidate whose size divides the dim *and* whose mesh axes
are not already taken by another dim of the same array wins.  An empty-tuple
candidate means "replicate", which is the universal fallback — this is what
lets every assigned architecture lower on the same production mesh (e.g.
8 q-heads cannot shard over model=16 and silently fall back to replication
while the MLP stays sharded).

Rule sets differ by mode:
  * TRAIN: FSDP-style — the ``embed`` (d_model) dim of weights additionally
    shards over ``data`` so params/grads/optimizer state scale with the pod.
  * SERVE: weights replicated over ``data`` for latency; KV-cache sequence
    shards over spare axes (flash-decoding style).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidates = Tuple[Tuple[str, ...], ...]

# logical axis -> ordered candidates (each a tuple of mesh axes)
LOGICAL_RULES_TRAIN: Dict[str, Candidates] = {
    "batch":      (("pod", "data"), ("data",), ()),
    "vocab":      (("model",), ()),
    "heads":      (("model",), ()),
    "kv_heads":   (("model",), ()),
    "heads_flat": (("model",), ()),      # fused H*hd dim of wq/wo
    "kv_flat":    (("model",), ()),      # fused KV*hd dim of wk/wv
    "mlp":        (("model",), ()),
    "experts":    (("model",), ()),
    "expert_mlp": (("model",), ()),       # used when num_experts % model != 0
    "moe_capacity": (("model",), ()),     # dispatch token-slots (E indivisible)
    "grouped_in": (("model",), ()),       # per-shard channel groups (sparse)
    "embed":      (("data",), ()),        # FSDP dim in train mode
    "embed_act":  ((),),                  # activations' d_model: replicated
    "seq":        ((),),
    "kv_seq":     ((),),
    "ssm_heads":  (("model",), ()),
    "layers":     ((),),
}

LOGICAL_RULES_SERVE: Dict[str, Candidates] = {
    **LOGICAL_RULES_TRAIN,
    "embed":  ((),),                      # replicate weights across data
    # flash-decoding: shard the KV-cache sequence over whatever is spare
    "kv_seq": (("data", "model"), ("model",), ("data",), ()),
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, Candidates]
    overrides: Dict[str, Candidates] = dataclasses.field(default_factory=dict)

    def candidates(self, name: str) -> Candidates:
        if name in self.overrides:
            return self.overrides[name]
        return self.rules.get(name, ((),))


_STATE = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules=None, overrides=None):
    prev = current_ctx()
    rules = rules if rules is not None else LOGICAL_RULES_TRAIN
    _STATE.ctx = ShardingCtx(mesh, dict(rules), dict(overrides or {}))
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def mesh_axes_for(axes: Sequence[Optional[str]], shape: Sequence[int],
                  ctx: Optional[ShardingCtx] = None) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility fallback."""
    ctx = ctx or current_ctx()
    if ctx is None:
        return P(*([None] * len(shape)))
    mesh_sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    used = set()
    out = []
    for name, dim in zip(axes, shape):
        chosen = None
        if name is not None:
            for cand in ctx.candidates(name):
                cand = tuple(a for a in cand if a in mesh_sizes)
                if any(a in used for a in cand):
                    continue
                size = int(np.prod([mesh_sizes[a] for a in cand])) if cand else 1
                if cand and dim % size != 0:
                    continue
                chosen = cand or None
                break
        if chosen:
            used.update(chosen)
            out.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            out.append(None)
    return P(*out)


def named_sharding(axes, shape, ctx=None) -> Optional[NamedSharding]:
    ctx = ctx or current_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, mesh_axes_for(axes, shape, ctx))


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op outside a context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = mesh_axes_for(axes, x.shape, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def param_shardings(schema_axes, abstract, ctx=None):
    """Map a logical-axes pytree + abstract pytree -> NamedSharding pytree."""
    ctx = ctx or current_ctx()

    def f(axes, aval):
        return named_sharding(axes, aval.shape, ctx)

    return jax.tree_util.tree_map(
        f, schema_axes, abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )
