"""Alg. 2 — lightweight block-wise grid search for the weight exponents.

For each block, candidate exponents alpha in [0, 1.5] (31-point grid, step
0.05 per §5.1) are scored by the MSE between the dense and sparse block
outputs on the block's own calibration inputs; thresholds for each candidate
come from Eq. 7 at the block's keep ratios.  A first pass searches one
shared alpha for the whole block (the paper's Alg. 2); optional coordinate
passes then refine each linear's alpha_l individually ("layer-specific
exponent", §4.2).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.calibration import CalibContext, Key

GRID = tuple(np.round(np.arange(0.0, 1.5001, 0.05), 4))


def search_block_alpha(ctx: CalibContext, depth: int,
                       ratios: Dict[Key, float],
                       grid=GRID, coord_passes: int = 1) -> Dict[Key, float]:
    """Returns {key: alpha} for all linears of block `depth`."""
    keys = [(depth, p) for p in ctx.keys_by_depth[depth]]
    if not keys:
        return {}

    def block_err(alphas: Dict[Key, float]) -> float:
        dl = ctx.layers[depth]
        sp = _sp_for_block(ctx, dl, alphas, ratios)
        return ctx.block_mse(depth, sp)

    # pass 0: shared alpha over the whole block (paper Alg. 2)
    best_a, best_e = 0.0, np.inf
    for a in grid:
        e = block_err({k: a for k in keys})
        if e < best_e:
            best_a, best_e = a, e
    alphas = {k: best_a for k in keys}

    # coordinate refinement: per-layer alpha_l
    for _ in range(coord_passes):
        improved = False
        for k in keys:
            cur = alphas[k]
            for a in grid:
                if a == cur:
                    continue
                trial = dict(alphas)
                trial[k] = a
                e = block_err(trial)
                if e < best_e - 1e-12:
                    best_e, alphas, improved = e, trial, True
        if not improved:
            break
    return alphas


def _sp_for_block(ctx: CalibContext, dl, alphas, ratios):
    from repro.core import unstacked as U
    sp = U.default_layer_sp(dl.params)
    for path in ctx.keys_by_depth[dl.depth]:
        key = (dl.depth, path)
        a = float(alphas.get(key, 0.0))
        r = float(ratios.get(key, 1.0))
        U.set_sp_leaf(sp, path, "alpha", a)
        U.set_sp_leaf(sp, path, "tau", ctx.tau_for(key, a, r))
        U.set_sp_leaf(sp, path, "keep_frac", r)
    return sp


def search_all_alphas(ctx: CalibContext, ratios: Dict[Key, float],
                      grid=GRID, coord_passes: int = 1,
                      progress=None) -> Dict[Key, float]:
    out: Dict[Key, float] = {}
    for d in range(ctx.num_blocks):
        out.update(search_block_alpha(ctx, d, ratios, grid, coord_passes))
        if progress:
            progress(d, ctx.num_blocks)
    return out
