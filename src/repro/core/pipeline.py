"""Alg. 1 — the full WiSparse pipeline.

    p_block  <- evolutionary block-level allocation        (Alg. 3)
    p_layer  <- greedy intra-block allocation              (Alg. 4)
    alpha    <- block-wise grid search                     (Alg. 2)
    tau_l    <- Eq. 7 quantile at the final (alpha, ratio)

Returns a ``SparsePlan`` holding per-depth sp dicts (calibration/eval form)
plus the re-stacked sp tree the scanned production model consumes.

Shipping a plan: ``SparsePlan.save``/``load_ratios`` round-trip the search
*outputs* (ratios/alphas/taus) as json — enough to rebuild sp against a
checkpoint.  For a **self-contained** artifact that needs no checkpoint
(it also carries the weight-column norms ``g``), use
``plan.to_policy().save(path, sp=plan.stacked_sp)`` /
``repro.sparsity.SparsityPolicy.load`` — that is what a serving fleet
loads.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import alpha_search, allocation, unstacked as U
from repro.core.calibration import CalibContext, Key, build_context
from repro.core.allocation import EvoConfig


@dataclasses.dataclass
class SparsePlan:
    cfg: ModelConfig
    p_target: float
    block_ratios: np.ndarray                  # per-block prune ratios
    layer_ratios: Dict[Key, float]            # per-linear prune ratios
    alphas: Dict[Key, float]
    taus: Dict[Key, float]
    per_depth_sp: list                        # calibration/unstacked form
    stacked_sp: list                          # scan-model form

    def summary(self) -> dict:
        return {
            "p_target": self.p_target,
            "block_ratios": [round(float(x), 4) for x in self.block_ratios],
            "mean_alpha": round(float(np.mean(list(self.alphas.values()))), 4)
            if self.alphas else 0.0,
        }

    def save(self, path: str):
        blob = {
            "p_target": self.p_target,
            "block_ratios": np.asarray(self.block_ratios).tolist(),
            "layer_ratios": {f"{d}|{p}": v for (d, p), v
                             in self.layer_ratios.items()},
            "alphas": {f"{d}|{p}": v for (d, p), v in self.alphas.items()},
            "taus": {f"{d}|{p}": v for (d, p), v in self.taus.items()},
        }
        with open(path, "w") as f:
            json.dump(blob, f)

    @staticmethod
    def load_ratios(path: str):
        with open(path) as f:
            blob = json.load(f)

        def parse(d):
            out = {}
            for k, v in d.items():
                # split once: a "|" inside the path component must survive
                # the round-trip, not silently truncate the key
                depth, p = k.split("|", 1)
                out[(int(depth), p)] = v
            return out

        return (blob["p_target"], np.array(blob["block_ratios"]),
                parse(blob["layer_ratios"]), parse(blob["alphas"]),
                parse(blob["taus"]))

    def to_policy(self, backend: str = "topk_shared",
                  sensitive_backend=None, sensitive_frac: float = 0.25,
                  **kw):
        """Execution policy for this plan — see
        :meth:`repro.sparsity.SparsityPolicy.from_plan`."""
        from repro.sparsity import SparsityPolicy
        return SparsityPolicy.from_plan(
            self, backend=backend, sensitive_backend=sensitive_backend,
            sensitive_frac=sensitive_frac, **kw)


def run_pipeline(params, cfg: ModelConfig, calib_batch, p_target: float,
                 evo: EvoConfig = EvoConfig(), delta: float = 0.05,
                 alpha_default: float = 1.0, coord_passes: int = 1,
                 skip_coarse: bool = False, skip_fine: bool = False,
                 skip_alpha: bool = False, log=None,
                 ctx: Optional[CalibContext] = None,
                 warm_start: Optional["SparsePlan"] = None,
                 generations: Optional[int] = None) -> SparsePlan:
    """Full WiSparse calibration.  The skip_* flags reproduce the paper's
    Table-2 ablation rows (activation-only / +weight / +coarse / +fine).

    ``warm_start``: a plan calibrated at an adjacent (lower) budget — both
    search stages start from (and never undercut) its ratios, which is
    what makes a calibrated ladder monotone per block.  ``generations``
    caps the evolutionary budget for that refinement search."""
    log = log or (lambda *_: None)
    if ctx is None:
        log("building calibration context ...")
        ctx = build_context(params, cfg, calib_batch)

    # default alphas during allocation: the plain |x|*g rule (alpha=1, WINA
    # -like) unless ablating weight-awareness entirely (alpha=0).
    base_alpha = {(d, p): alpha_default for d in range(ctx.num_blocks)
                  for p in ctx.keys_by_depth[d]}

    p_init = p_min = layer_init = None
    if warm_start is not None:
        if warm_start.p_target > p_target:
            raise ValueError(
                f"warm_start plan budget {warm_start.p_target} exceeds "
                f"p_target {p_target}; ladder budgets must be ascending")
        p_init = p_min = np.asarray(warm_start.block_ratios, np.float64)
        layer_init = dict(warm_start.layer_ratios)

    if skip_coarse:
        p_block = np.full(ctx.num_blocks, p_target)
        if p_init is not None:
            p_block = np.maximum(p_block, p_init)
    else:
        log("coarse search: evolutionary block-level allocation (Alg. 3)")
        p_block = allocation.block_level_allocation(
            ctx, p_target, evo, base_alpha, log,
            p_init=p_init, p_min=p_min, generations=generations)

    layer_ratios: Dict[Key, float] = {}
    if skip_fine:
        for d in range(ctx.num_blocks):
            for p in ctx.keys_by_depth[d]:
                layer_ratios[(d, p)] = float(p_block[d])
        if layer_init is not None:
            for k, v in layer_init.items():
                layer_ratios[k] = max(layer_ratios.get(k, 0.0), v)
    else:
        log("fine search: greedy intra-block allocation (Alg. 4)")
        for d in range(ctx.num_blocks):
            layer_ratios.update(allocation.intra_block_allocation(
                ctx, d, float(p_block[d]), delta, base_alpha,
                p_init=layer_init))

    keep_ratios = {k: 1.0 - v for k, v in layer_ratios.items()}

    if skip_alpha:
        alphas = dict(base_alpha)
    else:
        log("alpha search: block-wise grid (Alg. 2)")
        alphas = alpha_search.search_all_alphas(
            ctx, keep_ratios, coord_passes=coord_passes,
            progress=lambda d, n: log(f"  alpha block {d + 1}/{n}"))

    taus = {k: ctx.tau_for(k, alphas.get(k, 0.0), keep_ratios[k])
            for k in layer_ratios}
    per_depth_sp = ctx.make_sp(alphas, keep_ratios)
    stacked_sp = U.restack_sp(cfg, per_depth_sp)
    return SparsePlan(cfg, p_target, p_block, layer_ratios, alphas, taus,
                      per_depth_sp, stacked_sp)


def activation_only_plan(params, cfg: ModelConfig, calib_batch,
                         p_target: float,
                         ctx: Optional[CalibContext] = None) -> SparsePlan:
    """TEAL-style baseline: alpha=0 (activation-only), uniform allocation.
    The paper's 'Activation only' ablation row."""
    if ctx is None:
        ctx = build_context(params, cfg, calib_batch)
    ratios = {(d, p): 1.0 - p_target for d in range(ctx.num_blocks)
              for p in ctx.keys_by_depth[d]}
    alphas = {k: 0.0 for k in ratios}
    taus = {k: ctx.tau_for(k, 0.0, ratios[k]) for k in ratios}
    per_depth_sp = ctx.make_sp(alphas, ratios)
    return SparsePlan(cfg, p_target,
                      np.full(ctx.num_blocks, p_target),
                      {k: p_target for k in ratios}, alphas, taus,
                      per_depth_sp, U.restack_sp(cfg, per_depth_sp))
