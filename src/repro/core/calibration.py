"""Calibration context: captured activations, dense references, cached
threshold computation and jitted fitness/block-error evaluators.

Built once per (model, calibration set); every WiSparse search stage
(alpha grid, evolutionary block allocation, greedy layer allocation) runs
against this context (paper §4.2-4.3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sparse_linear as sl
from repro.core import unstacked as U
from repro.models import model as M
from repro.sparsity import CaptureSink, SparsityPolicy

Key = Tuple[int, str]                       # (depth, leaf path e.g. "attn/wq")

# calibration/eval execution: paper-exact per-token mask numerics
_MASK = SparsityPolicy.uniform("mask")


@dataclasses.dataclass
class CalibContext:
    cfg: ModelConfig
    params: dict
    layers: list
    batch: dict
    dense_logits: jnp.ndarray
    block_io: list                          # len D+1: dense input to block d
    acts: Dict[Key, np.ndarray]             # captured linear inputs
    g: Dict[Key, np.ndarray]                # weight-column norms
    sizes: Dict[Key, float]                 # active-compute weights
    keys_by_depth: Dict[int, List[str]]
    enc_out: Optional[jnp.ndarray] = None
    _tau_cache: dict = dataclasses.field(default_factory=dict)
    _fit_fn: Optional[callable] = None
    _block_fns: dict = dataclasses.field(default_factory=dict)

    # -- thresholds (Eq. 7) ------------------------------------------------
    def scores_for(self, key: Key, alpha: float) -> np.ndarray:
        ck = (key, round(float(alpha), 4))
        if ck not in self._tau_cache:
            x = self.acts[key]
            g = self.g[key]
            gb = g[:, None, :] if g.ndim == 2 else g[None, :]
            s = np.abs(x) * np.maximum(gb, 1e-12) ** float(alpha)
            s = s[s > 0]          # drop MoE capacity-padding rows (all-zero)
            self._tau_cache[ck] = np.sort(s, axis=None)
        return self._tau_cache[ck]

    def tau_for(self, key: Key, alpha: float, keep_ratio: float) -> float:
        s = self.scores_for(key, alpha)
        p = float(np.clip(1.0 - keep_ratio, 0.0, 1.0))
        if p <= 0.0:
            return -np.inf
        idx = min(int(p * len(s)), len(s) - 1)
        return float(s[idx])

    # -- sp construction ---------------------------------------------------
    def make_sp(self, alphas: Dict[Key, float], ratios: Dict[Key, float]):
        """Per-depth sp list with thresholds derived from keep ratios."""
        out = []
        for dl in self.layers:
            sp = U.default_layer_sp(dl.params)
            for path in self.keys_by_depth[dl.depth]:
                key = (dl.depth, path)
                a = float(alphas.get(key, 0.0))
                r = float(ratios.get(key, 1.0))
                U.set_sp_leaf(sp, path, "alpha", a)
                U.set_sp_leaf(sp, path, "tau", self.tau_for(key, a, r))
                U.set_sp_leaf(sp, path, "keep_frac", r)
            out.append(sp)
        return out

    # -- evaluators ----------------------------------------------------------
    def fitness(self, per_depth_sp) -> float:
        """Token-averaged KL(dense || sparse) on the calibration set (Eq. 8)."""
        if self._fit_fn is None:
            cfg, params, layers, batch = self.cfg, self.params, self.layers, self.batch
            dense = jax.nn.log_softmax(self.dense_logits.astype(jnp.float32), -1)
            pd = jnp.exp(dense)

            def f(sp_list):
                logits, _ = U.forward_unstacked(
                    params, cfg, batch["tokens"], layers=layers,
                    per_depth_sp=sp_list,
                    patch_embeds=batch.get("patch_embeds"),
                    frames=batch.get("frames"), policy=_MASK)
                ls = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                return jnp.mean(jnp.sum(pd * (dense - ls), axis=-1))

            self._fit_fn = jax.jit(f)
        return float(self._fit_fn(per_depth_sp))

    def block_mse(self, depth: int, sp_d) -> float:
        """Block-output reconstruction error vs the dense block (Eq. 6)."""
        if depth not in self._block_fns:
            dl = self.layers[depth]
            x_in = self.block_io[depth]
            y_ref = self.block_io[depth + 1].astype(jnp.float32)
            cfg, enc_out = self.cfg, self.enc_out

            def f(sp):
                y = U.block_forward(dl, x_in, cfg, sp, enc_out, policy=_MASK)
                return jnp.mean(jnp.square(y.astype(jnp.float32) - y_ref))

            self._block_fns[depth] = jax.jit(f)
        return float(self._block_fns[depth](sp_d))

    @property
    def num_blocks(self) -> int:
        return len(self.layers)

    def block_weight(self, depth: int) -> float:
        return sum(self.sizes[(depth, p)] for p in self.keys_by_depth[depth])


def _active_size(cfg: ModelConfig, w) -> float:
    if w.ndim == 3:                         # MoE expert weight (E, n, m)
        e, n, m = w.shape
        return float(cfg.num_experts_per_tok * n * m)
    return float(np.prod(w.shape))


def build_context(params, cfg: ModelConfig, batch) -> CalibContext:
    """Run the dense model once over the calibration batch, capturing every
    linear's inputs and each block's dense input/output."""
    layers = U.unstack_layers(cfg, params)
    id2key: Dict[int, Key] = {}
    g, sizes, keys_by_depth = {}, {}, {}
    for dl in layers:
        names = []
        for path, w in U.sparsifiable_leaves(dl.params):
            key = (dl.depth, path)
            id2key[id(w)] = key
            if w.ndim == 3:
                g[key] = np.asarray(jax.vmap(sl.column_norms)(w))
            else:
                g[key] = np.asarray(sl.column_norms(w))
            sizes[key] = _active_size(cfg, w)
            names.append(path)
        keys_by_depth[dl.depth] = names

    enc_out = None
    if cfg.family == "encdec" and "frames" in batch:
        enc_out = M.encode(params, batch["frames"], cfg)

    cap = CaptureSink()
    logits, block_io = U.forward_unstacked(
        params, cfg, batch["tokens"], layers=layers,
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"), collect_block_inputs=True,
        policy=SparsityPolicy.dense(capture=cap))
    block_io = list(block_io)
    # forward_unstacked appends inputs before each block; add the final x
    # is handled below via a second pass convention: recompute last output.
    last = layers[-1]
    y_last = U.block_forward(last, block_io[-1], cfg, None, enc_out)
    block_io.append(y_last)

    acts: Dict[Key, list] = {}
    for wid, x in cap:
        key = id2key.get(wid)
        if key is None:
            continue
        xn = np.asarray(x, np.float32)
        if xn.ndim == 4:                   # MoE dispatch (B,E,C,D) -> (E,T,D)
            xn = np.moveaxis(xn, 1, 0).reshape(xn.shape[1], -1, xn.shape[-1])
        else:
            xn = xn.reshape(-1, xn.shape[-1])
        acts.setdefault(key, []).append(xn)

    acts_np = {key: np.concatenate(chunks, axis=-2)
               for key, chunks in acts.items()}

    return CalibContext(
        cfg=cfg, params=params, layers=layers, batch=batch,
        dense_logits=logits, block_io=block_io, acts=acts_np, g=g,
        sizes=sizes, keys_by_depth=keys_by_depth, enc_out=enc_out)
