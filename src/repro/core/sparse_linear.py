"""WiSparse sparse-projection dispatch.

``project(x, w, sp, policy=...)`` is the single choke point through which
every linear layer in the model zoo runs.  ``sp`` carries the per-layer
WiSparse parameters (all traced arrays so they can ride through
``lax.scan`` over a stacked layer group):

    g          (n_in,)  precomputed weight-column L2 norms  (paper Eq. 4)
    alpha      ()       layer exponent alpha_l               (paper Eq. 4)
    tau        ()       inference threshold tau_l            (paper Eq. 5)
    keep_frac  ()       keep ratio 1 - p_l (gather backends)

The *static* execution config is an explicit :class:`SparsityPolicy`
value (``repro.sparsity``): which backend runs where (globally, per
layer-role, per block range), the static top-k bound, the Pallas block
size/interpret flag (``interpret=None`` auto-detects — compiled on TPU,
interpreted elsewhere), and the optional calibration capture hook.  Because
backends differ in lowering, the policy is a hashable static jit argument
— never ambient state — so concurrent engines with different policies can
never share a trace.  ``policy=None`` means dense execution; the
thread-local ``sparsity_mode``/``capture_inputs``/``token_weights``
contexts that used to fill unspecified state are gone (see the README
migration notes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sparsity import CaptureSink, SparsityPolicy, VALID_BACKENDS

__all__ = [
    "SparsityPolicy", "CaptureSink", "VALID_BACKENDS", "DENSE", "project",
    "scores", "column_norms", "default_sp",
]

# the default execution when no policy is passed: plain dense matmuls
DENSE = SparsityPolicy.dense()


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------

def _saliency(xf, sp, tok_w=None):
    """Per-channel shared saliency over all token rows (optionally
    weighted by the serving engine's token weights)."""
    s = scores(xf, sp["g"], sp["alpha"])                 # (rows, n_in)
    if tok_w is None:
        return s.mean(axis=0)
    if tok_w.size != s.shape[0]:
        # a projection whose rows aren't the step's tokens (e.g. an
        # expert-dispatched layout) must opt out via token_weights=None
        # — mis-aligned weights would silently bias the channel set
        raise ValueError(
            f"token_weights has {tok_w.size} rows but the projection sees "
            f"{s.shape[0]} token rows; pass token_weights=None for "
            "dispatch-reshaped projections")
    twf = tok_w.reshape(-1, 1).astype(jnp.float32)
    return (s * twf).sum(axis=0) / jnp.maximum(twf.sum(), 1.0)


def _matmul(x, w):
    """x (..., n_in) @ w (n_in, *out).

    Output dtype == input dtype: a f32 preferred_element_type makes XLA
    hoist the bf16 convert past the row-parallel all-reduce, doubling every
    TP activation psum on the wire (measured on the TP mesh dry-runs; see
    benchmarks/roofline_report.py).  The MXU accumulates in f32 internally
    either way."""
    return jax.lax.dot_general(
        x.reshape(-1, x.shape[-1]), w.reshape(w.shape[0], -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    ).reshape(x.shape[:-1] + w.shape[1:])


def scores(x, g, alpha):
    """Weight-aware importance score  s_i = |x_i| * g_i^alpha  (Eq. 4)."""
    gf = jnp.maximum(g.astype(jnp.float32), 1e-12)
    return jnp.abs(x.astype(jnp.float32)) * jnp.power(gf, alpha)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def project(x, w, sp: Optional[dict] = None, row_parallel: bool = False, *,
            policy: Optional[SparsityPolicy] = None,
            role: Optional[str] = None, token_weights=None):
    """Dispatch one projection under ``policy`` (per-block depth ranges
    are already folded in by the model's scan driver; only role overrides
    remain to resolve here).  ``policy=None`` runs dense.

    row_parallel statically marks weights whose *input* dim is
    model-sharded (o_proj/down_proj/out_proj).  The top-k gather backends
    then select a balanced per-shard channel budget so the gather stays
    local instead of lowering to a cross-shard masked-gather + all-reduce
    (see ``_topk_gather_grouped``).
    """
    if policy is None:
        policy = DENSE
    if policy.capture is not None:
        policy.capture.record(w, x)
    backend = policy.backend_at(role=role)
    if sp is None or backend == "off":
        return _matmul(x, w)
    if backend == "mask":
        s = scores(x, sp["g"], sp["alpha"])
        m = (s >= sp["tau"]).astype(x.dtype)           # Eq. 5
        return _matmul(x * m, w)
    if backend in ("topk_shared", "topk_block"):
        groups = 1
        if row_parallel:
            from repro.distributed.sharding import current_ctx
            ctx = current_ctx()
            if ctx is not None:
                sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
                g = sizes.get("model", 1)
                if w.shape[0] % g == 0:
                    groups = g
        return _topk_gather(x, w, sp, policy, backend=backend, groups=groups,
                            token_weights=token_weights)
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.wisparse_project(x, w, sp, block=policy.block,
                                     k_frac=policy.k_max_frac,
                                     interpret=policy.interpret,
                                     token_weights=token_weights)
    raise ValueError(    # unreachable: policies validate at construction
        f"unknown sparsity backend {backend}")


def _topk_gather(x, w, sp, policy, *, backend: Optional[str] = None,
                 groups: int = 1, token_weights=None):
    """Shared-mask gather path: aggregate weight-aware scores over all
    tokens in the call, keep the top k_max channels (static), mask ranks
    beyond the layer's own traced keep_frac, gather the corresponding
    weight rows and run a compact matmul.  FLOPs ~ k/n of dense.

    ``policy`` supplies the static knobs (k_max_frac, block).

    groups > 1: balanced per-shard selection for row-parallel weights —
    the channel budget is split evenly across `groups` contiguous input
    slices (= the weight's model shards) so every gather is shard-local."""
    backend = backend or policy.backend
    if groups > 1:
        return _topk_gather_grouped(x, w, sp, policy, groups,
                                    token_weights=token_weights)
    n_in = w.shape[0]
    xf = x.reshape(-1, n_in)
    sal = _saliency(xf, sp, token_weights)                       # (n_in,)
    if backend == "topk_block":
        b = policy.block
        nb = max(n_in // b, 1)
        if n_in % b:
            pad = nb * b + b - n_in
            sal = jnp.pad(sal, (0, pad))
            nb += 1
        blk = sal.reshape(nb, -1).sum(axis=1)
        kb_max = max(1, round(nb * policy.k_max_frac))
        _, bidx = jax.lax.top_k(blk, kb_max)
        idx = (bidx[:, None] * b + jnp.arange(b)[None, :]).reshape(-1)
        idx = jnp.minimum(idx, n_in - 1)
        k_l = jnp.round(sp["keep_frac"] * nb).astype(jnp.int32)
        rank_ok = (jnp.arange(kb_max) < k_l)
        rank_ok = jnp.repeat(rank_ok, b)
    else:
        k_max = max(1, round(n_in * policy.k_max_frac))
        _, idx = jax.lax.top_k(sal, k_max)
        k_l = jnp.round(sp["keep_frac"] * n_in).astype(jnp.int32)
        rank_ok = jnp.arange(k_max) < k_l
    ws = jnp.take(w.reshape(n_in, -1), idx, axis=0)              # (k, m)
    xs = jnp.take(xf, idx, axis=1) * rank_ok.astype(x.dtype)
    y = jax.lax.dot_general(xs, ws, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype).reshape(x.shape[:-1] + w.shape[1:])


def _topk_gather_grouped(x, w, sp, policy, groups: int, token_weights=None):
    """Balanced grouped selection: reshape the input-channel dim into
    (groups, n/groups), pick top-(k/groups) per group, gather within each
    group (shard-local for model-sharded weight rows), contract per group
    and sum.  Keeps the same global budget; selection is per-shard-balanced
    (accuracy delta measured in benchmarks/table1_accuracy.py)."""
    n_in = w.shape[0]
    G = groups
    ng = n_in // G
    xf = x.reshape(-1, n_in)
    sal = _saliency(xf, sp, token_weights).reshape(G, ng)
    k_max = max(1, round(ng * policy.k_max_frac))
    _, idx = jax.lax.top_k(sal, k_max)                    # (G, k)
    k_l = jnp.round(sp["keep_frac"] * ng).astype(jnp.int32)
    rank_ok = (jnp.arange(k_max) < k_l)[None, :]          # (1, k)
    from repro.distributed.sharding import constrain
    wg = constrain(w.reshape(G, ng, -1), "grouped_in", None, None)
    ws = jnp.take_along_axis(wg, idx[:, :, None], axis=1)  # (G, k, m)
    xg = xf.reshape(-1, G, ng)
    xs = jnp.take_along_axis(xg, idx[None], axis=2)        # (B, G, k)
    xs = xs * rank_ok[None].astype(xs.dtype)
    y = jnp.einsum("bgk,gkm->bm", xs, ws,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype).reshape(x.shape[:-1] + w.shape[1:])


def column_norms(w) -> jnp.ndarray:
    """g_i = ||W[:, i]||_2 over all output dims; w: (n_in, *out)."""
    wf = w.reshape(w.shape[0], -1).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(wf * wf, axis=1))


def default_sp(w) -> dict:
    """Dense-equivalent sparsity params (alpha=0, tau=-inf, keep=1)."""
    return {
        "g": column_norms(w),
        "alpha": jnp.zeros((), jnp.float32),
        "tau": jnp.full((), -jnp.inf, jnp.float32),
        "keep_frac": jnp.ones((), jnp.float32),
    }
