"""WiSparse sparse-projection dispatch.

``project(x, w, sp)`` is the single choke point through which every linear
layer in the model zoo runs.  ``sp`` carries the per-layer WiSparse
parameters (all traced arrays so they can ride through ``lax.scan`` over a
stacked layer group):

    g          (n_in,)  precomputed weight-column L2 norms  (paper Eq. 4)
    alpha      ()       layer exponent alpha_l               (paper Eq. 4)
    tau        ()       inference threshold tau_l            (paper Eq. 5)
    keep_frac  ()       keep ratio 1 - p_l (gather backends)

The *static* execution mode lives in a context var (set by the serving /
calibration drivers), because backends differ in lowering:

    off          dense matmul (baseline)
    mask         per-token threshold mask, dense compute (paper-exact
                 numerics; the calibration/eval path)
    topk_shared  batched-serving gather path (DESIGN.md SS3.3): one
                 weight-aware channel set per layer per step, shared across
                 the batch; FLOPs and weight bytes shrink with sparsity and
                 the op stays XLA-partitionable.
    topk_block   like topk_shared but whole 128-channel blocks (the TPU
                 block-granular scheme the Pallas kernel implements).
    pallas       Pallas block-gather kernel (TPU target; interpret on CPU).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityMode:
    mode: str = "off"            # off|mask|topk_shared|topk_block|pallas
    k_max_frac: float = 1.0      # static upper bound on kept fraction
    block: int = 128             # channel-block size (TPU lane width)
    interpret: bool = True       # Pallas interpret mode (CPU container)


_STATE = threading.local()


def current_mode() -> SparsityMode:
    return getattr(_STATE, "mode", None) or SparsityMode()


@contextlib.contextmanager
def sparsity_mode(mode: str = "mask", k_max_frac: float = 1.0,
                  block: int = 128, interpret: bool = True):
    prev = getattr(_STATE, "mode", None)
    _STATE.mode = SparsityMode(mode, k_max_frac, block, interpret)
    try:
        yield _STATE.mode
    finally:
        _STATE.mode = prev


@contextlib.contextmanager
def capture_inputs():
    """Calibration hook: record (id(w), x) for every projection executed
    eagerly inside this context.  Used by repro.core.calibration to gather
    per-linear input activations without instrumenting the models."""
    prev = getattr(_STATE, "capture", None)
    _STATE.capture = []
    try:
        yield _STATE.capture
    finally:
        _STATE.capture = prev


def capture_active() -> bool:
    return getattr(_STATE, "capture", None) is not None


@contextlib.contextmanager
def token_weights(w):
    """Serving hook: weight each token row's contribution to the shared
    top-k saliency aggregate.  The engine passes the active-slot mask for
    batched decode (so freed/empty slots don't pollute the layer's shared
    channel set) and the real-token mask for padded prefill chunks.  With
    all-ones weights the ranking (and the floats) match the unweighted
    mean exactly.  w: (rows,) or None; rows must equal the flattened
    token count of each projection call inside the context."""
    prev = getattr(_STATE, "tok_w", None)
    _STATE.tok_w = w
    try:
        yield
    finally:
        _STATE.tok_w = prev


def current_token_weights():
    return getattr(_STATE, "tok_w", None)


def _saliency(xf, sp):
    """Per-channel shared saliency over all token rows (optionally
    weighted by the serving engine's token_weights context)."""
    s = scores(xf, sp["g"], sp["alpha"])                 # (rows, n_in)
    tw = current_token_weights()
    if tw is None:
        return s.mean(axis=0)
    if tw.size != s.shape[0]:
        # a projection whose rows aren't the context's tokens (e.g. an
        # expert-dispatched layout) must opt out via token_weights(None)
        # — mis-aligned weights would silently bias the channel set
        raise ValueError(
            f"token_weights has {tw.size} rows but the projection sees "
            f"{s.shape[0]} token rows; wrap dispatch-reshaped projections "
            "in token_weights(None)")
    twf = tw.reshape(-1, 1).astype(jnp.float32)
    return (s * twf).sum(axis=0) / jnp.maximum(twf.sum(), 1.0)


def record(w, x):
    cap = getattr(_STATE, "capture", None)
    if cap is not None and not isinstance(x, jax.core.Tracer):
        cap.append((id(w), x))


def _matmul(x, w):
    """x (..., n_in) @ w (n_in, *out).

    Output dtype == input dtype: a f32 preferred_element_type makes XLA
    hoist the bf16 convert past the row-parallel all-reduce, doubling every
    TP activation psum on the wire (EXPERIMENTS.md SSPerf iteration B2).
    The MXU accumulates in f32 internally either way."""
    return jax.lax.dot_general(
        x.reshape(-1, x.shape[-1]), w.reshape(w.shape[0], -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    ).reshape(x.shape[:-1] + w.shape[1:])


def scores(x, g, alpha):
    """Weight-aware importance score  s_i = |x_i| * g_i^alpha  (Eq. 4)."""
    gf = jnp.maximum(g.astype(jnp.float32), 1e-12)
    return jnp.abs(x.astype(jnp.float32)) * jnp.power(gf, alpha)


def project(x, w, sp: Optional[dict] = None, row_parallel: bool = False):
    """row_parallel: statically marks weights whose *input* dim is
    model-sharded (o_proj/down_proj/out_proj).  The top-k gather backends
    then select a balanced per-shard channel budget so the gather stays
    local instead of lowering to a cross-shard masked-gather + all-reduce
    (DESIGN.md SS3 / EXPERIMENTS.md SSPerf iteration A3)."""
    record(w, x)
    mode = current_mode()
    if sp is None or mode.mode == "off":
        return _matmul(x, w)
    if mode.mode == "mask":
        s = scores(x, sp["g"], sp["alpha"])
        m = (s >= sp["tau"]).astype(x.dtype)           # Eq. 5
        return _matmul(x * m, w)
    if mode.mode in ("topk_shared", "topk_block"):
        groups = 1
        if row_parallel:
            from repro.distributed.sharding import current_ctx
            ctx = current_ctx()
            if ctx is not None:
                sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
                g = sizes.get("model", 1)
                if w.shape[0] % g == 0:
                    groups = g
        return _topk_gather(x, w, sp, mode, groups)
    if mode.mode == "pallas":
        from repro.kernels import ops as kops
        return kops.wisparse_project(x, w, sp, block=mode.block,
                                     interpret=mode.interpret)
    raise ValueError(f"unknown sparsity mode {mode.mode}")


def _topk_gather(x, w, sp, mode: SparsityMode, groups: int = 1):
    """Shared-mask gather path: aggregate weight-aware scores over all
    tokens in the call, keep the top k_max channels (static), mask ranks
    beyond the layer's own traced keep_frac, gather the corresponding
    weight rows and run a compact matmul.  FLOPs ~ k/n of dense.

    groups > 1: balanced per-shard selection for row-parallel weights —
    the channel budget is split evenly across `groups` contiguous input
    slices (= the weight's model shards) so every gather is shard-local."""
    if groups > 1:
        return _topk_gather_grouped(x, w, sp, mode, groups)
    n_in = w.shape[0]
    xf = x.reshape(-1, n_in)
    sal = _saliency(xf, sp)                                      # (n_in,)
    if mode.mode == "topk_block":
        b = mode.block
        nb = max(n_in // b, 1)
        if n_in % b:
            pad = nb * b + b - n_in
            sal = jnp.pad(sal, (0, pad))
            nb += 1
        blk = sal.reshape(nb, -1).sum(axis=1)
        kb_max = max(1, round(nb * mode.k_max_frac))
        _, bidx = jax.lax.top_k(blk, kb_max)
        idx = (bidx[:, None] * b + jnp.arange(b)[None, :]).reshape(-1)
        idx = jnp.minimum(idx, n_in - 1)
        k_l = jnp.round(sp["keep_frac"] * nb).astype(jnp.int32)
        rank_ok = (jnp.arange(kb_max) < k_l)
        rank_ok = jnp.repeat(rank_ok, b)
    else:
        k_max = max(1, round(n_in * mode.k_max_frac))
        _, idx = jax.lax.top_k(sal, k_max)
        k_l = jnp.round(sp["keep_frac"] * n_in).astype(jnp.int32)
        rank_ok = jnp.arange(k_max) < k_l
    ws = jnp.take(w.reshape(n_in, -1), idx, axis=0)              # (k, m)
    xs = jnp.take(xf, idx, axis=1) * rank_ok.astype(x.dtype)
    y = jax.lax.dot_general(xs, ws, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype).reshape(x.shape[:-1] + w.shape[1:])


def _topk_gather_grouped(x, w, sp, mode: SparsityMode, groups: int):
    """Balanced grouped selection: reshape the input-channel dim into
    (groups, n/groups), pick top-(k/groups) per group, gather within each
    group (shard-local for model-sharded weight rows), contract per group
    and sum.  Keeps the same global budget; selection is per-shard-balanced
    (accuracy delta measured in benchmarks/table1)."""
    n_in = w.shape[0]
    G = groups
    ng = n_in // G
    xf = x.reshape(-1, n_in)
    sal = _saliency(xf, sp).reshape(G, ng)
    k_max = max(1, round(ng * mode.k_max_frac))
    _, idx = jax.lax.top_k(sal, k_max)                    # (G, k)
    k_l = jnp.round(sp["keep_frac"] * ng).astype(jnp.int32)
    rank_ok = (jnp.arange(k_max) < k_l)[None, :]          # (1, k)
    from repro.distributed.sharding import constrain
    wg = constrain(w.reshape(G, ng, -1), "grouped_in", None, None)
    ws = jnp.take_along_axis(wg, idx[:, :, None], axis=1)  # (G, k, m)
    xg = xf.reshape(-1, G, ng)
    xs = jnp.take_along_axis(xg, idx[None], axis=2)        # (B, G, k)
    xs = xs * rank_ok[None].astype(xs.dtype)
    y = jnp.einsum("bgk,gkm->bm", xs, ws,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype).reshape(x.shape[:-1] + w.shape[1:])


def column_norms(w) -> jnp.ndarray:
    """g_i = ||W[:, i]||_2 over all output dims; w: (n_in, *out)."""
    wf = w.reshape(w.shape[0], -1).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(wf * wf, axis=1))


def default_sp(w) -> dict:
    """Dense-equivalent sparsity params (alpha=0, tau=-inf, keep=1)."""
    return {
        "g": column_norms(w),
        "alpha": jnp.zeros((), jnp.float32),
        "tau": jnp.full((), -jnp.inf, jnp.float32),
        "keep_frac": jnp.ones((), jnp.float32),
    }
