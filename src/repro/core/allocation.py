"""Mixed-granularity sparsity allocation (paper §4.3).

Coarse (Alg. 3): evolutionary search over *block-level* prune ratios under a
global average constraint; fitness is the token-level KL divergence between
dense and sparse model outputs on the calibration set (Eq. 8).  Mutation is
localized (a small fraction of blocks, fixed step eps), offspring-only, no
crossover — per the paper's EvoPress-style setup.

Fine (Alg. 4): within each block, a greedy loop adds sparsity increments to
whichever linear layer increases the block's output reconstruction error
the least, until the block meets its budget.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.calibration import CalibContext, Key


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    generations: int = 400          # paper §5.1
    offspring: int = 64
    eps: float = 0.005              # 0.5% mutation step
    mutate_frac: float = 0.1        # 10% of blocks per mutation
    max_sparsity: float = 0.95
    seed: int = 0
    elitist: bool = True


def weighted_average(ctx: CalibContext, p: np.ndarray) -> float:
    w = np.array([ctx.block_weight(d) for d in range(ctx.num_blocks)])
    return float(np.sum(p * w) / np.sum(w))


def _ratios_uniform_block(ctx: CalibContext, p: np.ndarray) -> Dict[Key, float]:
    """All linears in block d share keep ratio 1-p[d] (coarse-stage view)."""
    ratios = {}
    for d in range(ctx.num_blocks):
        for path in ctx.keys_by_depth[d]:
            ratios[(d, path)] = 1.0 - float(p[d])
    return ratios


def block_level_allocation(ctx: CalibContext, p_target: float,
                           cfg: EvoConfig = EvoConfig(),
                           alphas: Optional[Dict[Key, float]] = None,
                           log=None) -> np.ndarray:
    """Alg. 3.  Returns per-block prune ratios p (averaging to p_target)."""
    N = ctx.num_blocks
    rng = np.random.default_rng(cfg.seed)
    alphas = alphas or {}

    def fitness(p):
        sp = ctx.make_sp(alphas, _ratios_uniform_block(ctx, p))
        return ctx.fitness(sp)

    p = np.full(N, p_target, np.float64)
    best_fit = fitness(p)
    if log:
        log(f"gen 0 uniform KL={best_fit:.6f}")

    for gen in range(1, cfg.generations + 1):
        offspring = []
        for _ in range(cfg.offspring):
            q = p.copy()
            flips = max(1, int(round(N * cfg.mutate_frac)))
            for b in rng.choice(N, flips, replace=False):
                q[b] = min(q[b] + cfg.eps, cfg.max_sparsity)
            guard = 0
            while weighted_average(ctx, q) > p_target + 1e-9 and guard < 10000:
                b = rng.integers(N)
                q[b] = max(q[b] - cfg.eps, 0.0)
                guard += 1
            offspring.append(q)
        fits = [fitness(q) for q in offspring]
        i = int(np.argmin(fits))
        if not cfg.elitist or fits[i] < best_fit:
            p, best_fit = offspring[i], fits[i]
        if log and (gen % max(1, cfg.generations // 10) == 0):
            log(f"gen {gen} KL={best_fit:.6f} "
                f"spread=[{p.min():.3f},{p.max():.3f}]")
    return p


def intra_block_allocation(ctx: CalibContext, depth: int, p_block: float,
                           delta: float = 0.05,
                           alphas: Optional[Dict[Key, float]] = None,
                           max_sparsity: float = 0.95) -> Dict[Key, float]:
    """Alg. 4.  Returns per-linear prune ratios for block `depth` whose
    size-weighted average meets p_block."""
    alphas = alphas or {}
    paths = ctx.keys_by_depth[depth]
    if not paths:
        return {}
    keys = [(depth, p) for p in paths]
    sizes = np.array([ctx.sizes[k] for k in keys])
    p = {k: 0.0 for k in keys}

    def effective():
        vals = np.array([p[k] for k in keys])
        return float(np.sum(vals * sizes) / np.sum(sizes))

    def block_err(trial):
        from repro.core.alpha_search import _sp_for_block
        ratios = {k: 1.0 - v for k, v in trial.items()}
        sp = _sp_for_block(ctx, ctx.layers[depth], alphas, ratios)
        return ctx.block_mse(depth, sp)

    guard = 0
    while effective() < p_block - 1e-9 and guard < 10000:
        best_err, best_key = np.inf, None
        for k in keys:
            if p[k] + delta > max_sparsity:
                continue
            trial = dict(p)
            trial[k] = p[k] + delta
            err = block_err(trial)
            if err < best_err:
                best_err, best_key = err, k
        if best_key is None:
            break
        p[best_key] += delta
        guard += 1
    return p


def allocate(ctx: CalibContext, p_target: float,
             evo: EvoConfig = EvoConfig(), delta: float = 0.05,
             alphas: Optional[Dict[Key, float]] = None, log=None):
    """Coarse-to-fine: returns (block_ratios p, per-linear prune ratios)."""
    p = block_level_allocation(ctx, p_target, evo, alphas, log)
    per_linear: Dict[Key, float] = {}
    for d in range(ctx.num_blocks):
        per_linear.update(intra_block_allocation(ctx, d, float(p[d]), delta,
                                                 alphas))
        if log:
            log(f"block {d} fine allocation done (p_B={p[d]:.3f})")
    return p, per_linear
