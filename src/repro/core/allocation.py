"""Mixed-granularity sparsity allocation (paper §4.3).

Coarse (Alg. 3): evolutionary search over *block-level* prune ratios under a
global average constraint; fitness is the token-level KL divergence between
dense and sparse model outputs on the calibration set (Eq. 8).  Mutation is
localized (a small fraction of blocks, fixed step eps), offspring-only, no
crossover — per the paper's EvoPress-style setup.

Fine (Alg. 4): within each block, a greedy loop adds sparsity increments to
whichever linear layer increases the block's output reconstruction error
the least, until the block meets its budget.

Warm starts (ladder calibration, ``repro.sparsity.ladder``): both stages
accept the adjacent budget's solution as a starting point — the coarse
search via ``p_init`` (uniformly shifted to the new budget) plus a
``p_min`` floor that keeps every block at least as sparse as the previous
rung (the ladder's monotonicity invariant), the fine stage via a
per-linear ``p_init`` the greedy loop only ever adds to.  ``generations``
overrides the EvoConfig budget per call, so warm-started rungs run short
refinement searches instead of full cold ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.calibration import CalibContext, Key


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    generations: int = 400          # paper §5.1
    offspring: int = 64
    eps: float = 0.005              # 0.5% mutation step
    mutate_frac: float = 0.1        # 10% of blocks per mutation
    max_sparsity: float = 0.95
    seed: int = 0
    elitist: bool = True


def weighted_average(ctx: CalibContext, p: np.ndarray) -> float:
    w = np.array([ctx.block_weight(d) for d in range(ctx.num_blocks)])
    return float(np.sum(p * w) / np.sum(w))


def _ratios_uniform_block(ctx: CalibContext, p: np.ndarray) -> Dict[Key, float]:
    """All linears in block d share keep ratio 1-p[d] (coarse-stage view)."""
    ratios = {}
    for d in range(ctx.num_blocks):
        for path in ctx.keys_by_depth[d]:
            ratios[(d, path)] = 1.0 - float(p[d])
    return ratios


def block_fitness(ctx: CalibContext, p: np.ndarray,
                  alphas: Optional[Dict[Key, float]] = None) -> float:
    """KL fitness of a block-ratio vector under coarse-stage semantics
    (all linears in a block share its ratio) — the objective Alg. 3
    minimizes, exposed for warm-start/convergence diagnostics."""
    sp = ctx.make_sp(alphas or {}, _ratios_uniform_block(ctx, p))
    return ctx.fitness(sp)


def _repair_down(ctx: CalibContext, q: np.ndarray, p_target: float,
                 p_min: np.ndarray, eps: float, rng) -> np.ndarray:
    """Randomly walk blocks down by eps (never below p_min) until the
    weighted average meets the budget."""
    guard = 0
    while weighted_average(ctx, q) > p_target + 1e-9 and guard < 10000:
        b = rng.integers(len(q))
        q[b] = max(q[b] - eps, p_min[b])
        guard += 1
    return q


def _repair_up(ctx: CalibContext, q: np.ndarray, p_target: float,
               max_sparsity: float, eps: float, rng) -> np.ndarray:
    """Randomly walk blocks up by eps (never above max_sparsity) until
    the weighted average reaches the budget — clipping a warm start at
    max_sparsity sheds budget mass, and nothing downstream restores it
    (the KL fitness *prefers* denser candidates, so an under-budget rung
    would silently ship less sparsity than its label)."""
    guard = 0
    while weighted_average(ctx, q) < p_target - 1e-9 and guard < 10000:
        if not (q < max_sparsity - 1e-12).any():
            break                       # budget infeasible at this cap
        b = rng.integers(len(q))
        q[b] = min(q[b] + eps, max_sparsity)
        guard += 1
    return q


def block_level_allocation(ctx: CalibContext, p_target: float,
                           cfg: EvoConfig = EvoConfig(),
                           alphas: Optional[Dict[Key, float]] = None,
                           log=None, *,
                           p_init: Optional[np.ndarray] = None,
                           p_min: Optional[np.ndarray] = None,
                           generations: Optional[int] = None) -> np.ndarray:
    """Alg. 3.  Returns per-block prune ratios p (averaging to p_target).

    p_init       warm start: search from these ratios (uniformly shifted
                 to the new budget) instead of the uniform vector.
    p_min        per-block floor the search never crosses — with the
                 previous rung's ratios here, every candidate (and the
                 result) keeps at most as many channels per block as that
                 rung (ladder monotonicity).
    generations  per-call override of cfg.generations (warm-started
                 searches refine; they don't need the cold budget).
    """
    N = ctx.num_blocks
    rng = np.random.default_rng(cfg.seed)
    alphas = alphas or {}
    gens = cfg.generations if generations is None else generations
    p_min = np.zeros(N) if p_min is None else \
        np.asarray(p_min, np.float64).copy()
    if weighted_average(ctx, p_min) > p_target + 1e-9:
        raise ValueError(
            f"p_min averages to {weighted_average(ctx, p_min):.4f} > "
            f"budget {p_target}; ladder budgets must be ascending")

    def fitness(p):
        return block_fitness(ctx, p, alphas)

    if p_init is None:
        p = np.full(N, p_target, np.float64)
    else:
        p = np.asarray(p_init, np.float64).copy()
        # block weights are normalized, so a uniform shift moves the
        # weighted average by exactly the shift; clipping to the feasible
        # band can move it either way, so repair in both directions
        p += p_target - weighted_average(ctx, p)
    p = np.clip(p, p_min, cfg.max_sparsity)
    p = _repair_up(ctx, p, p_target, cfg.max_sparsity, cfg.eps, rng)
    p = _repair_down(ctx, p, p_target, p_min, cfg.eps, rng)
    best_fit = fitness(p)
    if log:
        log(f"gen 0 {'warm' if p_init is not None else 'uniform'} "
            f"KL={best_fit:.6f}")

    for gen in range(1, gens + 1):
        offspring = []
        for _ in range(cfg.offspring):
            q = p.copy()
            flips = max(1, int(round(N * cfg.mutate_frac)))
            for b in rng.choice(N, flips, replace=False):
                q[b] = min(q[b] + cfg.eps, cfg.max_sparsity)
            q = _repair_down(ctx, q, p_target, p_min, cfg.eps, rng)
            offspring.append(q)
        fits = [fitness(q) for q in offspring]
        i = int(np.argmin(fits))
        if not cfg.elitist or fits[i] < best_fit:
            p, best_fit = offspring[i], fits[i]
        if log and (gen % max(1, gens // 10) == 0):
            log(f"gen {gen} KL={best_fit:.6f} "
                f"spread=[{p.min():.3f},{p.max():.3f}]")
    return p


def intra_block_allocation(ctx: CalibContext, depth: int, p_block: float,
                           delta: float = 0.05,
                           alphas: Optional[Dict[Key, float]] = None,
                           max_sparsity: float = 0.95, *,
                           p_init: Optional[Dict[Key, float]] = None
                           ) -> Dict[Key, float]:
    """Alg. 4.  Returns per-linear prune ratios for block `depth` whose
    size-weighted average meets p_block.

    p_init: warm start — the greedy loop begins from these per-linear
    ratios (a previous ladder rung's fine allocation) and only ever adds
    sparsity, so the result is elementwise >= the starting point."""
    alphas = alphas or {}
    paths = ctx.keys_by_depth[depth]
    if not paths:
        return {}
    keys = [(depth, p) for p in paths]
    sizes = np.array([ctx.sizes[k] for k in keys])
    p_init = p_init or {}
    p = {k: float(p_init.get(k, 0.0)) for k in keys}

    def effective():
        vals = np.array([p[k] for k in keys])
        return float(np.sum(vals * sizes) / np.sum(sizes))

    def block_err(trial):
        from repro.core.alpha_search import _sp_for_block
        ratios = {k: 1.0 - v for k, v in trial.items()}
        sp = _sp_for_block(ctx, ctx.layers[depth], alphas, ratios)
        return ctx.block_mse(depth, sp)

    guard = 0
    while effective() < p_block - 1e-9 and guard < 10000:
        best_err, best_key = np.inf, None
        for k in keys:
            if p[k] + delta > max_sparsity:
                continue
            trial = dict(p)
            trial[k] = p[k] + delta
            err = block_err(trial)
            if err < best_err:
                best_err, best_key = err, k
        if best_key is None:
            break
        p[best_key] += delta
        guard += 1
    return p


def allocate(ctx: CalibContext, p_target: float,
             evo: EvoConfig = EvoConfig(), delta: float = 0.05,
             alphas: Optional[Dict[Key, float]] = None, log=None, *,
             p_init: Optional[np.ndarray] = None,
             p_min: Optional[np.ndarray] = None,
             layer_init: Optional[Dict[Key, float]] = None,
             generations: Optional[int] = None):
    """Coarse-to-fine: returns (block_ratios p, per-linear prune ratios).
    The keyword-only args warm-start both stages from an adjacent ladder
    rung's solution (see :func:`block_level_allocation`)."""
    p = block_level_allocation(ctx, p_target, evo, alphas, log,
                               p_init=p_init, p_min=p_min,
                               generations=generations)
    per_linear: Dict[Key, float] = {}
    for d in range(ctx.num_blocks):
        per_linear.update(intra_block_allocation(
            ctx, d, float(p[d]), delta, alphas, p_init=layer_init))
        if log:
            log(f"block {d} fine allocation done (p_B={p[d]:.3f})")
    return p, per_linear
