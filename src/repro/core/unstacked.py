"""Unstacked (per-depth python loop) model execution for calibration and
search.  The scanned production model is great for compile time but opaque
to per-block instrumentation; calibration instead unstacks the layer groups
into a list of per-depth layers and reuses the exact same ``layer_apply``,
so numerics are identical.

Only used on calibration-scale models (the paper's offline stage).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

# weight-leaf names WiSparse sparsifies: every channel-sparse linear in the
# zoo (attention q/k/v/o, MLP gate/up/down, SSM input/output projections);
# convs, norms, routers and the SSD recurrence stay dense
SPARSIFIABLE = {
    "wq", "wk", "wv", "wo", "wi_gate", "wi_up", "wi",
    "in_z", "in_x", "in_B", "in_C", "in_dt", "out_proj",
}


@dataclasses.dataclass
class DepthLayer:
    depth: int
    kind: Tuple[str, str]            # (mixer, ffn)
    group: int
    rep: int
    pos: int
    params: dict


def unstack_layers(cfg: ModelConfig, params) -> List[DepthLayer]:
    layers, depth = [], 0
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        gp = params["groups"][gi]
        for r in range(reps):
            for j, kind in enumerate(pattern):
                lp = jax.tree_util.tree_map(lambda a, r=r: a[r], gp[f"l{j}"])
                layers.append(DepthLayer(depth, kind, gi, r, j, lp))
                depth += 1
    return layers


def restack_sp(cfg: ModelConfig, per_depth_sp: List[Optional[dict]]):
    """Per-depth sparsity dicts -> stacked group sp tree for the scan model."""
    out, d = [], 0
    for pattern, reps in cfg.layer_groups():
        slots = [[] for _ in pattern]
        for _r in range(reps):
            for j in range(len(pattern)):
                slots[j].append(per_depth_sp[d])
                d += 1
        group = {}
        for j in range(len(pattern)):
            group[f"l{j}"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *slots[j])
        out.append(group)
    return out


def sparsifiable_leaves(layer_params: dict, prefix: str = ""):
    """Yield (path, weight) for sparsifiable linears within one layer."""
    for k, v in sorted(layer_params.items()):
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            yield from sparsifiable_leaves(v, path + "/")
        elif k in SPARSIFIABLE and v.ndim >= 2:
            yield path, v


def default_layer_sp(layer_params: dict):
    """Dense-equivalent sp dict (alpha=0, tau=-inf, keep=1) mirroring the
    sparsifiable subtree of one layer's params."""
    from repro.core import sparse_linear as sl

    def rec(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                sub = rec(v)
                if sub:
                    out[k] = sub
            elif k in SPARSIFIABLE and v.ndim >= 2:
                if v.ndim == 3:          # MoE (E, n_in, n_out): per-expert g
                    g = jax.vmap(sl.column_norms)(v)
                else:
                    g = sl.column_norms(v)
                out[k] = {"g": g,
                          "alpha": jnp.zeros((), jnp.float32),
                          "tau": jnp.full((), -jnp.inf, jnp.float32),
                          "keep_frac": jnp.ones((), jnp.float32)}
        return out

    return rec(layer_params)


def set_sp_leaf(sp: dict, path: str, key: str, value):
    node = sp
    parts = path.split("/")
    for p in parts[:-1]:
        node = node[p]
    node[parts[-1]] = dict(node[parts[-1]])
    node[parts[-1]][key] = jnp.asarray(value, jnp.float32)


def get_sp_leaf(sp: dict, path: str) -> dict:
    node = sp
    for p in path.split("/"):
        node = node[p]
    return node


def forward_unstacked(params, cfg: ModelConfig, tokens, *, layers=None,
                      per_depth_sp=None, patch_embeds=None, frames=None,
                      collect_block_inputs=False, policy=None):
    """Full forward via the python-loop layer list.  Returns
    (logits, block_inputs or None).  ``policy``: the SparsityPolicy driving
    every projection (depth ranges resolve per layer here; None runs
    dense)."""
    from repro.core import sparse_linear as _sl
    policy = policy if policy is not None else _sl.DENSE
    layers = layers or unstack_layers(cfg, params)
    enc_out = None
    if cfg.family == "encdec" and frames is not None:
        enc_out = M.encode(params, frames, cfg, policy=policy)
    x = M.embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    if cfg.family == "encdec":
        from repro.models.layers import sinusoidal_positions
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model
                                     ).astype(x.dtype)[None]
    block_inputs = [] if collect_block_inputs else None
    for dl in layers:
        if collect_block_inputs:
            block_inputs.append(x)
        sp = per_depth_sp[dl.depth] if per_depth_sp is not None else None
        x, _ = M.layer_apply(dl.params, x, cfg, dl.kind, sp, None, None,
                             "train", enc_out,
                             policy=policy.resolve_depth(dl.depth))
    x = M.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return M.lm_logits(params, x, cfg), block_inputs


def block_forward(dl: DepthLayer, x, cfg: ModelConfig, sp=None, enc_out=None,
                  policy=None):
    """One transformer block (paper's unit of sensitivity analysis)."""
    from repro.core import sparse_linear as _sl
    policy = policy if policy is not None else _sl.DENSE
    out, _ = M.layer_apply(dl.params, x, cfg, dl.kind, sp, None, None,
                           "train", enc_out,
                           policy=policy.resolve_depth(dl.depth))
    return out
