"""WiSparse core: the paper's contribution (scoring, alpha search,
mixed-granularity allocation, calibration, sparse projection dispatch)."""
from repro.core import sparse_linear

__all__ = ["sparse_linear"]
