"""Schema + builders for stacked sparsity-parameter trees (the form the
scanned production model consumes, and the abstract inputs the dry-run
lowers with)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import sparse_linear as sl
from repro.core.unstacked import SPARSIFIABLE
from repro.models.params import ParamSpec, abstract_params, logical_axes, stacked


def _rec_schema(d):
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            sub = _rec_schema(v)
            if sub:
                out[k] = sub
        elif isinstance(v, ParamSpec) and k in SPARSIFIABLE and len(v.shape) >= 2:
            if len(v.shape) == 3:      # MoE (E, n, m): per-expert g
                gspec = ParamSpec(v.shape[:2], v.axes[:2], dtype="float32")
            else:
                gspec = ParamSpec(v.shape[:1], v.axes[:1], dtype="float32")
            out[k] = {
                "g": gspec,
                "alpha": ParamSpec((), (), dtype="float32"),
                "tau": ParamSpec((), (), dtype="float32"),
                "keep_frac": ParamSpec((), (), dtype="float32"),
            }
    return out


def sparsity_schema(cfg: ModelConfig):
    """List over layer groups of stacked sp ParamSpec trees."""
    from repro.models.model import layer_schema
    groups = []
    for pattern, reps in cfg.layer_groups():
        gd = {}
        for j, kind in enumerate(pattern):
            sub = _rec_schema(layer_schema(cfg, kind,
                                           cross=(cfg.family == "encdec")))
            gd[f"l{j}"] = stacked(sub, reps, "layers")
        groups.append(gd)
    return groups


def abstract_sp(cfg: ModelConfig):
    schema = sparsity_schema(cfg)
    return abstract_params(schema, "float32"), logical_axes(schema)


def default_sp_stacked(params, cfg: ModelConfig, keep_frac: float = 1.0,
                       alpha: float = 1.0):
    """Concrete stacked sp tree from model weights: g = column norms,
    uniform alpha/keep (tau unused by the top-k serving backends)."""
    groups = []
    for gi, (pattern, _reps) in enumerate(cfg.layer_groups()):
        gp = params["groups"][gi]

        def rec(d):
            out = {}
            for k, v in d.items():
                if isinstance(v, dict):
                    sub = rec(v)
                    if sub:
                        out[k] = sub
                elif k in SPARSIFIABLE and hasattr(v, "ndim") and v.ndim >= 3:
                    # stacked weight (reps, n, m) or (reps, E, n, m)
                    if v.ndim == 4:
                        g = jax.vmap(jax.vmap(sl.column_norms))(v)
                    else:
                        g = jax.vmap(sl.column_norms)(v)
                    ones = jnp.ones((v.shape[0],), jnp.float32)
                    out[k] = {"g": g,
                              "alpha": ones * alpha,
                              "tau": ones * jnp.inf,
                              "keep_frac": ones * keep_frac}
            return out

        groups.append({f"l{j}": rec(gp[f"l{j}"])
                       for j in range(len(pattern))})
    return groups
