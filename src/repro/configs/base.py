"""Model/shape configuration dataclasses and the architecture registry.

Every assigned architecture gets its own module in ``repro.configs`` exporting
``CONFIG``.  ``get_config(name)`` resolves them; ``reduced(cfg)`` produces a
tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


# Layer "kinds": (mixer, ffn).  mixer in {"attn", "local", "global", "mamba",
# "attn_bidir"}; ffn in {"dense", "moe", "none"}.
LayerKind = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MLP ---
    mlp_activation: str = "swiglu"   # swiglu | geglu
    # --- attention ---
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0       # final-logit softcap (gemma2)
    attn_softcap: float = 0.0        # attention-logit softcap (gemma2)
    sliding_window: int = 0          # window for "local" layers (0 = unused)
    layer_pattern: Tuple[LayerKind, ...] = (("attn", "dense"),)
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256             # SSD chunk length
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0          # precomputed conv-frontend frames (stub input)
    # --- VLM (internvl) ---
    vision_prefix: int = 0           # precomputed patch-embedding prefix length
    # --- misc ---
    scale_embed: bool = False        # gemma-family sqrt(d_model) embed scale
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    max_position: int = 1 << 20
    # Does the arch support O(1)-memory-per-token decode at 500k context?
    # (SSM / hybrid / mostly-local-attention archs).  Pure full-attention
    # archs skip the long_500k cell (see DESIGN.md SS5).
    subquadratic: bool = False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def num_experts_padded(self) -> int:
        """Experts padded up to the TP width (16) so the expert dim always
        shards (granite-3b: 40 -> 48).  Pad experts get -inf router logits
        and are never selected — numerics match the unpadded model
        (EXPERIMENTS.md SSPerf iteration C3)."""
        e = self.num_experts
        if e > 16 and e % 16:
            return ((e + 15) // 16) * 16
        return e

    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        """Expanded per-layer (mixer, ffn) kinds for all num_layers layers."""
        p = self.layer_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.num_layers])

    def layer_groups(self):
        """[(pattern, repeats)] chunks: a scan over `repeats` periods of
        `pattern`, plus a possibly-shorter trailing group."""
        p = self.layer_pattern
        full, rem = divmod(self.num_layers, len(p))
        groups = []
        if full:
            groups.append((p, full))
        if rem:
            groups.append((p[:rem], 1))
        return groups


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "granite_moe_1b_a400m",
    "granite_moe_3b_a800m",
    "internvl2_26b",
    "deepseek_67b",
    "gemma2_2b",
    "gemma_2b",
    "gemma3_4b",
    "mamba2_130m",
    "whisper_large_v3",
    "jamba_v01_52b",
    # the paper's own model, used by benchmarks/examples
    "llama31_8b",
]


def get_config(name: str) -> ModelConfig:
    mod_name = name.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def runnable_cells():
    """All (arch, shape) cells that the dry-run must lower, with skips
    applied per DESIGN.md SS5 (long_500k only for subquadratic archs)."""
    cells, skips = [], []
    for arch in ARCH_IDS:
        if arch == "llama31_8b":
            continue  # paper's model is extra, not an assigned cell
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                skips.append((arch, shape.name, "full-attention KV at 524k"))
                continue
            cells.append((arch, shape.name))
    return cells, skips


def reduced(cfg: ModelConfig, seq_hint: int = 64) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        num_layers=max(2, len(cfg.layer_pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        moe_d_ff=64 if cfg.num_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        sliding_window=min(cfg.sliding_window, seq_hint // 2) if cfg.sliding_window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_frames=16 if cfg.encoder_frames else 0,
        vision_prefix=8 if cfg.vision_prefix else 0,
        max_position=4096,
        dtype="float32",
    )
