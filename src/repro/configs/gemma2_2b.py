"""gemma2-2b [dense] — arXiv:2408.00118.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, local+global
alternating (window 4096), attention+final logit softcaps, GeGLU,
head_dim=256.  Local-attention-dominant -> runs long_500k (bounded KV on
local layers; see DESIGN.md SS5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2_2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_activation="geglu",
    layer_pattern=(("local", "dense"), ("global", "dense")),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    rope_theta=10000.0,
    subquadratic=True,
)
