from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    LayerKind,
    ModelConfig,
    ShapeConfig,
    get_config,
    reduced,
    runnable_cells,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "LayerKind",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "reduced",
    "runnable_cells",
]
