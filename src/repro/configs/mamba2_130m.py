"""mamba2-130m [ssm] — arXiv:2405.21060 (SSD / state-space duality).

24L d_model=768 (attn-free, no FFN) vocab=50280, ssm_state=128,
expand=2 -> d_inner=1536, head_dim=64 -> 24 SSD heads.  O(1) decode state
-> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=(("mamba", "none"),),
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    subquadratic=True,
)
