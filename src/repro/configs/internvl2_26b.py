"""internvl2-26b [vlm] — arXiv:2404.16821 (InternViT-6B + InternLM2-20B).

LM backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings for a 256-token image prefix (DESIGN.md SS5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    layer_pattern=(("attn", "dense"),),
    rope_theta=1000000.0,
    vision_prefix=256,
)
