"""granite-moe-3b-a800m [moe] — granite-3.0-3b-a800m family.

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_3b_a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_tok=8,
    layer_pattern=(("attn", "moe"),),
    tie_embeddings=True,
    rope_theta=10000.0,
)
