"""whisper-large-v3 [audio] — arXiv:2212.04356.

Enc-dec, 32+32L d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
The conv frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (1500 frames = 30 s).  Shapes' ``seq_len`` applies to the
decoder (DESIGN.md SS5).  Full attention decoder -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_large_v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    mlp_activation="gelu",
    layer_pattern=(("attn", "dense"),),
    encoder_layers=32,
    encoder_frames=1500,
    rope_theta=0.0,  # whisper uses learned/sinusoidal absolute positions
)
