"""gemma3-4b [dense] — hf:google/gemma-3-4b-pt (unverified tier).

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, 5:1 local:global
(window 1024), head_dim=256, 128k context.  Mostly-local attention ->
runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    mlp_activation="geglu",
    layer_pattern=(
        ("local", "dense"), ("local", "dense"), ("local", "dense"),
        ("local", "dense"), ("local", "dense"), ("global", "dense"),
    ),
    sliding_window=1024,
    tie_embeddings=True,
    scale_embed=True,
    rope_theta=1000000.0,
    subquadratic=True,
)
