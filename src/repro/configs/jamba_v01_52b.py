"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2 on
every 2nd layer, Mamba:attention 7:1 interleave (attention at period index
4), ssm_state=16.  Hybrid -> runs long_500k.
"""
from repro.configs.base import ModelConfig

_PERIOD = (
    ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
    ("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    layer_pattern=_PERIOD,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=0.0,  # jamba uses no positional encoding (mamba provides order)
    subquadratic=True,
)
