"""gemma-2b [dense] — arXiv:2403.08295.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU, head_dim=256.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma_2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_activation="geglu",
    layer_pattern=(("attn", "dense"),),
    tie_embeddings=True,
    scale_embed=True,
    rope_theta=10000.0,
)
