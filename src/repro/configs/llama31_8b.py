"""llama3.1-8b — the paper's primary evaluation model (arXiv:2407.21783).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope theta 500k.
Used by benchmarks/examples; not one of the 10 assigned dry-run archs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama31_8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=(("attn", "dense"),),
    rope_theta=500000.0,
)
