from repro.models import api, attention, layers, mlp, model, moe, params, ssm

__all__ = ["api", "attention", "layers", "mlp", "model", "moe", "params", "ssm"]
