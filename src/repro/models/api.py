"""Public model API: cache schemas, input specs, loss and step factories.

Everything is expressed over the same ``ParamSpec`` schema machinery as the
weights, so abstract lowering (dry-run), initialization (tests) and sharding
(rules table) all derive from one source of truth.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models import params as P
from repro.optim import adamw

INT = "int32"


# ---------------------------------------------------------------------------
# Cache schema
# ---------------------------------------------------------------------------

def cache_schema(cfg: ModelConfig, batch: int, cache_len: int):
    """Pytree of ParamSpec mirroring the cache structure run_groups expects:
    list over groups -> tuple over pattern positions -> {"self"|"ssm"|"cross"}."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def kv_pair(reps, T):
        # decode layouts: K (B,KV,hd,T), V (B,KV,T,hd) — the dot-ready
        # orientations, so decode never materializes transposed copies
        return {
            "k": P.ParamSpec((reps, batch, KV, hd, T),
                             ("layers", "batch", "kv_heads", None, "kv_seq"),
                             init="zeros"),
            "v": P.ParamSpec((reps, batch, KV, T, hd),
                             ("layers", "batch", "kv_heads", "kv_seq", None),
                             init="zeros"),
        }

    groups = []
    for pattern, reps in cfg.layer_groups():
        entries = []
        for (mixer, _ffn) in pattern:
            e = {}
            if mixer in ("attn", "global", "attn_bidir"):
                e["self"] = kv_pair(reps, cache_len)
            elif mixer == "local":
                e["self"] = kv_pair(reps, min(cfg.sliding_window, cache_len))
            elif mixer == "mamba":
                w, di, n = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
                H, Pd = cfg.ssm_nheads, cfg.ssm_head_dim
                e["ssm"] = {
                    "conv_x": P.ParamSpec((reps, batch, w - 1, di),
                                          ("layers", "batch", None, "mlp"),
                                          init="zeros"),
                    "conv_B": P.ParamSpec((reps, batch, w - 1, n),
                                          ("layers", "batch", None, None),
                                          init="zeros"),
                    "conv_C": P.ParamSpec((reps, batch, w - 1, n),
                                          ("layers", "batch", None, None),
                                          init="zeros"),
                    "ssm": P.ParamSpec((reps, batch, H, Pd, n),
                                       ("layers", "batch", "ssm_heads", None, None),
                                       init="zeros", dtype="float32"),
                }
            if cfg.family == "encdec":
                e["cross"] = kv_pair(reps, cfg.encoder_frames)
            entries.append(e)
        groups.append(tuple(entries))
    return groups


def prefix_segment_schema(cfg: ModelConfig, length: int):
    """Schema of one slot's KV *prefix segment* — the immutable unit the
    serving prefix cache (``repro.serving.prefix_cache``) extracts from
    and copies into the slot pool: the cache tree for a single sequence
    (batch=1) truncated to ``length`` positions.  Deriving it from
    :func:`cache_schema` keeps segment layouts and pool layouts in
    lockstep by construction."""
    return cache_schema(cfg, 1, length)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype: Optional[str] = None):
    """Abstract inputs for a (arch x shape) cell.  For decode shapes this is
    the serve_step signature (one new token + a KV cache of seq_len)."""
    dt = dtype or cfg.dtype
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    if shape.mode in ("train", "prefill"):
        specs = {}
        if cfg.family == "vlm":
            Ptok = cfg.vision_prefix
            specs["patch_embeds"] = sds((B, Ptok, cfg.d_model), jnp.dtype(dt))
            specs["tokens"] = sds((B, S - Ptok), jnp.dtype(INT))
        elif cfg.family == "encdec":
            specs["frames"] = sds((B, cfg.encoder_frames, cfg.d_model),
                                  jnp.dtype(dt))
            specs["tokens"] = sds((B, S), jnp.dtype(INT))
        else:
            specs["tokens"] = sds((B, S), jnp.dtype(INT))
        return specs

    caches = P.abstract_params(cache_schema(cfg, B, S), dt)
    return {
        "tokens": sds((B,), jnp.dtype(INT)),
        "positions": sds((B,), jnp.dtype(INT)),
        "caches": caches,
    }


def input_axes(cfg: ModelConfig, shape: ShapeConfig):
    """Logical axes pytree matching input_specs (for in_shardings)."""
    if shape.mode in ("train", "prefill"):
        axes = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            axes["patch_embeds"] = ("batch", "seq", "embed_act")
        elif cfg.family == "encdec":
            axes["frames"] = ("batch", "seq", "embed_act")
        return axes
    return {
        "tokens": ("batch",),
        "positions": ("batch",),
        "caches": P.logical_axes(cache_schema(cfg, shape.global_batch,
                                              shape.seq_len)),
    }


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------

def token_loss(cfg: ModelConfig, logits, tokens, text_start: int = 0):
    """Next-token CE in f32.  logits: (B,S,V) over [prefix+]text positions."""
    lg = logits[:, text_start: -1].astype(jnp.float32) if logits.shape[1] > 1 \
        else logits.astype(jnp.float32)
    labels = tokens[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def make_loss_fn(cfg: ModelConfig, remat: str = "none", policy=None):
    """``policy``: static SparsityPolicy baked into the returned callable
    (override per call via the ``policy=`` kwarg)."""
    def loss_fn(params, batch, sp=None, policy=policy):
        kwargs = {}
        text_start = 0
        if cfg.family == "vlm":
            kwargs["patch_embeds"] = batch["patch_embeds"]
            text_start = cfg.vision_prefix
        elif cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        logits, _ = M.forward(params, cfg, tokens=batch["tokens"],
                              mode="train", sp=sp, remat=remat,
                              policy=policy, **kwargs)
        return token_loss(cfg, logits, batch["tokens"], text_start)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    remat: str = "none", accum_steps: int = 1, policy=None):
    loss_fn = make_loss_fn(cfg, remat, policy=policy)

    def train_step(params, opt_state, batch, sp=None):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, sp)
        else:
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb, sp)
                return (acc[0] + l, jax.tree_util.tree_map(jnp.add, acc[1], g)), None
            z = (jnp.zeros(()),
                 jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                        params))
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(micro, z, mbs)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        new_params, new_opt, metrics = adamw.update(grads, opt_state, params,
                                                    opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, policy=None):
    def prefill_step(params, batch, sp=None, policy=policy):
        kwargs = {}
        if cfg.family == "vlm":
            kwargs["patch_embeds"] = batch["patch_embeds"]
        elif cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        logits, caches = M.forward(params, cfg, tokens=batch["tokens"],
                                   mode="prefill", sp=sp, policy=policy,
                                   **kwargs)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, policy=None, aligned: bool = False):
    def decode_step(params, batch, sp=None, policy=policy):
        logits, caches = M.forward(
            params, cfg, tokens=batch["tokens"], mode="decode",
            caches=batch["caches"], positions=batch["positions"], sp=sp,
            policy=policy, aligned=aligned)
        return logits, caches
    return decode_step


def make_slot_decode_step(cfg: ModelConfig):
    """Continuous-batching decode over a slot pool: one token per slot at
    per-slot positions, with the active-slot mask weighting the shared
    top-k saliency aggregate (empty slots don't pollute the layer's
    channel set; with every slot active the floats match the plain
    batched decode exactly).  ``policy`` is the phase's static
    SparsityPolicy; ``active`` rides in as an explicit token_weights
    argument, not ambient state."""
    def slot_decode_step(params, tokens, positions, caches, sp=None,
                         active=None, policy=None):
        logits, caches = M.forward(
            params, cfg, tokens=tokens, mode="decode", caches=caches,
            positions=positions, sp=sp, policy=policy, token_weights=active)
        return logits, caches
    return slot_decode_step


def make_chunk_prefill_step(cfg: ModelConfig):
    """Chunked prefill of one request directly into the slot pool: tokens
    (1,C) at chunk-start ``offset`` for pool slot ``slot``.  Pad tokens in
    the final chunk carry zero weight in the shared saliency (explicit
    ``weights`` argument).  Returns logits for every chunk position (the
    engine reads the last real one) and the updated pool.

    ``offset`` need not be 0 for the first chunk: under prefix caching
    the slot's positions ``[0, offset)`` hold a reused cached prefix and
    prefill starts at the matched length — the chunk attends the cached
    span through the same causal mask as its own earlier chunks."""
    def chunk_prefill_step(params, tokens, offset, slot, caches, sp=None,
                           weights=None, policy=None):
        logits, caches = M.forward(
            params, cfg, tokens=tokens, mode="chunk", caches=caches,
            positions=offset, sp=sp, slot=slot, policy=policy,
            token_weights=weights)
        return logits, caches
    return chunk_prefill_step


def make_verify_step(cfg: ModelConfig):
    """Speculative-decoding verify: a fixed-length multi-token decode over
    the slot pool, reusing the chunk-prefill write-in-place machinery
    (vmapped over slots).  ``tokens`` (S, gamma+1) — row s is slot s's
    last committed token followed by its gamma draft tokens — at per-slot
    start offsets ``positions`` (S,).  K/V for every window position are
    re-projected under the *verifier* policy and written in place, so the
    committed cache prefix is always verifier-faithful regardless of what
    the drafter wrote there.  ``weights`` (S, gamma+1) masks inactive
    slots out of the shared top-k saliency like decode's ``active`` mask.
    Returns logits for every window position (S, gamma+1, V) —
    ``logits[s, i]`` is the verifier's next-token distribution after
    consuming row s's i-th token — plus the updated pool.  Jit compiles
    once per (gamma, policy): the token shape pins gamma, the policy is
    static."""
    def verify_step(params, tokens, positions, caches, sp=None,
                    weights=None, policy=None):
        logits, caches = M.forward(
            params, cfg, tokens=tokens, mode="verify", caches=caches,
            positions=positions, sp=sp, policy=policy,
            token_weights=weights)
        return logits, caches
    return verify_step


def step_for_shape(cfg: ModelConfig, shape: ShapeConfig,
                   opt_cfg: Optional[adamw.AdamWConfig] = None,
                   remat: str = "none", policy=None, aligned: bool = False):
    """The jit-able callable a dry-run cell lowers, plus its input maker.
    ``policy`` (static) is baked into the step; ``aligned`` selects the
    single-DUS batched decode cache write."""
    if shape.mode == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        step = make_train_step(cfg, opt_cfg, remat=remat, policy=policy)
        return step, "train"
    if shape.mode == "prefill":
        return make_prefill_step(cfg, policy=policy), "prefill"
    return make_decode_step(cfg, policy=policy, aligned=aligned), "decode"


def abstract_model(cfg: ModelConfig):
    schema = M.model_schema(cfg)
    return (P.abstract_params(schema, cfg.dtype), P.logical_axes(schema), schema)


def init_model(cfg: ModelConfig, seed: int = 0):
    schema = M.model_schema(cfg)
    return P.init_params(schema, jax.random.PRNGKey(seed), cfg.dtype)
