"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Prefill/train uses the chunked SSD algorithm as a single ``lax.scan`` over
sequence chunks (intra-chunk quadratic term + inter-chunk state recurrence),
so activation memory stays O(B * chunk^2 * H) regardless of sequence length.
Decode is the O(1) recurrent state update.  ngroups is fixed at 1.

WiSparse applicability: ``in_*``/``out_proj`` are the channel-sparsifiable
linears (see ``repro.core.unstacked.SPARSIFIABLE``); the SSD scan itself is
a recurrence over state, not a channel-sparse matmul, so it stays dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rmsnorm, silu
from repro.models.params import ParamSpec
from repro.distributed.sharding import constrain


def mamba_schema(cfg):
    d, di, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_nheads, cfg.ssm_conv)
    return {
        "in_z": ParamSpec((d, di), ("embed", "mlp")),
        "in_x": ParamSpec((d, di), ("embed", "mlp")),
        "in_B": ParamSpec((d, n), ("embed", None)),
        "in_C": ParamSpec((d, n), ("embed", None)),
        "in_dt": ParamSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((w, di), (None, "mlp"), scale=0.5),
        "conv_B": ParamSpec((w, n), (None, None), scale=0.5),
        "conv_C": ParamSpec((w, n), (None, None), scale=0.5),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="ssm_A", dtype="float32"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="ssm_dt", dtype="float32"),
        "norm": ParamSpec((di,), (None,), init="zeros"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _causal_conv(u, w):
    """Depthwise causal conv, u: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(W):
        shift = W - 1 - i
        ui = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1]]
        out = out + ui.astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(u.dtype)


def _conv_step(state, u_new, w):
    """state: (B,W-1,C) last inputs; u_new: (B,C) -> (out, new_state)."""
    hist = jnp.concatenate([state, u_new[:, None]], axis=1)   # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(u_new.dtype)
    return out, hist[:, 1:]


def _project_inputs(p, x, sp, policy=None, token_weights=None):
    sp = sp or {}

    def proj(name):
        return dense(x, p[name], sp.get(name), policy=policy,
                     role=f"mamba/{name}", token_weights=token_weights)

    return (proj("in_z"), proj("in_x"), proj("in_B"), proj("in_C"),
            proj("in_dt"))


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P); dt: (B,S,H) (already softplus'd); A: (H,) < 0;
    Bm/Cm: (B,S,N).  Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    L = max(1, min(chunk, S))
    while S % L:
        L -= 1
    nc = S // L

    xc = jnp.moveaxis(xh.reshape(Bsz, nc, L, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, L, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nc, L, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nc, L, N), 1, 0)

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_step(S_prev, inputs):
        xb, dtb, Bb, Cb = inputs                   # (B,L,H,P),(B,L,H),(B,L,N)x2
        dtb = dtb.astype(jnp.float32)
        dA = dtb * A                               # (B,L,H), negative
        cum = jnp.cumsum(dA, axis=1)               # inclusive cumsum
        # intra-chunk quadratic term
        sc = jnp.einsum("bln,bmn->blm", Cb.astype(jnp.float32),
                        Bb.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])      # (B,L,M,H)
        idx = jnp.arange(L)
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        att = sc[..., None] * jnp.where(causal, decay, 0.0) * dtb[:, None]
        y = jnp.einsum("blmh,bmhp->blhp", att, xb.astype(jnp.float32))
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bln,bhpn->blhp", Cb.astype(jnp.float32),
                           S_prev) * jnp.exp(cum)[..., None]
        # state update
        to_end = jnp.exp(cum[:, -1:, :] - cum) * dtb            # (B,L,H)
        Sc = jnp.einsum("blh,bln,blhp->bhpn", to_end,
                        Bb.astype(jnp.float32), xb.astype(jnp.float32))
        S_new = S_prev * jnp.exp(cum[:, -1])[..., None, None] + Sc
        return S_new, y.astype(xh.dtype)

    final, yc = jax.lax.scan(chunk_step, init_state, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, P)
    return y, final


def mamba_apply(p, x, cfg, sp=None, cache=None, mode: str = "train",
                policy=None, token_weights=None):
    """x: (B,S,D) for train/prefill, (B,1,D) for decode.

    Returns (out, new_cache).  Cache: {"conv_x","conv_B","conv_C","ssm"}.
    """
    H, P, N = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Dp = p["D"].astype(jnp.float32)

    if mode == "decode":
        xt = x[:, 0]
        z, xs, Bm, Cm, dt = _project_inputs(p, xt, sp, policy, token_weights)
        xs, conv_x = _conv_step(cache["conv_x"], xs, p["conv_x"])
        Bm, conv_B = _conv_step(cache["conv_B"], Bm, p["conv_B"])
        Cm, conv_C = _conv_step(cache["conv_C"], Cm, p["conv_C"])
        xs, Bm, Cm = silu(xs), silu(Bm), silu(Cm)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        xh = xs.reshape(-1, H, P).astype(jnp.float32)
        dA = jnp.exp(dt * A)                                    # (B,H)
        S_new = (cache["ssm"] * dA[..., None, None]
                 + jnp.einsum("bh,bn,bhp->bhpn", dt,
                              Bm.astype(jnp.float32), xh))
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), S_new)
        y = y + Dp[:, None] * xh
        y = y.reshape(xt.shape[0], H * P).astype(x.dtype)
        y = rmsnorm(y * silu(z), p["norm"], cfg.norm_eps)
        out = dense(y, p["out_proj"], (sp or {}).get("out_proj"),
                    row_parallel=True, policy=policy, role="mamba/out_proj",
                    token_weights=token_weights)
        return out[:, None], {"conv_x": conv_x, "conv_B": conv_B,
                              "conv_C": conv_C, "ssm": S_new}

    B, S, D = x.shape
    z, xs, Bm, Cm, dt = _project_inputs(p, x, sp, policy, token_weights)
    raw = (xs, Bm, Cm)          # pre-conv inputs, tails feed the conv cache
    xs = silu(_causal_conv(xs, p["conv_x"]))
    Bm = silu(_causal_conv(Bm, p["conv_B"]))
    Cm = silu(_causal_conv(Cm, p["conv_C"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(B, S, H, P)
    xh = constrain(xh, "batch", None, "ssm_heads", None)
    y, S_fin = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + Dp[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, H * P).astype(x.dtype)
    y = rmsnorm(y * silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"], (sp or {}).get("out_proj"),
                row_parallel=True, policy=policy, role="mamba/out_proj",
                token_weights=token_weights)

    new_cache = None
    if mode == "prefill":
        w = cfg.ssm_conv
        def tail(u):
            return u[:, -(w - 1):] if S >= w - 1 else jnp.pad(
                u, ((0, 0), (w - 1 - S, 0), (0, 0)))[:, -(w - 1):]
        # conv caches hold the *pre-activation* projected inputs
        new_cache = {"conv_x": tail(raw[0]), "conv_B": tail(raw[1]),
                     "conv_C": tail(raw[2]), "ssm": S_fin}
    return out, new_cache
