"""Parameter schema: one source of truth for shapes, logical sharding axes
and initializers.

A model's parameters are described as a pytree whose leaves are
``ParamSpec``s.  From the same schema we derive:
  * ``init_params``      — concrete arrays (deterministic per-path keys),
  * ``abstract_params``  — ``jax.ShapeDtypeStruct``s for AOT lowering,
  * ``logical_axes``     — logical axis-name tuples for the sharding rules.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim
    init: str = "normal"                 # normal|zeros|ones|ssm_A|ssm_dt|identity_conv
    scale: float = 0.02
    dtype: Optional[str] = None          # overrides the model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _flatten(schema):
    return jax.tree_util.tree_flatten_with_path(schema, is_leaf=_is_spec)


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _init_leaf(spec: ParamSpec, key, default_dtype: str):
    dtype = jnp.dtype(spec.dtype or default_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_A":
        # A_log init: log of uniform [1, 16] per head (mamba2 default)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt":
        # dt bias: inverse-softplus of uniform [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dtype)
    # truncated-normal fan-agnostic init
    w = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    return (w * spec.scale).astype(dtype)


def init_params(schema, key, default_dtype: str = "float32"):
    leaves, treedef = _flatten(schema)
    out = []
    for _i, (path, spec) in enumerate(leaves):
        # crc32, NOT hash(): builtin str hashing is salted per process
        # (PYTHONHASHSEED), which would make "seed 0" params differ
        # across processes and break cross-process record/replay
        tag = zlib.crc32(_path_str(path).encode()) & 0x7FFFFFFF
        k = jax.random.fold_in(key, np.uint32(tag))
        out.append(_init_leaf(spec, k, default_dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(schema, default_dtype: str = "float32"):
    def f(spec):
        return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype or default_dtype))

    return jax.tree_util.tree_map(f, schema, is_leaf=_is_spec)


def logical_axes(schema):
    return jax.tree_util.tree_map(lambda s: s.axes, schema, is_leaf=_is_spec)


def stacked(schema, n: int, axis_name: Optional[str] = None):
    """Prepend a stacked-layers dim of size n to every spec in the subtree."""
    def f(spec: ParamSpec):
        return ParamSpec((n,) + spec.shape, (axis_name,) + spec.axes,
                         spec.init, spec.scale, spec.dtype)

    return jax.tree_util.tree_map(f, schema, is_leaf=_is_spec)


def count_params(schema) -> int:
    leaves, _ = _flatten(schema)
    return int(sum(int(np.prod(s.shape)) for _, s in leaves))
