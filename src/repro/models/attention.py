"""Attention: flash-style chunked prefill/train attention (online softmax,
GQA, sliding window, logit softcap) and single-query decode attention over
a (possibly sequence-sharded) KV cache.

Pure jnp + lax.scan so XLA SPMD can partition it; the sequence-sharded
decode path is flash-decoding realized by the partitioner (softmax
reductions over the sharded KV axis become all-reduces).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

NEG_INF = -1e30


def _chunk(n: int, want: int) -> int:
    """Largest chunk <= want that divides n."""
    c = min(want, n)
    while n % c:
        c -= 1
    return c


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    attn_softcap: float = 0.0, q_offset: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd).

    window > 0 limits attention to the last `window` positions (inclusive
    of self) and computes only the sliced KV span per query chunk, so local
    layers cost O(S*window) rather than O(S*T).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qc = _chunk(S, q_chunk)
    nq = S // qc
    qr = q.reshape(B, nq, qc, KV, G, hd)

    if window and window < T:
        return _local_attention(qr, k, v, window=window, softcap=attn_softcap,
                                q_offset=q_offset, scale=scale).reshape(B, S, H, hd)

    kc = _chunk(T, kv_chunk)
    nk = T // kc
    kr = k.reshape(B, nk, kc, KV, hd)
    vr = v.reshape(B, nk, kc, KV, hd)

    def q_block(qi, qb):
        # qb: (B,qc,KV,G,hd)
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, kb, vb = inputs
            kvpos = kj * kc + jnp.arange(kc)
            s = jnp.einsum("bqKGd,bkKd->bKGqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if attn_softcap:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            if causal:
                mask = kvpos[None, :] <= qpos[:, None]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bKGqk,bkKd->bKGqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, qc), jnp.float32),
                jnp.zeros((B, KV, G, qc, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)                       # (B,qc,KV,G,hd)

    def scan_q(_, inputs):
        qi, qb = inputs
        return None, q_block(qi, qb)

    _, out = jax.lax.scan(scan_q, None,
                          (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(out, 0, 1)                             # (B,nq,qc,KV,G,hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _local_attention(qr, k, v, *, window: int, softcap: float,
                     q_offset: int, scale: float):
    """Sliding-window attention: per q-chunk, slice exactly the
    [start-window, start+qc) KV span.  qr: (B,nq,qc,KV,G,hd)."""
    B, nq, qc, KV, G, hd = qr.shape
    T = k.shape[1]
    span = window + qc
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def q_block(_, inputs):
        qi, qb = inputs
        start = qi * qc                                     # span starts at abs pos start-window
        kb = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qpos = q_offset + start + jnp.arange(qc)
        kvpos = q_offset + start - window + jnp.arange(span)
        s = jnp.einsum("bqKGd,bkKd->bKGqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = ((kvpos[None, :] <= qpos[:, None])
                & (kvpos[None, :] > qpos[:, None] - window)
                & (kvpos[None, :] >= q_offset))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bKGqk,bkKd->bqKGd", p.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        return None, out

    _, out = jax.lax.scan(q_block, None,
                          (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    return jnp.moveaxis(out, 0, 1).astype(qr.dtype)


def decode_attention(q, k_cache, v_cache, positions, k_new=None, v_new=None,
                     *, rolling: bool = False, attn_softcap: float = 0.0):
    """Single new query vs a *pre-transposed* cache plus an explicit
    new-token term.

    q: (B,H,hd); k_cache: (B,KV,hd,T); v_cache: (B,KV,T,hd) — the layouts
    the decode dots want, so XLA never materializes a transposed copy of
    the cache (measured in the decode dry-runs).  k_new/v_new (B,KV,hd)
    carry the current token, which is attended explicitly and written to
    the cache independently (so the cache write can be an update-only DUS
    into the carried stack).  Cache slots at `positions` and beyond are
    masked.  rolling=True: slot p%T holds position p (local windows).
    """
    B, H, hd = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[3]
    G = H // KV
    scale = hd ** -0.5
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bKGd,bKdt->bKGt", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    slot = jnp.arange(T)
    if rolling:
        # slots hold positions pos-T .. pos-1; exclude the stale slot
        # (pos % T holds pos-T, outside the window) once wrapped
        valid = jnp.where(positions[:, None] < T,
                          slot[None, :] < positions[:, None],
                          slot[None, :] != (positions % T)[:, None])
    else:
        valid = slot[None, :] < positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    # explicit online softmax over [cache, new token] — no concat along the
    # (possibly sharded) T dim
    if k_new is not None:
        s_new = jnp.einsum("bKGd,bKd->bKG", qr, k_new,
                           preferred_element_type=jnp.float32) * scale
        if attn_softcap:
            s_new = attn_softcap * jnp.tanh(s_new / attn_softcap)
        m = jnp.maximum(s.max(-1), s_new)
        e = jnp.exp(s - m[..., None])
        e_new = jnp.exp(s_new - m)
        l = e.sum(-1) + e_new
        out = jnp.einsum("bKGt,bKtd->bKGd", e.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        out = out + e_new[..., None] * v_new[:, :, None, :].astype(jnp.float32)
        out = out / l[..., None]
    else:                                   # cross-attention: cache only
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bKGt,bKtd->bKGd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


def chunk_attention(q, k_cache, v_cache, offset, *, attn_softcap: float = 0.0):
    """Chunked-prefill attention: a chunk of C new queries against a slot's
    cache which *already holds* the chunk's own K/V at [offset, offset+C)
    (written before the call, so causal masking ``t <= qpos`` covers both
    the past context and the within-chunk triangle in one pass).

    q: (B,C,H,hd); k_cache: (B,KV,hd,T); v_cache: (B,KV,T,hd) — the same
    pre-transposed decode layouts, so chunked prefill reads the pool cache
    without materializing transposed copies.  offset: int32 start position
    of the chunk — a scalar shared across the batch (chunked prefill) or a
    (B,) vector of per-row offsets (the speculative-decoding verify
    forward, where every slot verifies its own window).  Slots beyond
    offset+C hold stale data and are masked out; this masking is also
    what makes a prefix-cache admission's copied tail (segment data
    past the matched length) unobservable — every position is rewritten
    by the suffix prefill or decode before any query can reach it, and
    masked until then.
    """
    B, C, H, hd = q.shape
    KV, T = k_cache.shape[1], k_cache.shape[3]
    G = H // KV
    scale = hd ** -0.5
    qr = q.reshape(B, C, KV, G, hd)
    s = jnp.einsum("bqKGd,bKdt->bKGqt", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    offset = jnp.asarray(offset)
    if offset.ndim:                              # per-row offsets: (B,C)
        qpos = offset[:, None] + jnp.arange(C)
    else:
        qpos = (offset + jnp.arange(C))[None]    # shared offset: (1,C)
    valid = jnp.arange(T) <= qpos[..., None]     # (B|1,C,T)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKGqt,bKtd->bKGqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, hd).astype(q.dtype)


def cache_write_kv(k_cache, v_cache, k_new, v_new, positions, *,
                   rolling: bool = False, aligned: bool = False):
    """Write one token into a layer's caches.

    k_cache: (B,KV,hd,T); v_cache: (B,KV,T,hd); k/v_new: (B,KV,hd).
    aligned=True (all sequences decode the same position) collapses to a
    single update-only dynamic_update_slice per cache; otherwise a vmapped
    per-sequence write."""
    T = k_cache.shape[-1]
    pos = positions % T if rolling else positions
    kn = k_new.astype(k_cache.dtype)
    vn = v_new.astype(v_cache.dtype)
    if aligned:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, kn[..., None], (0, 0, 0, pos[0]))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, vn[:, :, None, :], (0, 0, pos[0], 0))
        return k_cache, v_cache

    def upd_k(c, n, p):                          # c: (KV,hd,T)
        return jax.lax.dynamic_update_slice(c, n[..., None], (0, 0, p))

    def upd_v(c, n, p):                          # c: (KV,T,hd)
        return jax.lax.dynamic_update_slice(c, n[:, None, :], (0, p, 0))

    return jax.vmap(upd_k)(k_cache, kn, pos), jax.vmap(upd_v)(v_cache, vn, pos)
