"""Model assembly: schema + apply for every assigned architecture family.

One generic decoder stack covers dense / MoE / SSM / hybrid archs via the
config's ``layer_pattern`` (a period of (mixer, ffn) kinds); homogeneous
periods are stacked and scanned (``lax.scan``) so HLO size and compile time
stay bounded at 95 layers.  Whisper adds an encoder stack + cross-attention;
InternVL prepends precomputed patch embeddings (frontend stub).

Modes: "train" (full seq, no cache), "prefill" (full seq, emits caches),
"decode" (one token per sequence against caches).

Execution state is explicit: ``forward`` takes a static
``SparsityPolicy`` (``repro.sparsity``) selecting the projection backend
per role / per block range (``None`` = dense), a traced ``token_weights``
row-weight vector for the serving engine's shared saliency, and a static
``aligned`` flag for the single-DUS batched decode cache write.  Nothing
on the forward path reads ambient thread-local state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import sparse_linear
from repro.distributed.sharding import constrain
from repro.models import attention as attn_lib
from repro.models.layers import apply_rope, dense, rmsnorm, rope_angles, softcap
from repro.models.mlp import mlp_apply, mlp_schema
from repro.models.moe import moe_apply, moe_schema
from repro.models.params import ParamSpec, stacked
from repro.models.ssm import mamba_apply, mamba_schema

ATTN_KINDS = ("attn", "local", "global", "attn_bidir")


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "heads_flat")),
        "wk": ParamSpec((d, KV * hd), ("embed", "kv_flat")),
        "wv": ParamSpec((d, KV * hd), ("embed", "kv_flat")),
        "wo": ParamSpec((H * hd, d), ("heads_flat", "embed")),
    }


def layer_schema(cfg: ModelConfig, kind, cross: bool = False):
    mixer, ffn = kind
    s = {}
    if mixer in ATTN_KINDS:
        s["ln1"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
        s["attn"] = attn_schema(cfg)
    elif mixer == "mamba":
        s["ln1"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
        s["mamba"] = mamba_schema(cfg)
    if cross:
        s["ln_cross"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
        s["cross"] = attn_schema(cfg)
    if ffn == "dense":
        s["ln2"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
        s["mlp"] = mlp_schema(cfg)
    elif ffn == "moe":
        s["ln2"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
        s["moe"] = moe_schema(cfg)
    return s


def group_schemas(cfg: ModelConfig, cross: bool = False):
    out = []
    for pattern, reps in cfg.layer_groups():
        g = {f"l{j}": layer_schema(cfg, kind, cross)
             for j, kind in enumerate(pattern)}
        out.append(stacked(g, reps, "layers"))
    return out


def model_schema(cfg: ModelConfig):
    V, D = cfg.vocab_size, cfg.d_model
    s = {
        "embed": ParamSpec((V, D), ("vocab", "embed")),
        "final_norm": ParamSpec((D,), (None,), init="zeros"),
        "groups": group_schemas(cfg, cross=(cfg.family == "encdec")),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    if cfg.family == "encdec":
        enc_pattern = (("attn_bidir", "dense"),)
        g = {f"l{j}": layer_schema(cfg, kind)
             for j, kind in enumerate(enc_pattern)}
        s["encoder"] = {
            "groups": [stacked(g, cfg.encoder_layers, "layers")],
            "final_norm": ParamSpec((D,), (None,), init="zeros"),
        }
    return s


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def attn_apply(p, x, cfg: ModelConfig, kind: str, sp=None, cache=None,
               positions=None, mode: str = "train", kv_override=None,
               slot=None, policy=None, token_weights=None,
               aligned: bool = False, role_base: str = "attn"):
    """Self- or cross-attention.  kv_override: (enc_out) for cross-attn.

    mode "chunk" is the serving engine's chunked-prefill path: x is one
    request's C-token chunk, cache holds the *whole slot pool*
    (max_slots batch dim), ``slot`` is the request's pool slot and
    ``positions`` (B,) its chunk-start offset.  The chunk's K/V are written
    in place at (slot, offset) via dynamic_update_slice and attention runs
    against the slot's full cache row, so every chunk reuses one compiled
    step regardless of prompt length or pool occupancy.  The slot's
    positions before the offset may equally be a prefix-cache copy
    (``repro.serving.prefix_cache``) rather than this request's own
    earlier chunks — the causal mask treats both identically.

    mode "verify" is the speculative-decoding verify forward: x's batch
    dim *is* the pool's slot dim, row s carrying slot s's (gamma+1)-token
    verify window starting at per-slot offset ``positions[s]``.  The same
    write-in-place machinery as "chunk", vmapped over slots, re-projects
    every window position's K/V under the verifier policy before
    attention, so whatever the drafter wrote there is overwritten and the
    committed cache prefix stays verifier-faithful."""
    sp = sp or {}
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    win = cfg.sliding_window if kind == "local" else 0
    tw = token_weights

    def proj(name, xin, row_parallel=False):
        return dense(xin, p[name], sp.get(name), row_parallel=row_parallel,
                     policy=policy, role=f"{role_base}/{name}",
                     token_weights=tw)

    # fused qkv only pays in training (merges backward dx psums); in serve
    # modes the concat of differently-sharded weight dims costs an
    # all-to-all reshard.  WiSparse needs per-projection masks (and
    # calibration needs per-projection input capture), so the sparse and
    # capture paths keep separate matmuls.
    fuse = (mode == "train" and not sp and kv_override is None
            and (policy is None or policy.capture is None))
    if not fuse:
        q = proj("wq", x).reshape(B, S, H, hd)
    if kv_override is not None:                      # cross-attention
        if mode == "decode":                         # static pre-transposed KV
            kc, vc = cache["k"], cache["v"]
            F = kc.shape[-1]
            out = attn_lib.decode_attention(
                q[:, 0], kc, vc, jnp.full((B,), F, jnp.int32))
            out = out[:, None]
        else:
            F = kv_override.shape[1]
            # encoder rows are not the step's tokens: opt out of weighting
            k = dense(kv_override, p["wk"], sp.get("wk"), policy=policy,
                      role=f"{role_base}/wk",
                      token_weights=None).reshape(B, F, KV, hd)
            v = dense(kv_override, p["wv"], sp.get("wv"), policy=policy,
                      role=f"{role_base}/wv",
                      token_weights=None).reshape(B, F, KV, hd)
            q = constrain(q, "batch", None, "heads", None)
            out = attn_lib.flash_attention(q, k, v, causal=False)
        y = proj("wo", out.reshape(B, S, H * hd), row_parallel=True)
        return y, None

    if fuse:
        # fused qkv: one matmul -> backward emits ONE dx all-reduce instead
        # of three.
        w_cat = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1)
        qkv = dense(x, w_cat, policy=policy, token_weights=None)
        q = qkv[..., : H * hd].reshape(B, S, H, hd)
        k = qkv[..., H * hd: (H + KV) * hd].reshape(B, S, KV, hd)
        v = qkv[..., (H + KV) * hd:].reshape(B, S, KV, hd)
    else:
        k = proj("wk", x).reshape(B, S, KV, hd)
        v = proj("wv", x).reshape(B, S, KV, hd)

    if cfg.rope_theta:
        if mode == "decode":
            cos, sin = rope_angles(positions[:, None], hd, cfg.rope_theta)
        elif mode in ("chunk", "verify"):
            cos, sin = rope_angles(positions[:, None] + jnp.arange(S)[None],
                                   hd, cfg.rope_theta)
        else:
            cos, sin = rope_angles(jnp.arange(S)[None], hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "heads", None)

    if mode == "verify":
        if win:
            raise NotImplementedError(
                "speculative verify does not support local-attention "
                "layers (rolling-window caches cannot roll back)")
        kc, vc = cache["k"], cache["v"]          # pool: (S,KV,hd,T)/(S,KV,T,hd)
        kn = k.transpose(0, 2, 3, 1).astype(kc.dtype)        # (S,KV,hd,C)
        vn = v.transpose(0, 2, 1, 3).astype(vc.dtype)        # (S,KV,C,hd)

        def wk(c, n, off):                       # c: (KV,hd,T)
            return jax.lax.dynamic_update_slice(c, n, (0, 0, off))

        def wv(c, n, off):                       # c: (KV,T,hd)
            return jax.lax.dynamic_update_slice(c, n, (0, off, 0))

        kc = jax.vmap(wk)(kc, kn, positions)
        vc = jax.vmap(wv)(vc, vn, positions)
        out = attn_lib.chunk_attention(q, kc, vc, positions,
                                       attn_softcap=cfg.attn_softcap)
        y = proj("wo", out.reshape(B, S, H * hd), row_parallel=True)
        return y, {"k": kc, "v": vc}

    if mode == "chunk":
        if win:
            raise NotImplementedError(
                "chunked prefill does not support local-attention layers; "
                "use the engine's whole-prompt prefill strategy")
        kc, vc = cache["k"], cache["v"]          # pool: (S,KV,hd,T)/(S,KV,T,hd)
        off = positions[0]
        kn = k.transpose(0, 2, 3, 1).astype(kc.dtype)        # (B,KV,hd,C)
        vn = v.transpose(0, 2, 1, 3).astype(vc.dtype)        # (B,KV,C,hd)
        kc = jax.lax.dynamic_update_slice(kc, kn, (slot, 0, 0, off))
        vc = jax.lax.dynamic_update_slice(vc, vn, (slot, 0, off, 0))
        ks = jax.lax.dynamic_slice(kc, (slot, 0, 0, 0), (B,) + kc.shape[1:])
        vs = jax.lax.dynamic_slice(vc, (slot, 0, 0, 0), (B,) + vc.shape[1:])
        out = attn_lib.chunk_attention(q, ks, vs, off,
                                       attn_softcap=cfg.attn_softcap)
        y = proj("wo", out.reshape(B, S, H * hd), row_parallel=True)
        return y, {"k": kc, "v": vc}

    if mode == "decode":
        kc, vc = cache["k"], cache["v"]
        T = kc.shape[-1]
        rolling = bool(win) and win == T
        k_new, v_new = k[:, 0], v[:, 0]               # (B,KV,hd)
        out = attn_lib.decode_attention(
            q[:, 0], kc, vc, positions, k_new, v_new,
            rolling=rolling, attn_softcap=cfg.attn_softcap)
        out = out[:, None]
        nk, nv = attn_lib.cache_write_kv(
            kc, vc, k_new, v_new, positions,
            rolling=rolling, aligned=aligned)
        new_cache = {"k": nk, "v": nv}
    else:
        causal = kind != "attn_bidir"
        out = attn_lib.flash_attention(
            q, k, v, causal=causal, window=win, attn_softcap=cfg.attn_softcap)
        new_cache = None
        if mode == "prefill":
            if win and win < S:                      # rolling window cache
                ck, cv = k[:, -win:], v[:, -win:]
                # slot j of k[:, -win:] holds abs position S-win+j; roll right
                # by S%win so slot (pos % win) holds position pos
                shift = S % win
                ck = jnp.roll(ck, shift, axis=1)
                cv = jnp.roll(cv, shift, axis=1)
            else:
                ck, cv = k, v
            # decode-layout caches: K as (B,KV,hd,T), V as (B,KV,T,hd)
            new_cache = {
                "k": constrain(ck.transpose(0, 2, 3, 1),
                               "batch", "kv_heads", None, "kv_seq"),
                "v": constrain(cv.transpose(0, 2, 1, 3),
                               "batch", "kv_heads", "kv_seq", None)}
    y = proj("wo", out.reshape(B, S, H * hd), row_parallel=True)
    return y, new_cache


def layer_apply(p, x, cfg: ModelConfig, kind, sp=None, cache=None,
                positions=None, mode: str = "train", enc_out=None,
                slot=None, policy=None, token_weights=None,
                aligned: bool = False):
    """cache: per-layer dict (train/prefill) or, in decode mode,
    {"stack": <layer-stacked group cache entry>, "idx": layer-in-stack} —
    decode caches ride through xs/ys with update-only in-place writes.

    ``policy`` is the depth-resolved SparsityPolicy for this block (per-
    block ranges already folded by ``run_groups``); None runs dense."""
    if policy is None:
        policy = sparse_linear.DENSE
    mixer, ffn = kind
    sp = sp or {}
    cache = cache or {}
    decode = mode in ("decode", "chunk", "verify")
    new_cache = dict(cache) if decode else {}
    if mixer in ATTN_KINDS:
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        h, nc = attn_apply(p["attn"], h, cfg, mixer, sp.get("attn"),
                           cache.get("self"), positions, mode, slot=slot,
                           policy=policy, token_weights=token_weights,
                           aligned=aligned)
        if nc is not None:
            new_cache["self"] = nc
        x = x + h
    elif mixer == "mamba":
        if mode in ("chunk", "verify"):
            raise NotImplementedError(
                "chunked prefill / speculative verify do not support SSM "
                "layers; use the engine's whole-prompt prefill strategy")
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        h, nc = mamba_apply(p["mamba"], h, cfg, sp.get("mamba"),
                            cache.get("ssm"), mode, policy=policy,
                            token_weights=token_weights)
        if nc is not None:
            new_cache["ssm"] = nc
        x = x + h
    if "cross" in p:
        h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        h, nc = attn_apply(p["cross"], h, cfg, "attn_bidir", sp.get("cross"),
                           cache.get("cross") if decode else None,
                           positions, mode,
                           kv_override=enc_out if enc_out is not None else x,
                           policy=policy, token_weights=token_weights,
                           aligned=aligned, role_base="cross")
        if mode == "prefill" and enc_out is not None:
            # stash static cross KV for decode (decode layouts)
            F = enc_out.shape[1]
            B = x.shape[0]
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            ck = dense(enc_out, p["cross"]["wk"], policy=policy,
                       token_weights=None).reshape(B, F, KV, hd)
            cv = dense(enc_out, p["cross"]["wv"], policy=policy,
                       token_weights=None).reshape(B, F, KV, hd)
            new_cache["cross"] = {"k": ck.transpose(0, 2, 3, 1),
                                  "v": cv.transpose(0, 2, 1, 3)}
        x = x + h
    if ffn == "dense":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg, sp.get("mlp"), mode,
                          policy=policy, token_weights=token_weights)
    elif ffn == "moe":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_apply(p["moe"], h, cfg, sp.get("moe"), policy=policy)
    x = constrain(x, "batch", None, "embed_act")
    return x, (new_cache or None)


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)   # "full": save nothing


def _rep_backends(policy, depth0: int, plen: int, reps: int):
    """Per-rep tuple of depth-resolved backends for one stacked group, or
    None when the policy has no per-block map (the uniform fast path)."""
    if policy is None or not policy.block_backends:
        return None
    return [tuple(policy.backend_at(depth=depth0 + r * plen + j)
                  for j in range(plen)) for r in range(reps)]


def run_groups(groups, x, cfg: ModelConfig, patterns, *, mode="train",
               caches=None, positions=None, sp=None, enc_out=None,
               remat: str = "none", slot=None, policy=None,
               token_weights=None, aligned: bool = False, depth0: int = 0):
    """Scan each stacked layer group.  Returns (x, new_caches).

    Mixed per-block policies (``policy.block_backends``) split a group's
    rep scan into contiguous segments of equal backend signature — each
    segment is its own ``lax.scan`` over a slice of the stacked params /
    caches / sp, so the backend stays a static property of the trace while
    compile time grows only with the number of backend *switches*, not
    with depth.  Uniform policies take the single-scan fast path (HLO
    identical to the pre-policy code).
    """
    new_caches = []
    depth = depth0
    for gi, (gp, (pattern, reps)) in enumerate(zip(groups, patterns)):
        gc = caches[gi] if caches is not None else None
        gsp = sp[gi] if sp is not None else None
        plen = len(pattern)

        # NOTE (perf, measured in the decode dry-runs): carrying decode
        # caches through the scan carry, or unrolling the layer loop over
        # a stacked donated buffer, both force XLA to defensively copy the
        # full stack per layer (10-600x memory-term regressions) — decode
        # caches therefore flow through xs/ys like prefill, with
        # update-only writes inside each per-layer slice.

        rb = _rep_backends(policy, depth, plen, reps)
        if rb is None:
            segs = [(0, reps, (policy,) * plen)]
        else:
            segs, s = [], 0
            for r in range(1, reps + 1):
                if r == reps or rb[r] != rb[s]:
                    jpols = tuple(policy.resolve_depth(depth + s * plen + j)
                                  for j in range(plen))
                    segs.append((s, r, jpols))
                    s = r

        seg_ys = []
        for (r0, r1, jpols) in segs:
            if (r0, r1) == (0, reps):
                xs = (gp, gc, gsp)
            else:
                xs = tuple(jax.tree_util.tree_map(
                    lambda a, lo=r0, hi=r1: a[lo:hi], t)
                    for t in (gp, gc, gsp))

            def body(xc, xs_in, pattern=pattern, jpols=jpols):
                p_i, c_i, sp_i = xs_in
                ncs = []
                for j, kind in enumerate(pattern):
                    cj = c_i[j] if c_i is not None else None
                    spj = sp_i[f"l{j}"] if sp_i is not None else None
                    xc, nc = layer_apply(p_i[f"l{j}"], xc, cfg, kind, spj,
                                         cj, positions, mode, enc_out,
                                         slot=slot, policy=jpols[j],
                                         token_weights=token_weights,
                                         aligned=aligned)
                    ncs.append(nc)
                ys = tuple(ncs) if any(n is not None for n in ncs) else None
                return xc, ys

            wrapped = _remat_wrap(body, remat if mode == "train" else "none")
            x, ys = jax.lax.scan(wrapped, x, xs)
            seg_ys.append(ys)

        if len(seg_ys) == 1:
            new_caches.append(seg_ys[0])
        elif all(y is None for y in seg_ys):
            new_caches.append(None)
        else:
            new_caches.append(jax.tree_util.tree_map(
                lambda *ys: jnp.concatenate(ys, axis=0), *seg_ys))
        depth += plen * reps
    return x, new_caches


def embed_tokens(params, tokens, cfg: ModelConfig):
    e = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)
    return e


def lm_logits(params, x, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "batch", None, "vocab")


def encode(params, frames, cfg: ModelConfig, sp=None, remat="none",
           policy=None):
    """Whisper encoder over precomputed conv-frontend frame embeddings.
    Per-block backend ranges index *decoder* depth, so the encoder runs
    the policy's default backend."""
    from repro.models.layers import sinusoidal_positions
    if policy is not None:
        policy = policy.resolve_depth(None)
    enc = params["encoder"]
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model
                                      ).astype(frames.dtype)[None]
    patterns = [((("attn_bidir", "dense"),), cfg.encoder_layers)]
    x, _ = run_groups(enc["groups"], x, cfg, patterns, mode="train",
                      sp=sp, remat=remat, policy=policy, token_weights=None)
    return rmsnorm(x, enc["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, *, tokens=None, frames=None,
            patch_embeds=None, mode="train", caches=None, positions=None,
            sp=None, sp_enc=None, remat="none", slot=None, policy=None,
            token_weights=None, aligned: bool = False):
    """Unified forward.

    train/prefill: tokens (B,S[-P]) [+ frames (B,F,D) | patch_embeds (B,P,D)]
    decode:        tokens (B,), positions (B,), caches required.
    chunk:         tokens (B,C) one request's prefill chunk, positions (B,)
                   chunk-start offset, slot () pool slot, caches = the full
                   slot pool (serving engine's chunked prefill).
    verify:        tokens (S,C) one C-token verify window per pool slot,
                   positions (S,) per-slot window start, caches = the full
                   slot pool (speculative decoding; batch dim == slot dim).

    policy: static SparsityPolicy (None runs dense).  token_weights:
    per-row weights for the shared top-k saliency (serving active-slot /
    real-token masks).
    aligned: static flag — all decode rows share one position, so cache
    writes collapse to a single dynamic_update_slice.

    Returns (logits, new_caches):
      train  -> logits (B,S,V), caches None
      prefill-> logits (B,V) last position, caches filled
      decode -> logits (B,V), caches updated
      chunk  -> logits (B,C,V) all chunk positions, pool caches updated
      verify -> logits (S,C,V) all window positions, pool caches updated
    """
    if policy is None:
        policy = sparse_linear.DENSE
    enc_out = None
    if cfg.family == "encdec" and frames is not None:
        enc_out = encode(params, frames, cfg, sp=sp_enc, remat=remat,
                         policy=policy)

    if mode in ("chunk", "verify"):
        x = embed_tokens(params, tokens, cfg)
        x, new_caches = run_groups(
            params["groups"], x, cfg, cfg.layer_groups(), mode=mode,
            caches=caches, positions=positions, sp=sp, slot=slot,
            policy=policy, token_weights=token_weights)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return lm_logits(params, x, cfg), new_caches

    if mode == "decode":
        x = embed_tokens(params, tokens[:, None], cfg)
        if cfg.family == "encdec" and cfg.rope_theta == 0.0:
            from repro.models.layers import sinusoidal_at
            x = x + sinusoidal_at(positions, cfg.d_model)[:, None].astype(x.dtype)
        x, new_caches = run_groups(
            params["groups"], x, cfg, cfg.layer_groups(), mode="decode",
            caches=caches, positions=positions, sp=sp, enc_out=enc_out,
            policy=policy, token_weights=token_weights, aligned=aligned)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return lm_logits(params, x, cfg)[:, 0], new_caches

    x = embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:                      # VLM stub frontend
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    if cfg.family == "encdec":
        from repro.models.layers import sinusoidal_positions
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model
                                     ).astype(x.dtype)[None]
    x = constrain(x, "batch", None, "embed_act")
    x, new_caches = run_groups(
        params["groups"], x, cfg, cfg.layer_groups(), mode=mode,
        caches=None, positions=None, sp=sp, enc_out=enc_out, remat=remat,
        policy=policy, token_weights=token_weights)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if mode == "prefill":
        return lm_logits(params, x[:, -1:], cfg)[:, 0], new_caches
    return lm_logits(params, x, cfg), None
