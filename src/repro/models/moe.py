"""Mixture-of-Experts FFN with capacity-bounded gather dispatch.

Shardable formulation (see repro.distributed.sharding rules tables):
tokens stay batch-sharded over
``data`` while the expert dim shards over ``model``; because activations
are replicated across ``model``, dispatch gathers are local and the combine
scatter reduces over ``model`` exactly like a row-parallel matmul — no
token all-to-all is required.  When num_experts doesn't divide the model
axis the per-expert hidden dim shards instead (rules-table fallback).

Dispatch avoids the GShard (S,E,C) one-hot blowup: a sort by expert id
yields each assignment's position-in-expert; assignments beyond capacity
are dropped (standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, gelu, silu
from repro.models.params import ParamSpec
from repro.distributed.sharding import constrain


def moe_schema(cfg):
    # E padded to the TP width: pad experts carry -inf router logits and
    # are never routed to, so the expert dim always shards over `model`
    # (see ModelConfig.num_experts_padded)
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts_padded
    return {
        "router": ParamSpec((d, e), ("embed", None), scale=0.02),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.num_experts_per_tok / cfg.num_experts
            * cfg.capacity_factor) + 1
    return max(c, 1)


def moe_apply(p, x, cfg, sp=None, policy=None):
    """x: (B, S, D) -> (B, S, D).  Groups = batch dim.

    ``policy``: the block's SparsityPolicy.  Expert projections always opt
    out of the serving engine's per-token saliency weights (dispatch
    permutes and capacity-bounds the rows), so no token_weights parameter
    exists here — the opt-out is explicit at each dense() call."""
    sp = sp or {}
    B, S, D = x.shape
    E, K = cfg.num_experts_padded, cfg.num_experts_per_tok
    C = _capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"],
                        preferred_element_type=jnp.float32)
    if E != cfg.num_experts:                # mask pad experts (never routed)
        pad = jnp.full((E - cfg.num_experts,), -jnp.inf, logits.dtype)
        logits = logits.at[..., cfg.num_experts:].set(pad)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, topk = jax.lax.top_k(probs, K)                  # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    def dispatch_group(xg, topk_g, gate_g):
        # xg: (S,D); topk/gate: (S,K)
        A = S * K
        exp_id = topk_g.reshape(A)
        tok_id = jnp.repeat(jnp.arange(S), K)
        gates = gate_g.reshape(A)
        order = jnp.argsort(exp_id, stable=True)
        exp_s = exp_id[order]
        tok_s = tok_id[order]
        gate_s = gates[order]
        counts = jnp.zeros((E,), jnp.int32).at[exp_s].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(A) - starts[exp_s]               # position in expert
        keep = pos < C
        # scatter token ids into the (E, C) dispatch table (S = pad row)
        disp = jnp.full((E, C), S, jnp.int32)
        disp = disp.at[exp_s, jnp.where(keep, pos, 0)].set(
            jnp.where(keep, tok_s, S), mode="drop")
        xg_pad = jnp.concatenate([xg, jnp.zeros((1, D), xg.dtype)], 0)
        xe = xg_pad[disp]                                  # (E,C,D)
        return xe, (exp_s, pos, tok_s, gate_s, keep)

    xe, meta = jax.vmap(dispatch_group)(x, topk, gate)     # xe: (B,E,C,D)
    xe = constrain(xe, "batch", "experts", None, None)

    def ff(name):
        w = p[name]                                        # (E,D,F) or (E,F,D)
        s = sp.get(name)
        if s is None:
            def apply_dense(h):
                if policy is not None and policy.capture is not None:
                    policy.capture.record(w, h)            # calibration hook
                return jnp.einsum("becd,edf->becf", h, w)
            return apply_dense
        # per-expert WiSparse: vmap the sparse projection over experts.
        # The serving engine's per-token saliency weights cannot ride
        # through expert dispatch (rows here are capacity-bounded
        # permutations of tokens, and can even coincidentally match the
        # slot count) — opt out with an explicit token_weights=None;
        # dropped/pad rows are zeroed by dispatch and contribute nothing
        # to the saliency sum.
        def apply(h):                                      # h: (B,E,C,din)
            hm = jnp.moveaxis(h, 1, 0)                     # (E,B,C,din)
            out = jax.vmap(lambda he, we, ge: dense(
                he, we, {**s, "g": ge}, policy=policy, role=f"moe/{name}",
                token_weights=None))(hm, w, s["g"])
            return jnp.moveaxis(out, 0, 1)
        return apply

    act = silu if cfg.mlp_activation == "swiglu" else gelu
    h = act(ff("wi_gate")(xe)) * ff("wi_up")(xe)           # (B,E,C,F)
    h = constrain(h, "batch", "experts", None, "expert_mlp")
    ye = ff("wo")(h)                                       # (B,E,C,D)
    ye = constrain(ye, "batch", "experts", None, None)

    def combine_group(ye_g, meta_g):
        exp_s, pos, tok_s, gate_s, keep = meta_g
        vals = ye_g[exp_s, jnp.clip(pos, 0, C - 1)]        # (A,D)
        vals = vals * (gate_s * keep).astype(vals.dtype)[:, None]
        out = jnp.zeros((S + 1, D), vals.dtype).at[tok_s].add(vals)
        return out[:S]

    out = jax.vmap(combine_group)(ye, meta)
    out = constrain(out, "batch", None, "embed_act")
    return out.astype(x.dtype)


def moe_aux_loss(logits_probs):
    """Load-balancing auxiliary loss (Switch-style)."""
    probs, topk = logits_probs
    E = probs.shape[-1]
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    ce = jnp.zeros((E,)).at[topk.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    return E * jnp.sum(me * ce)
