"""Gated MLP (SwiGLU / GeGLU) and plain GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, gelu, silu
from repro.models.params import ParamSpec
from repro.distributed.sharding import constrain


def mlp_schema(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp_apply(p, x, cfg, sp=None, mode: str = "train", policy=None,
              token_weights=None):
    sp = sp or {}

    def proj(name, xin, row_parallel=False):
        return dense(xin, p[name], sp.get(name), row_parallel=row_parallel,
                     policy=policy, role=f"mlp/{name}",
                     token_weights=token_weights)

    if cfg.mlp_activation in ("swiglu", "geglu"):
        act = silu if cfg.mlp_activation == "swiglu" else gelu
        if mode == "train" and not sp \
                and (policy is None or policy.capture is None):
            # fused gate/up: one dx all-reduce in backward instead of two;
            # the concat reshards in serve modes, and WiSparse/calibration
            # (per-projection masks / input capture) need separate matmuls.
            f = p["wi_gate"].shape[1]
            gu = dense(x, jnp.concatenate([p["wi_gate"], p["wi_up"]], axis=1),
                       policy=policy, token_weights=None)
            g, u = gu[..., :f], gu[..., f:]
        else:
            g = proj("wi_gate", x)
            u = proj("wi_up", x)
        h = act(g) * u
    else:
        h = gelu(proj("wi", x))
    h = constrain(h, "batch", None, "mlp")
    return proj("wo", h, row_parallel=True)
