"""Gated MLP (SwiGLU / GeGLU) and plain GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, gelu, silu
from repro.models.params import ParamSpec
from repro.distributed.sharding import constrain


def mlp_schema(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp_apply(p, x, cfg, sp=None, mode: str = "train"):
    sp = sp or {}
    if cfg.mlp_activation in ("swiglu", "geglu"):
        act = silu if cfg.mlp_activation == "swiglu" else gelu
        from repro.core.sparse_linear import capture_active
        if mode == "train" and not sp and not capture_active():
            # fused gate/up: one dx all-reduce in backward instead of two
            # (EXPERIMENTS.md SSPerf iteration B3); the concat reshards in
            # serve modes, and WiSparse/calibration need separate matmuls.
            f = p["wi_gate"].shape[1]
            gu = dense(x, jnp.concatenate([p["wi_gate"], p["wi_up"]], axis=1))
            g, u = gu[..., :f], gu[..., f:]
        else:
            g = dense(x, p["wi_gate"], sp.get("wi_gate"))
            u = dense(x, p["wi_up"], sp.get("wi_up"))
        h = act(g) * u
    else:
        h = gelu(dense(x, p["wi"], sp.get("wi")))
    h = constrain(h, "batch", None, "mlp")
    return dense(h, p["wo"], sp.get("wo"), row_parallel=True)
