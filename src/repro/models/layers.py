"""Shared primitive layers: the sparsity-aware dense projection, norms,
rotary embeddings, activations.

Every linear projection in the model zoo routes through ``dense()`` — the
single integration point for WiSparse (repro.core.sparse_linear decides
whether/how to sparsify based on the per-layer sparsity params ``sp`` and
the explicit SparsityPolicy).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_linear


def dense(x, w, sp=None, row_parallel: bool = False, *, policy=None,
          role=None, token_weights=None):
    """y = x @ W, optionally channel-sparsified per WiSparse.

    x: (..., n_in); w: (n_in, *out_dims); sp: per-layer sparsity params
    ({"g","alpha","tau","keep_frac"}) or None.  row_parallel statically
    marks o_proj/down_proj-style weights whose input dim is model-sharded.
    policy: the static SparsityPolicy (depth-resolved by the scan driver);
    role: this projection's sp-leaf path (e.g. "attn/wq") for per-role
    backend overrides; token_weights: per-row saliency weights (explicit
    None opts out — e.g. expert-dispatched layouts).
    """
    return sparse_linear.project(x, w, sp, row_parallel=row_parallel,
                                 policy=policy, role=role,
                                 token_weights=token_weights)


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


ACT = {"gelu": gelu, "silu": silu}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos,sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., P, n_heads, head_dim); cos/sin: (..., P, head_dim//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(dt)


def sinusoidal_at(positions, dim: int):
    """Sinusoidal absolute position embedding at given positions (..., dim)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_positions(length: int, dim: int):
    """Whisper-style sinusoidal absolute position embedding (length, dim)."""
    return sinusoidal_at(jnp.arange(length), dim)
