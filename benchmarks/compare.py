"""Diff two benchmark artifact directories (``BENCH_<name>.json``).

    PYTHONPATH=src python -m benchmarks.compare baseline-dir candidate-dir \
        [--threshold 25] [--structural]

For every artifact in the baseline directory the candidate must have the
matching ``BENCH_<name>.json`` with status ``ok`` and every baseline row
present.  Timed rows are compared as per-row percentage deltas on
``us_per_call``; a slowdown beyond ``--threshold`` percent is a
regression and the exit code is nonzero.

``--structural`` skips the timing comparison (rows/status/coverage
only) — the mode CI uses against a committed baseline, where shared
runners make wall-time deltas meaningless noise.  Rows whose
``us_per_call`` is 0 in either run (gate-only rows) are always compared
structurally.

Exit codes: 0 clean, 1 regression/coverage breach, 2 usage error.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple


def load_dir(directory: str) -> Dict[str, dict]:
    """{benchmark short-name: artifact dict} for a directory."""
    if not os.path.isdir(directory):
        raise SystemExit(2)
    out = {}
    for path in sorted(glob.glob(os.path.join(directory, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        out[doc.get("benchmark",
                    os.path.basename(path)[len("BENCH_"):-len(".json")])] = doc
    return out


def compare_rows(base_rows: List[dict], cand_rows: List[dict],
                 threshold_pct: float, structural: bool,
                 ) -> Tuple[List[str], List[str]]:
    """(report lines, failure lines) for one artifact's rows."""
    cand = {r["name"]: r for r in cand_rows}
    lines, failures = [], []
    for row in base_rows:
        name = row["name"]
        if name not in cand:
            failures.append(f"row {name!r} missing from candidate")
            continue
        b_us = float(row.get("us_per_call") or 0.0)
        c_us = float(cand[name].get("us_per_call") or 0.0)
        if structural or b_us <= 0.0 or c_us <= 0.0:
            lines.append(f"  {name}: present")
            continue
        delta = (c_us - b_us) / b_us * 100.0
        flag = ""
        if delta > threshold_pct:
            flag = f"  << REGRESSION (> {threshold_pct:g}%)"
            failures.append(
                f"row {name!r} regressed {delta:+.1f}% "
                f"({b_us:.1f}us -> {c_us:.1f}us)")
        lines.append(f"  {name}: {b_us:.1f}us -> {c_us:.1f}us "
                     f"({delta:+.1f}%){flag}")
    extra = [r["name"] for r in cand_rows
             if r["name"] not in {b["name"] for b in base_rows}]
    for name in extra:
        lines.append(f"  {name}: new row (not in baseline)")
    return lines, failures


def compare_dirs(baseline_dir: str, candidate_dir: str,
                 threshold_pct: float = 25.0, structural: bool = False,
                 log=print) -> List[str]:
    """Compare every baseline artifact; returns the failure list."""
    base = load_dir(baseline_dir)
    cand = load_dir(candidate_dir)
    if not base:
        return [f"no BENCH_*.json artifacts in baseline {baseline_dir!r}"]
    failures: List[str] = []
    for name, b_doc in base.items():
        log(f"== {name} ==")
        c_doc = cand.get(name)
        if c_doc is None:
            failures.append(f"artifact BENCH_{name}.json missing from "
                            f"candidate")
            log("  MISSING from candidate")
            continue
        if c_doc.get("status") != "ok":
            failures.append(
                f"{name}: candidate status {c_doc.get('status')!r}"
                + (f" ({c_doc['error']})" if c_doc.get("error") else ""))
        lines, row_failures = compare_rows(
            b_doc.get("rows", []), c_doc.get("rows", []),
            threshold_pct, structural)
        for ln in lines:
            log(ln)
        failures.extend(f"{name}: {f}" for f in row_failures)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.compare",
        description="Diff two BENCH_<name>.json artifact directories and "
                    "gate per-row regressions.")
    ap.add_argument("baseline", help="baseline artifact directory")
    ap.add_argument("candidate", help="candidate artifact directory")
    ap.add_argument("--threshold", type=float, default=25.0,
                    help="per-row us_per_call slowdown (percent) treated "
                         "as a regression (default 25)")
    ap.add_argument("--structural", action="store_true",
                    help="compare artifact/row coverage and status only, "
                         "ignoring timings (CI mode: shared runners make "
                         "wall-time deltas noise)")
    args = ap.parse_args(argv)
    if args.threshold <= 0:
        ap.error(f"--threshold must be > 0, got {args.threshold}")

    failures = compare_dirs(args.baseline, args.candidate,
                            threshold_pct=args.threshold,
                            structural=args.structural)
    if failures:
        print(f"\n{len(failures)} failure(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
