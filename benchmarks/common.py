"""Shared benchmark fixtures: one small trained model + calibration context,
built once per process."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

_STATE = {}


def timed(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = obs.now()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (obs.now() - t0) / reps * 1e6, out   # us


def trained_model(steps: int = 60, seq: int = 64, batch: int = 8):
    """Train the reduced llama3.1 config on synthetic data (cached)."""
    key = ("trained", steps, seq, batch)
    if key not in _STATE:
        from repro.launch.train import train
        params, cfg, data_cfg, hist, final = train(
            arch="llama31_8b", use_reduced=True, steps=steps, batch=batch,
            seq=seq, lr=3e-3, log=lambda *a: None)
        _STATE[key] = (params, cfg, data_cfg, hist, final)
    return _STATE[key]


def calib_context():
    if "ctx" not in _STATE:
        from repro.core import calibration
        from repro.data import SyntheticLM
        params, cfg, data_cfg, _, _ = trained_model()
        calib = SyntheticLM(dataclasses.replace(data_cfg, global_batch=4)
                            ).batch(991)
        batch = {"tokens": jnp.asarray(calib)}
        _STATE["ctx"] = (calibration.build_context(params, cfg, batch),
                         batch)
    return _STATE["ctx"]


def eval_metrics(params, cfg, data_cfg, per_depth_sp=None):
    """Held-out PPL + KL + top-1 agreement vs dense."""
    from repro.core import unstacked as U
    from repro.data import eval_batch
    from repro.sparsity import SparsityPolicy
    toks = jnp.asarray(eval_batch(data_cfg, n=4))
    policy = SparsityPolicy.uniform("mask") if per_depth_sp is not None \
        else SparsityPolicy.dense()
    logits, _ = U.forward_unstacked(params, cfg, toks,
                                    per_depth_sp=per_depth_sp,
                                    policy=policy)
    dense_logits, _ = U.forward_unstacked(params, cfg, toks)
    lg = logits[:, :-1].astype(jnp.float32)
    lab = toks[:, 1:]
    lse = jax.nn.logsumexp(lg, -1)
    pick = jnp.take_along_axis(lg, lab[..., None], -1)[..., 0]
    ppl = float(jnp.exp(jnp.mean(lse - pick)))
    pd = jax.nn.log_softmax(dense_logits.astype(jnp.float32), -1)
    ps = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    kl = float(jnp.mean(jnp.sum(jnp.exp(pd) * (pd - ps), -1)))
    agree = float((jnp.argmax(logits, -1) == jnp.argmax(dense_logits, -1))
                  .mean())
    return {"ppl": ppl, "kl": kl, "top1_agree": agree}
