"""Paper Fig. 6: calibrated alpha values across layers/projection types."""
from __future__ import annotations

import numpy as np

from benchmarks.common import calib_context, trained_model
from repro.core import alpha_search


def run(log=print):
    params, cfg, data_cfg, _, _ = trained_model()
    ctx, _ = calib_context()
    ratios = {(d, p): 0.5 for d in range(ctx.num_blocks)
              for p in ctx.keys_by_depth[d]}
    alphas = alpha_search.search_all_alphas(ctx, ratios, coord_passes=1)
    by_proj = {}
    for (_d, path), a in alphas.items():
        by_proj.setdefault(path, []).append(a)
    rows = []
    for path, vals in sorted(by_proj.items()):
        log(f"alpha[{path}]: mean={np.mean(vals):.3f} "
            f"range=[{min(vals):.2f},{max(vals):.2f}]")
        rows.append((f"fig6/alpha/{path.replace('/', '_')}", 0.0,
                     f"mean={np.mean(vals):.4f};min={min(vals):.2f};"
                     f"max={max(vals):.2f}"))
    nontrivial = any(np.std(v) > 0 or np.mean(v) not in (0.0,)
                     for v in by_proj.values())
    rows.append(("fig6/alphas_nontrivial", 0.0, str(bool(nontrivial))))
    return rows


if __name__ == "__main__":
    run()
