"""Paper Fig. 4: efficiency vs sparsity.

Left panel (FLOPs): per-token matmul FLOPs at 0-50% sparsity — the paper
reports a near-linear reduction (1.92 -> 1.03 TFLOPs at 50% on Llama-3.1
-8B); we compute the same curve analytically for the full llama31_8b
config and from the compiled sparse dry-run artifacts where available.

Right panel (throughput): wall-clock cannot be measured on CPU for a TPU
target; we report the kernel-level arithmetic (block-gather matmul FLOPs/
bytes vs dense) and the modeled decode step time from the roofline terms.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.configs import SHAPES, get_config
from repro.launch import constants as C
from repro.launch import roofline as R


def run(log=print):
    rows = []
    cfg = get_config("llama31_8b")
    n_active = R.active_matmul_params(cfg)
    dense_tf = 2 * n_active / 1e12
    for p in (0.0, 0.3, 0.4, 0.5):
        # attention projections + MLP sparsify; head stays dense
        head = cfg.vocab_size * cfg.d_model
        sparse_tf = 2 * ((n_active - head) * (1 - p) + head) / 1e12
        log(f"sparsity={p:.0%}: {sparse_tf:.3f} TFLOPs/token "
            f"({sparse_tf/dense_tf:.1%} of dense)")
        rows.append((f"fig4/flops_per_token/p{int(p*100)}", 0.0,
                     f"{sparse_tf:.4f}TF;frac={sparse_tf/dense_tf:.4f}"))

    # kernel-level: dense matmul vs block-gather at 50% kept blocks
    B, n, m, blk = 4, 2048, 2048, 128
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, n), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, m), jnp.float32)
    from repro.kernels import sparse_matmul as K
    idx_half = jnp.arange(0, n // blk, 2, dtype=jnp.int32)
    us_dense, _ = timed(jax.jit(lambda x: x @ w), x)
    f_sparse = jax.jit(lambda x: K.sparse_matmul_shared(
        x, w, idx_half, blk=blk, interpret=True))
    us_sparse, _ = timed(f_sparse, x)
    flops_dense = 2 * B * n * m
    flops_sparse = flops_dense // 2
    rows.append(("fig4/kernel_dense_matmul", us_dense,
                 f"flops={flops_dense}"))
    rows.append(("fig4/kernel_gather_50pct", us_sparse,
                 f"flops={flops_sparse};note=interpret-mode-CPU"))
    log(f"kernel: dense {us_dense:.0f}us vs gather@50% {us_sparse:.0f}us "
        "(interpret mode; FLOPs/bytes halve structurally)")

    # modeled decode throughput gain from the dry-run roofline artifacts
    # (prefer the optimized sweep when present)
    base_f = "experiments/dryrun_optimized.jsonl"
    sparse_f = "experiments/dryrun_optimized_sparse.jsonl"
    if not (os.path.exists(base_f) and os.path.exists(sparse_f)):
        base_f = "experiments/dryrun_baseline.jsonl"
        sparse_f = "experiments/dryrun_sparse.jsonl"
    if os.path.exists(base_f) and os.path.exists(sparse_f):
        def load(path):
            out = {}
            with open(path) as f:
                for line in f:
                    r = json.loads(line)
                    if r.get("status") == "ok":
                        out[(r["arch"], r["shape"], r["mesh"])] = r
            return out
        base, sp = load(base_f), load(sparse_f)
        for k in sorted(set(base) & set(sp)):
            if k[1].startswith("decode") and k[2] == "single":
                tb = max(base[k]["roofline"]["compute_s"],
                         base[k]["roofline"]["memory_s"])
                ts = max(sp[k]["roofline"]["compute_s"],
                         sp[k]["roofline"]["memory_s"])
                gain = tb / ts if ts > 0 else float("nan")
                rows.append((f"fig4/modeled_decode_gain/{k[0]}", 0.0,
                             f"x{gain:.2f}"))
                log(f"modeled decode mem/compute speedup {k[0]}: x{gain:.2f}")
    return rows


if __name__ == "__main__":
    run()
