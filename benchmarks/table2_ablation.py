"""Paper Table 2: component ablation at 50% sparsity.

Rows: activation-only -> +weight importance -> +coarse (block) search ->
+fine (layer) search.  The paper's claim is strict ordering (58.64 ->
61.57 -> 62.10 -> 63.57 avg accuracy); our mechanism-level reproduction
asserts the same ordering on calibration KL and held-out PPL."""
from __future__ import annotations

from benchmarks.common import calib_context, eval_metrics, trained_model
from repro import obs
from repro.core import pipeline
from repro.core.allocation import EvoConfig


def run(log=print):
    params, cfg, data_cfg, _, _ = trained_model()
    ctx, batch = calib_context()
    evo = EvoConfig(generations=4, offspring=8, eps=0.1, seed=0)
    p = 0.5
    variants = [
        ("act_only", dict(skip_coarse=True, skip_fine=True, skip_alpha=True,
                          alpha_default=0.0)),
        ("plus_weight", dict(skip_coarse=True, skip_fine=True,
                             coord_passes=0)),
        ("plus_coarse", dict(skip_fine=True, coord_passes=0, evo=evo)),
        ("plus_fine", dict(coord_passes=0, evo=evo, delta=0.25)),
    ]
    rows = []
    kls = []
    for name, kw in variants:
        t0 = obs.now()
        plan = pipeline.run_pipeline(params, cfg, batch, p, ctx=ctx, **kw)
        us = (obs.now() - t0) * 1e6
        kl = ctx.fitness(plan.per_depth_sp)
        m = eval_metrics(params, cfg, data_cfg, plan.per_depth_sp)
        kls.append(kl)
        log(f"{name:12s} KL={kl:.5f} ppl={m['ppl']:.3f} "
            f"agree={m['top1_agree']:.3f}")
        rows.append((f"table2/{name}", us,
                     f"kl={kl:.5f};ppl={m['ppl']:.4f};"
                     f"agree={m['top1_agree']:.4f}"))
    ordered = all(kls[i] >= kls[i + 1] - 1e-9 for i in range(len(kls) - 1))
    log(f"ablation ordering (act>=+w>=+coarse>=+fine on KL): {ordered}")
    rows.append(("table2/ordering_holds", 0.0, str(ordered)))
    return rows


if __name__ == "__main__":
    run()
