"""Paper Fig. 3 / Observation 2: block-wise sensitivity to sparsification.

Sparsify one block at a time (all other blocks dense) at 40/50/60% and
report the relative change in held-out PPL.  The paper's claim: block
sensitivity is heterogeneous and non-monotonic in depth."""
from __future__ import annotations

import numpy as np

from benchmarks.common import calib_context, eval_metrics, trained_model


def run(log=print):
    params, cfg, data_cfg, _, _ = trained_model()
    ctx, _ = calib_context()
    dense = eval_metrics(params, cfg, data_cfg, None)
    rows = []
    spread = {}
    for p in (0.4, 0.5, 0.6):
        deltas = []
        for d in range(ctx.num_blocks):
            ratios = {(d, path): 1.0 - p for path in ctx.keys_by_depth[d]}
            alphas = {(d, path): 1.0 for path in ctx.keys_by_depth[d]}
            sp = ctx.make_sp(alphas, ratios)
            m = eval_metrics(params, cfg, data_cfg, sp)
            delta = (m["ppl"] - dense["ppl"]) / dense["ppl"] * 100
            deltas.append(delta)
        spread[p] = (min(deltas), max(deltas))
        log(f"p={p:.0%} dPPL% per block: "
            + " ".join(f"{d:+.2f}" for d in deltas))
        rows.append((f"fig3/p{int(p*100)}", 0.0,
                     ";".join(f"{d:+.3f}" for d in deltas)))
    hetero = spread[0.5][1] > 2 * max(abs(spread[0.5][0]), 1e-6) or \
        (spread[0.5][1] - spread[0.5][0]) > 0.05
    rows.append(("fig3/heterogeneous", 0.0, str(bool(hetero))))
    log(f"sensitivity heterogeneous across blocks: {hetero}")
    return rows


if __name__ == "__main__":
    run()
