"""Benchmark harness: one module per paper table/figure (plus the serving
benchmark, whose default run covers the Poisson scenario sweep *and* the
SLO-aware adaptive-controller sweep).
Prints ``name,us_per_call,derived`` CSV rows (stdout) per the repo contract.

With ``--artifact-dir`` each benchmark additionally writes a standardized
``BENCH_<name>.json`` artifact there — commit, timestamp (from the
environment: ``SOURCE_DATE_EPOCH`` / ``GITHUB_RUN_ID``, never the wall
clock, so artifacts are reproducible), pass/fail status and every result
row — for CI to upload and for cross-run regression diffing.

    PYTHONPATH=src python -m benchmarks.run --all
    PYTHONPATH=src python -m benchmarks.run [--only table2]
    PYTHONPATH=src python -m benchmarks.run --all --artifact-dir bench-out
"""
import argparse
import json
import os
import subprocess
import sys
import traceback

MODULES = [
    "benchmarks.table1_accuracy",
    "benchmarks.table2_ablation",
    "benchmarks.fig3_sensitivity",
    "benchmarks.fig4_efficiency",
    "benchmarks.fig6_alpha",
    "benchmarks.roofline_report",
    "benchmarks.serving_throughput",
]


def _commit():
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, check=True,
                             cwd=os.path.dirname(os.path.dirname(
                                 os.path.abspath(__file__))))
        return out.stdout.strip()
    except Exception:
        return None


def write_artifact(directory, mod_name, rows, status, error=None):
    """Write ``BENCH_<name>.json`` for one benchmark module; returns the
    path.  ``rows`` are the module's (name, us_per_call, derived) result
    rows — gate outcomes ride in the ``derived`` strings."""
    short = mod_name.rsplit(".", 1)[-1]
    artifact = {
        "benchmark": short,
        "module": mod_name,
        "commit": _commit(),
        "timestamp": os.environ.get("SOURCE_DATE_EPOCH"),
        "run_id": os.environ.get("GITHUB_RUN_ID"),
        "status": status,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }
    if error:
        artifact["error"] = error
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"BENCH_{short}.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every registered benchmark (the default; "
                         "spelled out for scripts)")
    ap.add_argument("--artifact-dir", default=None,
                    help="write a BENCH_<name>.json artifact per "
                         "benchmark here (commit, env timestamp, status, "
                         "result rows)")
    args = ap.parse_args()
    if args.all and args.only:
        raise SystemExit("pass --only or --all, not both")
    import importlib
    all_rows = []
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# === {mod_name} ===", file=sys.stderr, flush=True)
        rows, status, error = [], "ok", None
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run(log=lambda *a: print(*a, file=sys.stderr,
                                                flush=True))
            all_rows.extend(rows)
        except Exception as e:
            traceback.print_exc()
            failed.append(mod_name)
            status, error = "failed", f"{type(e).__name__}: {e}"
        if args.artifact_dir:
            path = write_artifact(args.artifact_dir, mod_name, rows,
                                  status, error)
            print(f"# wrote {path}", file=sys.stderr, flush=True)
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
