"""Benchmark harness: one module per paper table/figure (plus the serving
benchmark, whose default run covers the Poisson scenario sweep *and* the
SLO-aware adaptive-controller sweep).
Prints ``name,us_per_call,derived`` CSV rows (stdout) per the repo contract.

    PYTHONPATH=src python -m benchmarks.run --all
    PYTHONPATH=src python -m benchmarks.run [--only table2]
"""
import argparse
import sys
import traceback

MODULES = [
    "benchmarks.table1_accuracy",
    "benchmarks.table2_ablation",
    "benchmarks.fig3_sensitivity",
    "benchmarks.fig4_efficiency",
    "benchmarks.fig6_alpha",
    "benchmarks.roofline_report",
    "benchmarks.serving_throughput",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every registered benchmark (the default; "
                         "spelled out for scripts)")
    args = ap.parse_args()
    if args.all and args.only:
        raise SystemExit("pass --only or --all, not both")
    import importlib
    all_rows = []
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# === {mod_name} ===", file=sys.stderr, flush=True)
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run(log=lambda *a: print(*a, file=sys.stderr,
                                                flush=True))
            all_rows.extend(rows)
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
