"""Paper Table 1 (proxy): accuracy-retention vs sparsity for the
training-free methods, on the in-repo trained small LM.

Methods: activation-only (TEAL-style |x| criterion), WINA-style (|x|*g,
alpha=1, uniform), full WiSparse (searched alpha + mixed-granularity
allocation).  Metric: held-out PPL and top-1 agreement with the dense
model — the offline analogue of the paper's task-accuracy retention.
"""
from __future__ import annotations

from benchmarks.common import calib_context, eval_metrics, trained_model
from repro import obs
from repro.core import pipeline
from repro.core.allocation import EvoConfig


def run(log=print):
    params, cfg, data_cfg, _, _ = trained_model()
    ctx, batch = calib_context()
    rows = []
    dense = eval_metrics(params, cfg, data_cfg, None)
    log(f"dense: ppl={dense['ppl']:.3f}")
    rows.append(("table1/dense/ppl", 0.0, f"{dense['ppl']:.4f}"))

    evo = EvoConfig(generations=4, offspring=8, eps=0.1, seed=0)
    for sparsity in (0.3, 0.4, 0.5):
        t0 = obs.now()
        plans = {
            "teal_act_only": pipeline.activation_only_plan(
                params, cfg, batch, sparsity, ctx=ctx),
            "wina_alpha1": pipeline.run_pipeline(
                params, cfg, batch, sparsity, skip_coarse=True,
                skip_fine=True, skip_alpha=True, alpha_default=1.0, ctx=ctx),
            "wisparse_full": pipeline.run_pipeline(
                params, cfg, batch, sparsity, evo=evo, delta=0.25,
                coord_passes=0, ctx=ctx),
        }
        us = (obs.now() - t0) * 1e6
        for name, plan in plans.items():
            m = eval_metrics(params, cfg, data_cfg, plan.per_depth_sp)
            retention = dense["ppl"] / m["ppl"]
            log(f"p={sparsity:.0%} {name:16s} ppl={m['ppl']:.3f} "
                f"kl={m['kl']:.4f} agree={m['top1_agree']:.3f} "
                f"retention={retention:.3f}")
            rows.append((f"table1/{name}/p{int(sparsity*100)}", us,
                         f"ppl={m['ppl']:.4f};kl={m['kl']:.5f};"
                         f"agree={m['top1_agree']:.4f}"))
    return rows


if __name__ == "__main__":
    run()
