"""Roofline report: renders the dry-run JSONL records into the §Roofline
table (per arch x shape x mesh: three terms, bottleneck, useful-FLOPs
ratio, MFU, memory fit)."""
from __future__ import annotations

import json
import os
from collections import defaultdict

from repro.launch import constants as C

BASE = "experiments/dryrun_baseline.jsonl"
SPARSE = "experiments/dryrun_sparse.jsonl"


def load(path):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r["arch"], r["shape"], r["mesh"], r.get("sparsity", 0.0))
            recs[key] = r            # later records win (re-runs)
    return recs


def fmt_row(r):
    rl = r["roofline"]
    peak = r["memory"]["peak_bytes_estimate"] / 2**30
    fits = "OK" if peak <= C.CHIP_HBM_BYTES / 2**30 else "OVER"
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rl['compute_s']*1e3:.2f} | {rl['memory_s']*1e3:.2f} | "
            f"{rl['collective_s']*1e3:.2f} | {rl['bottleneck']} | "
            f"{rl['useful_ratio']:.2f} | {rl['mfu']:.3f} | "
            f"{peak:.2f} {fits} |")


HEADER = ("| arch | shape | mesh | compute ms | memory ms | coll ms | "
          "bottleneck | useful | MFU | peak GiB/chip |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def render(log=print, sparsity=0.0, path=BASE):
    recs = load(path)
    log(HEADER)
    n_ok = n_err = 0
    for key in sorted(recs):
        r = recs[key]
        if key[3] != sparsity:
            continue
        if r.get("status") != "ok":
            log(f"| {key[0]} | {key[1]} | {key[2]} | FAILED: "
                f"{r.get('error', '?')[:60]} |")
            n_err += 1
            continue
        log(fmt_row(r))
        n_ok += 1
    return n_ok, n_err


def run(log=print):
    rows = []
    for name, path, sp in (
            ("baseline", BASE, 0.0), ("sparse50", SPARSE, 0.5),
            ("optimized", "experiments/dryrun_optimized.jsonl", 0.0),
            ("optimized_sparse50",
             "experiments/dryrun_optimized_sparse.jsonl", 0.5)):
        if not os.path.exists(path):
            continue
        log(f"\n== roofline {name} ==")
        ok, err = render(log, sparsity=sp, path=path)
        rows.append((f"roofline/{name}/cells_ok", 0.0, str(ok)))
        rows.append((f"roofline/{name}/cells_failed", 0.0, str(err)))
    # always-present coverage row: the artifact carries at least one row
    # even without experiment dumps, so benchmarks.compare has a
    # non-vacuous baseline to gate against
    rows.append(("roofline/reports_rendered", 0.0, str(len(rows) // 2)))
    return rows


if __name__ == "__main__":
    run()
