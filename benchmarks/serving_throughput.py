"""Serving throughput under a Poisson arrival trace: dense vs WiSparse
decode backends on the continuous-batching engine.

Replays the *same* seeded request trace (prompts, lengths, arrival times)
against one engine per sparsity mode and reports decode tokens/s, p50/p95
request latency and time-to-first-token.  Also checks the engine's
token-level parity against the legacy static-batch ``generate()`` loop
(equal-length prompts, whole-prefill strategy) — the engine must match it
exactly.

    PYTHONPATH=src python -m benchmarks.serving_throughput \
        [--modes off,topk_shared,topk_block] [--requests 16] [--rate 8]

The default model is a reduced-but-not-tiny llama31_8b variant
(d_model=768, d_ff=6144, 4 layers) — large enough that decode is
matmul-bound on CPU, so the shared-mask gather backends show their FLOP/
byte savings (≥1.15x decode tokens/s at 50% sparsity for topk_shared).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api
from repro.serving import Engine, EngineConfig, EngineStats
from repro.serving.metrics import latency_percentiles


def bench_config(d_model=768, d_ff=6144, layers=4, vocab=1024):
    cfg = reduced(get_config("llama31_8b"))
    return dataclasses.replace(cfg, d_model=d_model, d_ff=d_ff,
                               num_layers=layers, num_heads=8,
                               num_kv_heads=4, head_dim=64,
                               vocab_size=vocab)


def poisson_trace(n_requests, rate_hz, prompt_lens, seed=0):
    """(arrival_s, prompt_len) per request; exponential inter-arrivals."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    lens = rng.choice(prompt_lens, size=n_requests)
    return arrivals, lens


def replay(engine: Engine, prompts, arrivals, gen_tokens):
    """Drive the engine against wall-clock arrivals; returns trace states."""
    states = []
    t0 = time.monotonic()
    i = 0
    while i < len(prompts) or engine.scheduler.has_work():
        now = time.monotonic() - t0
        while i < len(prompts) and arrivals[i] <= now:
            states.append(engine.submit(prompts[i], gen_tokens,
                                        arrival_time=t0 + arrivals[i]))
            i += 1
        if engine.scheduler.has_work():
            engine.step()
        elif i < len(prompts):
            time.sleep(min(1e-3, max(0.0, arrivals[i] - now)))
    return states


def run(log=print, modes=("off", "topk_shared", "topk_block"),
        n_requests=16, rate_hz=8.0, gen_tokens=48, max_slots=8,
        sparsity=0.5, seed=0, reps=2, cfg=None):
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    sp = default_sp_stacked(params, cfg, keep_frac=1.0 - sparsity)

    prompt_lens = (24, 32, 48)
    arrivals, lens = poisson_trace(n_requests, rate_hz, prompt_lens, seed)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n_requests)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    # --- parity gate: engine == legacy generate(), token for token -------
    eq_prompts = jnp.asarray(pool[:4, :32])
    legacy = np.asarray(generate(params, cfg, eq_prompts, 8, sp,
                                 mode="topk_shared", k_max_frac=1 - sparsity))
    eng = Engine(params, cfg, EngineConfig(
        max_slots=4, max_len=48, mode="topk_shared",
        k_max_frac=1 - sparsity, prefill_strategy="whole",
        prefill_dense_frac=1.0), sp)
    for b in range(4):
        eng.submit(np.asarray(eq_prompts[b]), 8)
    out = eng.run()
    parity = all(out[b] == list(legacy[b]) for b in range(4))
    log(f"engine/legacy token parity: {'OK' if parity else 'FAIL'}")
    rows = [("serving/parity_vs_generate", 0.0,
             "ok" if parity else "FAIL")]
    assert parity, "engine diverged from legacy generate()"

    # --- throughput under the Poisson trace ------------------------------
    # reps are interleaved across modes (off, sparse, off, sparse, ...) and
    # we keep each mode's best rep: wall-clock on a shared CPU drifts with
    # background load, and interleaving + best-of-n cancels that drift out
    # of the mode-vs-mode ratio
    engines = {}
    for mode in modes:
        use_sp = sp if mode != "off" else None
        engines[mode] = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32,
            mode=mode, k_max_frac=(1 - sparsity) if use_sp else 1.0), use_sp)
        # warm the executables so compile time stays out of the trace
        engines[mode].submit(prompts[0], 2)
        engines[mode].run()

    results = {m: 0.0 for m in modes}
    best = {}
    for rep in range(reps):
        for mode in modes:
            engine = engines[mode]
            engine.stats = EngineStats()
            states = replay(engine, prompts, arrivals, gen_tokens)
            if mode not in best or engine.stats.decode_tps > results[mode]:
                results[mode] = engine.stats.decode_tps
                best[mode] = (engine.stats, states)
    for mode in modes:
        s, states = best[mode]
        lat = latency_percentiles(states)
        log(f"{mode:12s} decode {s.decode_tps:7.1f} tok/s | prefill "
            f"{s.prefill_tps:7.1f} tok/s | latency p50 "
            f"{lat['latency_p50']:.2f}s p95 {lat['latency_p95']:.2f}s | "
            f"ttft p50 {lat['ttft_p50']:.2f}s | occ "
            f"{s.summary()['mean_occupancy']:.1f}/{max_slots}")
        rows.append((f"serving/decode_tps/{mode}", 0.0,
                     f"{s.decode_tps:.1f}tok/s;p50={lat['latency_p50']:.3f}s;"
                     f"p95={lat['latency_p95']:.3f}s"))

    if "off" in results and "topk_shared" in results:
        ratio = results["topk_shared"] / results["off"]
        log(f"topk_shared vs dense decode speedup: x{ratio:.2f} "
            f"(sparsity {sparsity:.0%})")
        rows.append(("serving/decode_speedup_topk_shared", 0.0,
                     f"x{ratio:.3f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", default="off,topk_shared,topk_block")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()
    rows = run(modes=tuple(args.modes.split(",")), n_requests=args.requests,
               rate_hz=args.rate, gen_tokens=args.gen, max_slots=args.slots,
               sparsity=args.sparsity, seed=args.seed, reps=args.reps)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
