"""Serving throughput under a Poisson arrival trace: dense vs WiSparse
decode backends (uniform and mixed per-block policies) on the
continuous-batching engine.

Replays the *same* seeded request trace (prompts, lengths, arrival times)
against one engine per :class:`SparsityPolicy` and reports decode
tokens/s, p50/p95 request latency, time-to-first-token, and each
scenario's token agreement vs the dense run.  Also checks the engine's
token-level parity against the legacy static-batch ``generate()`` loop
(equal-length prompts, whole-prefill strategy) — the engine must match it
exactly.

Scenarios (``--modes``): ``off`` / ``mask`` / ``topk_shared`` /
``topk_block`` / ``pallas`` are uniform-backend policies; ``mixed`` runs
the most sensitive blocks dense and ``topk_shared`` elsewhere at the
*matched global budget* (the sparse blocks prune harder so the average
keep ratio equals the uniform run's).  Without a calibrated plan the
"sensitive" set is the first ``--sensitive-frac`` of blocks — the early
blocks a calibrated ``plan.to_policy(sensitive_backend=...)`` would
typically protect.

    PYTHONPATH=src python -m benchmarks.serving_throughput \
        [--modes off,topk_shared,topk_block,mixed] [--requests 16] [--rate 8]
    PYTHONPATH=src python -m benchmarks.serving_throughput --controller
    PYTHONPATH=src python -m benchmarks.serving_throughput --spec
    PYTHONPATH=src python -m benchmarks.serving_throughput --prefix-cache
    PYTHONPATH=src python -m benchmarks.serving_throughput --telemetry
    PYTHONPATH=src python -m benchmarks.serving_throughput --gateway
    PYTHONPATH=src python -m benchmarks.serving_throughput --quality
    PYTHONPATH=src python -m benchmarks.serving_throughput --smoke   # CI

``--controller`` runs the SLO-aware adaptive sweep instead: a *stepped*
Poisson trace (calm -> burst -> calm) replayed against a fixed-dense
engine and a ladder engine under an :class:`AdaptiveController`.  The
p95-TPOT target is set from a dense probe at a fraction dense cannot hold
at peak; the sweep reports rung residency, p95 TPOT vs the SLO for both
engines, per-rung vs-dense token agreement, and asserts the controller
visited >= 2 rungs with zero decode retraces after warmup.

``--spec`` runs the self-speculative decoding sweep: the model is
*quick-trained* on the synthetic language first (a random-init model's
greedy argmax flips under any perturbation, so a sparse drafter would
never be accepted; a lightly trained one is confident enough that the
50%-sparse rung mostly agrees with the dense verifier), then the same
Poisson trace replays against a verifier-only engine, a plain-sparse
engine and a spec engine.  Reports decode tok/s for all three, the
accept rate per (drafter rung, gamma) so future PRs can tune defaults
from data, and enforces two hard gates: spec output token-identical to
verifier-only decode across the whole trace, and zero decode/verify
retraces after warmup.

``--prefix-cache`` runs the shared-system-prompt sweep: every trace
request shares one long system prefix plus a short unique suffix, and
the same Poisson trace replays against a cold-prefill engine and a
prefix-cache engine.  Hard gates: whole-trace token parity (cache-hit
generations must be bit-identical to cold prefill), hit rate >= 0.75,
warm TTFT p50 <= 0.6x cold, and zero decode retraces after warmup.

``--telemetry`` runs the observability sweep (``repro.obs``): the same
trace replays against a plain engine and one with full telemetry (span
tracer + event log + dispatch annotations).  Hard gates: bit-identical
tokens on every rep, full-telemetry decode tok/s >= 97% of plain
(interleaved best-of-reps), zero decode retraces with annotations
enabled, and the exported Prometheus/Chrome-trace artifacts validate.

``--gateway`` runs the two-tenant burst sweep: a best-effort ``batch``
tenant floods the slot pool with long generations, then interactive
``chat`` requests arrive mid-decode.  FIFO baseline vs a priority +
preemption engine.  Hard gates: every preempted-then-resumed request
finishes token-identical to its unpreempted FIFO run, preemptions > 0,
zero decode/segment retraces after warmup, and (full mode) interactive
p95 TTFT <= 0.7x the FIFO baseline's.

``--quality`` runs the quality-observability sweep: a ladder engine
pinned at a sparse rung replays the trace with the
:class:`repro.obs.QualityMonitor` off and on (shadow dense probes,
online reconstruction error, saliency drift, roofline counters).  Hard
gates: bit-identical tokens probes-on vs off on every rep, probes-on
wall-clock throughput >= 97% of probes-off, zero decode AND zero
probe/recon retraces after warmup, and the exported artifacts carry the
``repro_quality_*`` families and validate.

The default model is a reduced-but-not-tiny llama31_8b variant
(d_model=768, d_ff=6144, 4 layers) — large enough that decode is
matmul-bound on CPU, so the shared-mask gather backends show their FLOP/
byte savings (≥1.15x decode tokens/s at 50% sparsity for topk_shared).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.configs import get_config, reduced
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api
from repro.serving import (Engine, EngineConfig, EngineStats, Priority,
                           SchedulerConfig, SLOConfig, SpecConfig)
from repro.serving.metrics import latency_percentiles, percentile
from repro.sparsity import PolicyLadder, SparsityPolicy


def bench_config(d_model=768, d_ff=6144, layers=4, vocab=1024):
    cfg = reduced(get_config("llama31_8b"))
    return dataclasses.replace(cfg, d_model=d_model, d_ff=d_ff,
                               num_layers=layers, num_heads=8,
                               num_kv_heads=4, head_dim=64,
                               vocab_size=vocab)


def poisson_trace(n_requests, rate_hz, prompt_lens, seed=0):
    """(arrival_s, prompt_len) per request; exponential inter-arrivals."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    lens = rng.choice(prompt_lens, size=n_requests)
    return arrivals, lens


def stepped_trace(segments, prompt_lens, seed=0):
    """Bursty load: concatenated Poisson segments [(n_requests, rate_hz),
    ...] — e.g. calm -> burst -> calm.  Returns (arrivals, lens)."""
    rng = np.random.default_rng(seed)
    arrivals, t = [], 0.0
    for n, rate in segments:
        gaps = rng.exponential(1.0 / rate, size=n)
        for g in gaps:
            t += g
            arrivals.append(t)
    arrivals = np.asarray(arrivals)
    lens = rng.choice(prompt_lens, size=len(arrivals))
    return arrivals, lens


def replay(engine: Engine, prompts, arrivals, gen_tokens, submit_kw=None):
    """Drive the engine against wall-clock arrivals; returns trace states.

    ``gen_tokens`` is an int or a per-request sequence; ``submit_kw``
    optionally gives per-request extra :meth:`Engine.submit` keywords
    (priority / tenant / deadline).  Resets the engine's request-id
    namespace first, so trace request ``i`` is request id ``i`` on every
    engine and every rep — cross-engine state comparisons key on the id."""
    engine.reset_ids()
    states = []
    gens = ([gen_tokens] * len(prompts) if np.isscalar(gen_tokens)
            else list(gen_tokens))
    t0 = obs.now()            # the engine's own clock (repro.obs.clock)
    i = 0
    while i < len(prompts) or engine.scheduler.has_work():
        now = obs.now() - t0
        while i < len(prompts) and arrivals[i] <= now:
            states.append(engine.submit(prompts[i], gens[i],
                                        arrival_time=t0 + arrivals[i],
                                        **(submit_kw[i] if submit_kw
                                           else {})))
            i += 1
        if engine.scheduler.has_work():
            engine.step()
        elif i < len(prompts):
            time.sleep(min(1e-3, max(0.0, arrivals[i] - now)))
    return states


def _set_keep_per_depth(sp, cfg, keep_by_depth):
    """Stacked sp tree with each layer's traced keep_frac taken from
    keep_by_depth[depth] (scalar leaves become per-rep vectors)."""

    def set_keep(tree, keep_vec):
        if isinstance(tree, dict):
            if "keep_frac" in tree and "g" in tree:
                return {**tree,
                        "keep_frac": jnp.asarray(keep_vec, jnp.float32)}
            return {k: set_keep(v, keep_vec) for k, v in tree.items()}
        return tree

    out, depth = [], 0
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        group = {}
        for j in range(len(pattern)):
            keep_vec = [keep_by_depth[depth + r * len(pattern) + j]
                        for r in range(reps)]
            group[f"l{j}"] = set_keep(sp[gi][f"l{j}"], keep_vec)
        out.append(group)
        depth += len(pattern) * reps
    return out


def mixed_scenario(params, cfg, sparsity, sensitive_frac=0.25):
    """(policy, sp) for the mixed row: dense on the sensitive blocks,
    topk_shared elsewhere, pruned harder so the *global* keep budget
    matches the uniform run's 1 - sparsity."""
    L = cfg.num_layers
    n_dense = min(max(1, int(round(L * sensitive_frac))), L - 1)
    keep_target = 1.0 - sparsity
    f = n_dense / L
    k_rest = (keep_target - f) / (1.0 - f)
    if k_rest < 0.05:
        raise ValueError(
            f"cannot match the global keep budget {keep_target:.2f} with "
            f"{n_dense}/{L} blocks dense (the rest would need keep_frac "
            f"{k_rest:.3f} < 0.05); lower --sensitive-frac or --sparsity")
    keep_by_depth = [1.0 if d < n_dense else k_rest for d in range(L)]
    sp = default_sp_stacked(params, cfg, keep_frac=1.0)
    sp = _set_keep_per_depth(sp, cfg, keep_by_depth)
    policy = SparsityPolicy.uniform(
        "topk_shared", k_max_frac=k_rest,
        block_backends=((0, n_dense, "off"),))
    return policy, sp


def _agreement(states_a, states_b):
    """Mean per-request fraction of identical generated tokens, keyed by
    request id — ``replay()`` resets each engine's id namespace per rep,
    so trace request ``i`` carries id ``i`` on every engine."""
    by_id = {s.request.request_id: s for s in states_b}
    assert {s.request.request_id for s in states_a} == set(by_id), \
        f"trace mismatch: {len(states_a)} vs {len(states_b)} requests"
    fracs = []
    for sa in states_a:
        ta, tb = sa.tokens, by_id[sa.request.request_id].tokens
        n = max(len(ta), len(tb), 1)
        eq = sum(1 for x, y in zip(ta, tb) if x == y)
        fracs.append(eq / n)
    return float(np.mean(fracs)) if fracs else 1.0


def run(log=print, modes=("off", "topk_shared", "topk_block", "mixed"),
        n_requests=16, rate_hz=8.0, gen_tokens=48, max_slots=8,
        sparsity=0.5, seed=0, reps=2, cfg=None, sensitive_frac=0.25,
        expect_speedup=True, controller=True):
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    sp_uniform = default_sp_stacked(params, cfg, keep_frac=1.0 - sparsity)

    scenarios = {}
    for mode in modes:
        if mode == "off":
            scenarios[mode] = (SparsityPolicy.dense(), None)
        elif mode == "mixed":
            scenarios[mode] = mixed_scenario(params, cfg, sparsity,
                                             sensitive_frac)
        else:
            # 1e-6 floor: k_max_frac must be > 0; at 100% sparsity the
            # gather backends keep their one-channel minimum
            scenarios[mode] = (SparsityPolicy.uniform(
                mode, k_max_frac=max(1.0 - sparsity, 1e-6)), sp_uniform)

    prompt_lens = (24, 32, 48)
    arrivals, lens = poisson_trace(n_requests, rate_hz, prompt_lens, seed)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n_requests)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    # --- parity gate: engine == legacy generate(), token for token -------
    eq_prompts = jnp.asarray(pool[:4, :32])
    parity_pol = SparsityPolicy.uniform("topk_shared",
                                        k_max_frac=max(1 - sparsity, 1e-6))
    legacy = np.asarray(generate(params, cfg, eq_prompts, 8, sp_uniform,
                                 policy=parity_pol))
    eng = Engine(params, cfg, EngineConfig(
        max_slots=4, max_len=48, policy=parity_pol,
        prefill_strategy="whole", prefill_dense_frac=1.0), sp_uniform)
    for b in range(4):
        eng.submit(np.asarray(eq_prompts[b]), 8)
    out = eng.run()
    parity = all(out[b] == list(legacy[b]) for b in range(4))
    log(f"engine/legacy token parity: {'OK' if parity else 'FAIL'}")
    rows = [("serving/parity_vs_generate", 0.0,
             "ok" if parity else "FAIL")]
    assert parity, "engine diverged from legacy generate()"

    # --- throughput under the Poisson trace ------------------------------
    # reps are interleaved across modes (off, sparse, off, sparse, ...) and
    # we keep each mode's best rep: wall-clock on a shared CPU drifts with
    # background load, and interleaving + best-of-n cancels that drift out
    # of the mode-vs-mode ratio
    engines = {}
    for mode, (policy, sp) in scenarios.items():
        engines[mode] = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32,
            policy=policy), sp)
        # warm the executables so compile time stays out of the trace
        engines[mode].submit(prompts[0], 2)
        engines[mode].run()

    results = {m: 0.0 for m in scenarios}
    best = {}
    for _rep in range(reps):
        for mode in scenarios:
            engine = engines[mode]
            engine.stats = EngineStats()
            states = replay(engine, prompts, arrivals, gen_tokens)
            if mode not in best or engine.stats.decode_tps > results[mode]:
                results[mode] = engine.stats.decode_tps
                best[mode] = (engine.stats, states)
    dense_states = best.get("off", (None, None))[1]
    for mode in scenarios:
        s, states = best[mode]
        lat = latency_percentiles(states)
        agree = _agreement(states, dense_states) \
            if dense_states is not None else float("nan")
        log(f"{mode:12s} decode {s.decode_tps:7.1f} tok/s | prefill "
            f"{s.prefill_tps:7.1f} tok/s | latency p50 "
            f"{lat['latency_p50']:.2f}s p95 {lat['latency_p95']:.2f}s | "
            f"ttft p50 {lat['ttft_p50']:.2f}s | occ "
            f"{s.summary()['mean_occupancy']:.1f}/{max_slots} | "
            f"vs-dense agree {agree:.1%}")
        rows.append((f"serving/decode_tps/{mode}", 0.0,
                     f"{s.decode_tps:.1f}tok/s;p50={lat['latency_p50']:.3f}s;"
                     f"p95={lat['latency_p95']:.3f}s;"
                     f"dense_agree={agree:.3f}"))

    if "off" in results and "topk_shared" in results and expect_speedup:
        ratio = results["topk_shared"] / results["off"]
        log(f"topk_shared vs dense decode speedup: x{ratio:.2f} "
            f"(sparsity {sparsity:.0%})")
        rows.append(("serving/decode_speedup_topk_shared", 0.0,
                     f"x{ratio:.3f}"))
    if "off" in results and "mixed" in results:
        ratio = results["mixed"] / results["off"]
        log(f"mixed (dense sensitive + topk_shared) vs dense decode "
            f"speedup: x{ratio:.2f} (matched global budget)")
        rows.append(("serving/decode_speedup_mixed", 0.0, f"x{ratio:.3f}"))
    if controller:
        log("--- SLO-aware adaptive controller sweep ---")
        rows.extend(run_controller(log=log, cfg=cfg, seed=seed,
                                   gen_tokens=gen_tokens,
                                   max_slots=max_slots))
    return rows


def _request_tpot(rs):
    """Mean inter-token latency of one finished request, seconds."""
    n = len(rs.tokens)
    if n < 2 or rs.finish_time is None or rs.first_token_time is None:
        return None
    return (rs.finish_time - rs.first_token_time) / (n - 1)


def _tpot_p95(states, ids=None):
    """p95 over per-request mean TPOT, optionally restricted to request
    ids (e.g. the burst segment — the peak-load window the SLO is
    judged on)."""
    vals = [_request_tpot(s) for s in states
            if ids is None or s.request.request_id in ids]
    vals = [v for v in vals if v is not None]
    return percentile(vals, 95)


def _rung_agreement(states, dense_states, num_rungs):
    """Per-rung mean token agreement vs the dense run: each controller
    token is attributed to the rung that emitted it."""
    dense = {s.request.request_id: s.tokens for s in dense_states}
    eq = [[] for _ in range(num_rungs)]
    for s in states:
        ref = dense.get(s.request.request_id, [])
        for i, (tok, rung) in enumerate(zip(s.tokens, s.token_rungs)):
            if i < len(ref):
                eq[rung].append(1.0 if tok == ref[i] else 0.0)
    return [float(np.mean(e)) if e else float("nan") for e in eq]


def run_controller(log=print, cfg=None, budgets=(0.0, 0.5, 0.75),
                   segments=((6, 2.0), (24, 30.0), (6, 2.0)),
                   gen_tokens=48, max_slots=8, seed=0,
                   slo_frac=0.85, max_queue=2, dwell=4,
                   check=True):
    """SLO-aware adaptive sweep on a stepped (calm/burst/calm) trace.

    A dense probe replay measures the p95 per-request TPOT the
    fixed-dense policy delivers for the *burst-segment* requests (the
    peak-load window); the SLO target is set at ``slo_frac`` of it — an
    objective dense *cannot* hold at peak by construction — and the
    ladder engine must hold it by climbing rungs through the burst."""
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    # every rung prefills dense: on CPU the top-k gather backends pay off
    # on the wide decode batch but are overhead-bound on a skinny (1, C)
    # prefill chunk (the weight-row gather copies ~as many bytes as the
    # dense matmul reads), and burst-time TPOT is decode + interleaved
    # prefill — sparsifying prefill would *raise* the gap it must shrink
    ladder = PolicyLadder.uniform(
        params, cfg, budgets,
        dense_phases=("prefill_dense", "prefill_sparse"))

    prompt_lens = (24, 32, 48)
    arrivals, lens = stepped_trace(segments, prompt_lens, seed)
    n_requests = len(arrivals)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n_requests)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    def fresh_engine(slo=None):
        ecfg = EngineConfig(max_slots=max_slots, max_len=max_len,
                            prefill_chunk=32, slo=slo)
        return Engine(params, cfg, ecfg, ladder=ladder)

    # the peak-load window the SLO is judged on: the burst segment's
    # request ids (submission order == arrival order == segment order)
    n_head = segments[0][0]
    burst_ids = set(range(n_head, n_head + segments[1][0])) \
        if len(segments) > 1 else None

    # --- dense probe: what the fixed-dense policy delivers at peak -------
    dense_eng = fresh_engine()                    # pinned at rung 0: dense
    dense_eng.warmup()      # precompile outside the trace; request ids
    dense_states = replay(dense_eng, prompts, arrivals, gen_tokens)
    # stay aligned with the controller run's for per-rung agreement
    dense_p95 = _tpot_p95(dense_states, burst_ids)
    target = slo_frac * dense_p95
    log(f"dense probe: burst-request p95 TPOT {dense_p95*1e3:.1f}ms -> "
        f"SLO target {target*1e3:.1f}ms ({slo_frac:.0%} of dense)")

    # --- adaptive run under the same trace -------------------------------
    slo = SLOConfig(tpot_p95=target, max_queue=max_queue, dwell=dwell)
    eng = fresh_engine(slo=slo)                   # warms up all rungs
    states = replay(eng, prompts, arrivals, gen_tokens)
    ctl = eng.controller
    ctl_p95 = _tpot_p95(states, burst_ids)
    res = ctl.snapshot()["rung_residency"]
    agree = _rung_agreement(states, dense_states, len(ladder))
    visited = sum(1 for r in ctl.residency if r > 0)
    retraces = eng.decode_retraces_after_warmup

    log(f"controller: burst-request p95 TPOT {ctl_p95*1e3:.1f}ms vs "
        f"target {target*1e3:.1f}ms | rungs visited "
        f"{visited}/{len(ladder)} | "
        f"residency {[f'{r:.0%}' for r in res]} | "
        f"switches {len(ctl.transitions)} | decode retraces {retraces}")
    for i, b in enumerate(ladder.budgets):
        log(f"  rung {i} (sparsity {b:.0%}): residency {res[i]:.1%}, "
            f"vs-dense agreement "
            f"{'n/a' if np.isnan(agree[i]) else f'{agree[i]:.1%}'}")

    rows = [
        ("serving/controller/dense_tpot_p95_s", 0.0, f"{dense_p95:.5f}"),
        ("serving/controller/slo_tpot_p95_s", 0.0, f"{target:.5f}"),
        ("serving/controller/ctl_tpot_p95_s", 0.0,
         f"{ctl_p95:.5f};held={ctl_p95 <= target}"),
        ("serving/controller/rungs_visited", 0.0,
         f"{visited}/{len(ladder)}"),
        ("serving/controller/rung_residency", 0.0,
         ";".join(f"{r:.3f}" for r in res)),
        ("serving/controller/rung_agreement_vs_dense", 0.0,
         ";".join("nan" if np.isnan(a) else f"{a:.3f}" for a in agree)),
        ("serving/controller/decode_retraces_after_warmup", 0.0,
         str(retraces)),
    ]
    if check:
        assert visited >= 2, \
            f"controller only visited {visited} rung(s) on the burst trace"
        assert retraces == 0, \
            f"{retraces} decode retrace(s) after warmup — rung switches " \
            "must be compile-cache hits"
        assert dense_p95 > target, "SLO target not below dense p95?"
        assert ctl_p95 <= target, \
            f"controller p95 TPOT {ctl_p95:.4f}s misses the " \
            f"{target:.4f}s SLO the dense policy also violates " \
            f"(dense p95 {dense_p95:.4f}s)"
    return rows


def run_prefix(log=print, cfg=None, n_requests=12, rate_hz=8.0,
               sys_len=160, sfx_lens=(8, 16, 32), gen_tokens=32,
               max_slots=4, chunk=32, seed=0, reps=2,
               ttft_gate=0.6, hit_gate=0.75, check=True,
               check_ttft=True):
    """Shared-system-prompt sweep: prefix-cache engine vs cold prefill.

    Every trace request is ``system prefix (sys_len tokens) + unique
    suffix``; one priming request (a suffix outside the trace) is run to
    completion on both engines before measuring, so the cache is
    populated and the trace's hit rate is deterministic rather than a
    race against the first request's prefill.  Reps are interleaved
    (cold, warm, cold, warm) and each engine keeps its best rep, the
    same drift-cancelling protocol as ``run()``.  The parity gate runs
    on EVERY warm rep: dense decode is per-row deterministic, so the
    warm engine must reproduce the cold engine's tokens exactly no
    matter how the faster prefill reshuffles batching."""
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    sfx = rng.choice(sfx_lens, size=n_requests)
    pool = np.asarray(SyntheticLM(DataConfig(
        cfg.vocab_size, sys_len + max(sfx_lens), n_requests + 2)).batch(0))
    system = pool[0, :sys_len]
    prompts = [np.concatenate([system, pool[i + 1, :sfx[i]]])
               for i in range(n_requests)]
    prime = np.concatenate([system, pool[-1, :max(sfx_lens)]])
    max_len = sys_len + max(sfx_lens) + gen_tokens

    def fresh(prefix: bool) -> Engine:
        eng = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=chunk,
            prefix_cache=prefix), None)
        eng.warmup()
        eng.submit(prime, 2)      # populate the cache / warm executables
        eng.run()
        eng.stats = EngineStats()
        return eng

    engines = {"cold": fresh(False), "warm": fresh(True)}
    best = {}
    for rep in range(reps):
        rep_states = {}
        for mode, eng in engines.items():
            eng.stats = EngineStats()
            states = replay(eng, prompts, arrivals, gen_tokens)
            rep_states[mode] = states
            lat = latency_percentiles(states)
            if mode not in best or lat["ttft_p50"] < best[mode][1]["ttft_p50"]:
                best[mode] = (eng.stats, lat, states)
        for i, (sw, sc) in enumerate(zip(rep_states["warm"],
                                         rep_states["cold"])):
            assert sw.tokens == sc.tokens, \
                f"prefix-cache run diverged from cold prefill on trace " \
                f"request {i} (rep {rep})"
    log(f"prefix-cache parity vs cold prefill: OK "
        f"({n_requests} requests x {reps} reps)")
    rows = [("serving/prefix/parity_vs_cold", 0.0, "ok")]

    warm_stats = best["warm"][0]
    hit_rate = warm_stats.prefix_hits / max(1, warm_stats.prefix_lookups)
    retraces = engines["warm"].decode_retraces_after_warmup
    for mode in engines:
        s, lat, _ = best[mode]
        log(f"{mode:6s} ttft p50 {lat['ttft_p50']*1e3:7.1f}ms p95 "
            f"{lat['ttft_p95']*1e3:7.1f}ms | latency p50 "
            f"{lat['latency_p50']:.2f}s | prefill {s.prefill_tokens} tok "
            f"in {s.prefill_time:.2f}s | decode {s.decode_tps:7.1f} tok/s")
        rows.append((f"serving/prefix/ttft/{mode}", 0.0,
                     f"p50={lat['ttft_p50']:.4f}s;"
                     f"p95={lat['ttft_p95']:.4f}s"))
    ratio = best["warm"][1]["ttft_p50"] / best["cold"][1]["ttft_p50"]
    log(f"prefix-cache TTFT p50: {ratio:.2f}x cold | hit rate "
        f"{hit_rate:.1%} | {warm_stats.prefix_tokens_saved} prompt tokens "
        f"not re-prefilled | decode retraces after warmup {retraces}")
    rows.append(("serving/prefix/ttft_p50_ratio", 0.0,
                 f"x{ratio:.3f};gate<={ttft_gate}"))
    rows.append(("serving/prefix/hit_rate", 0.0,
                 f"{hit_rate:.3f};tokens_saved="
                 f"{warm_stats.prefix_tokens_saved}"))
    rows.append(("serving/prefix/decode_retraces_after_warmup", 0.0,
                 str(retraces)))
    if check:
        assert hit_rate > 0, "prefix cache never hit on a shared-prefix trace"
        assert retraces == 0, \
            f"{retraces} decode retrace(s) after warmup — prefix " \
            "admission must not disturb the decode executable"
        if check_ttft:
            assert hit_rate >= hit_gate, \
                f"hit rate {hit_rate:.2f} below the {hit_gate} gate"
            assert ratio <= ttft_gate, \
                f"prefix-cache TTFT p50 is {ratio:.2f}x cold, above the " \
                f"{ttft_gate}x gate"
    return rows


def run_telemetry(log=print, cfg=None, n_requests=12, rate_hz=8.0,
                  gen_tokens=48, max_slots=4, seed=0, reps=3,
                  overhead_gate=0.97, check=True, check_overhead=True,
                  trace_out=None, metrics_out=None, events_out=None):
    """Telemetry parity + overhead sweep: the same Poisson trace replays
    against a plain engine and one with full telemetry (span tracer +
    event log + dispatch annotations).

    Hard gates: (1) bit-identical tokens with telemetry on vs off, on
    EVERY rep — telemetry only observes host-side state; (2) full
    telemetry keeps >= ``overhead_gate`` (default 97%) of plain decode
    tok/s, judged on interleaved best-of-``reps`` to cancel CPU drift;
    (3) zero decode retraces after warmup with dispatch annotations
    enabled — annotations wrap the dispatch, not the traced function, so
    they must not perturb jit cache keys; (4) the run's artifacts
    validate (Prometheus exposition + Chrome trace schema), optionally
    exported to ``trace_out``/``metrics_out``/``events_out``."""
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    prompt_lens = (24, 32, 48)
    arrivals, lens = poisson_trace(n_requests, rate_hz, prompt_lens, seed)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n_requests)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    tel = obs.Telemetry.full(events_sink=events_out)

    def fresh(telemetry):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32),
            None, telemetry=telemetry)
        eng.warmup()
        eng.submit(prompts[0], 2)     # absorb first-dispatch overheads
        eng.run()
        eng.stats = EngineStats()
        return eng

    engines = {"plain": fresh(None), "telemetry": fresh(tel)}

    results = {m: 0.0 for m in engines}
    best = {}
    for rep in range(reps):
        rep_states = {}
        for mode, eng in engines.items():
            eng.stats = EngineStats()
            states = replay(eng, prompts, arrivals, gen_tokens)
            rep_states[mode] = states
            if mode not in best or eng.stats.decode_tps > results[mode]:
                results[mode] = eng.stats.decode_tps
                best[mode] = eng.stats
        # parity gate on EVERY rep (states align by trace order)
        for i, (st, sp_) in enumerate(zip(rep_states["telemetry"],
                                          rep_states["plain"])):
            assert st.tokens == sp_.tokens, \
                f"telemetry changed tokens on trace request {i} " \
                f"(rep {rep}) — it must only observe"
    log(f"telemetry parity vs plain engine: OK "
        f"({n_requests} requests x {reps} reps)")
    rows = [("serving/telemetry/parity_vs_plain", 0.0, "ok")]

    ratio = results["telemetry"] / results["plain"]
    retraces = engines["telemetry"].decode_retraces_after_warmup
    for mode, _eng in engines.items():
        log(f"{mode:10s} decode {results[mode]:7.1f} tok/s")
        rows.append((f"serving/telemetry/decode_tps/{mode}", 0.0,
                     f"{results[mode]:.1f}tok/s"))
    log(f"full-telemetry decode throughput: {ratio:.1%} of plain "
        f"(gate >= {overhead_gate:.0%}) | {len(tel.tracer.events)} spans, "
        f"{tel.events.count} events | decode retraces with annotations "
        f"{retraces}")
    rows.append(("serving/telemetry/overhead_ratio", 0.0,
                 f"{ratio:.4f};gate>={overhead_gate}"))
    rows.append(("serving/telemetry/decode_retraces_after_warmup", 0.0,
                 str(retraces)))

    # --- artifacts validate (and export when paths are given) ------------
    n_samples = obs.validate_exposition(
        engines["telemetry"].metrics_exposition())
    n_events = obs.validate_chrome_trace(tel.tracer.to_dict())
    log(f"artifacts: exposition OK ({n_samples} samples), trace OK "
        f"({n_events} events)")
    rows.append(("serving/telemetry/artifacts", 0.0,
                 f"exposition={n_samples};trace={n_events};"
                 f"events={tel.events.count}"))
    if trace_out:
        tel.tracer.export(trace_out)
        log(f"wrote trace to {trace_out}")
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(engines["telemetry"].metrics_exposition())
        log(f"wrote exposition to {metrics_out}")
    # Engine.close() flushes every telemetry sink (satisfying sinks with
    # buffered JSONL even if a gate above raised first on a rerun)
    engines["telemetry"].close()

    if check:
        assert retraces == 0, \
            f"{retraces} decode retrace(s) with dispatch annotations — " \
            "annotations must not perturb jit cache keys"
        if check_overhead:
            assert ratio >= overhead_gate, \
                f"full telemetry keeps only {ratio:.1%} of plain decode " \
                f"throughput, below the {overhead_gate:.0%} gate"
    return rows


def run_quality(log=print, cfg=None, budgets=(0.0, 0.5), rung=1,
                n_requests=12, rate_hz=8.0, gen_tokens=48, max_slots=4,
                seed=0, reps=3, probe_rate=0.25, recon_every=4,
                recon_window=8, overhead_gate=0.97, check=True,
                check_overhead=True, trace_out=None, metrics_out=None,
                events_out=None):
    """Quality-observability sweep: shadow dense probes on vs off.

    The same Poisson trace replays against a ladder engine pinned at a
    sparse rung with no quality monitor and an identical engine with the
    :class:`repro.obs.QualityMonitor` armed (shadow dense probes, online
    reconstruction error, saliency drift, roofline counters).

    Hard gates: (1) bit-identical tokens probes-on vs probes-off on
    EVERY rep — the probe's dense KV writes are overwritten by the real
    decode step before they can be read; (2) probes-on keeps
    >= ``overhead_gate`` of probes-off decode throughput, judged on
    wall-clock around ``replay()`` (the probe runs *outside* the engine's
    timed decode region, so ``stats.decode_tps`` would hide its cost);
    (3) zero decode retraces AND zero probe/recon retraces after warmup;
    (4) the exposition validates and carries the ``repro_quality_*``
    families, the Chrome trace validates, and ``snapshot()`` reports the
    quality fields at schema v6."""
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    # every rung prefills dense (same rationale as the controller sweep):
    # the comparison is pure decode mechanics + probe overhead
    ladder = PolicyLadder.uniform(
        params, cfg, budgets,
        dense_phases=("prefill_dense", "prefill_sparse"))

    prompt_lens = (24, 32, 48)
    arrivals, lens = poisson_trace(n_requests, rate_hz, prompt_lens, seed)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n_requests)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    tel = obs.Telemetry.full(
        events_sink=events_out,
        quality=obs.QualityConfig(probe_rate=probe_rate,
                                  recon_every=recon_every,
                                  recon_window=recon_window))

    def fresh(telemetry):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32,
            initial_rung=rung), ladder=ladder, telemetry=telemetry)
        eng.warmup()
        eng.submit(prompts[0], 2)     # absorb first-dispatch overheads
        eng.run()
        eng.stats = EngineStats()
        return eng

    engines = {"plain": fresh(None), "quality": fresh(tel)}

    # interleaved best-of reps on *wall-clock* replay time: both engines
    # emit the same tokens (parity gate), so tok/s ratio == time ratio
    times = {m: float("inf") for m in engines}
    for rep in range(reps):
        rep_states = {}
        for mode, eng in engines.items():
            eng.stats = EngineStats()
            t0 = obs.now()
            states = replay(eng, prompts, arrivals, gen_tokens)
            times[mode] = min(times[mode], obs.now() - t0)
            rep_states[mode] = states
        # parity gate on EVERY rep (states align by trace order)
        for i, (sq, sp_) in enumerate(zip(rep_states["quality"],
                                          rep_states["plain"])):
            assert sq.tokens == sp_.tokens, \
                f"quality probes changed tokens on trace request {i} " \
                f"(rep {rep}) — the probe must only observe"
    log(f"probe parity vs plain engine: OK "
        f"({n_requests} requests x {reps} reps)")
    rows = [("serving/quality/parity_vs_plain", 0.0, "ok")]

    q = tel.quality
    eng_q = engines["quality"]
    ratio = times["plain"] / times["quality"]
    d_retraces = eng_q.decode_retraces_after_warmup
    p_retraces = eng_q.probe_retraces_after_warmup
    snap = eng_q.snapshot()
    log(f"probes {q.probes} ({q.probe_tokens} tokens) | recon passes "
        f"{q.recon_passes} | agreement "
        f"{snap.get('quality_agreement_mean')} | top-k overlap "
        f"{snap.get('quality_topk_overlap_mean')} | pressure "
        f"{snap.get('quality_pressure')}")
    log(f"probes-on wall-clock throughput: {ratio:.1%} of probes-off "
        f"(gate >= {overhead_gate:.0%}) | retraces after warmup: decode "
        f"{d_retraces} probe {p_retraces}")
    rows.append(("serving/quality/probes", 0.0,
                 f"{q.probes};tokens={q.probe_tokens};"
                 f"recon={q.recon_passes};drift={q.drift_events}"))
    rows.append(("serving/quality/agreement", 0.0,
                 f"{snap.get('quality_agreement_mean')};topk="
                 f"{snap.get('quality_topk_overlap_mean')}"))
    rows.append(("serving/quality/overhead_ratio", 0.0,
                 f"{ratio:.4f};gate>={overhead_gate}"))
    rows.append(("serving/quality/retraces_after_warmup", 0.0,
                 f"decode={d_retraces};probe={p_retraces}"))

    # --- artifacts validate (and export when paths are given) ------------
    expo = eng_q.metrics_exposition()
    n_samples = obs.validate_exposition(expo)
    n_events = obs.validate_chrome_trace(tel.tracer.to_dict())
    log(f"artifacts: exposition OK ({n_samples} samples), trace OK "
        f"({n_events} events)")
    rows.append(("serving/quality/artifacts", 0.0,
                 f"exposition={n_samples};trace={n_events};"
                 f"schema={snap['schema_version']}"))
    if trace_out:
        tel.tracer.export(trace_out)
        log(f"wrote trace to {trace_out}")
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(expo)
        log(f"wrote exposition to {metrics_out}")
    eng_q.close()

    if check:
        assert q.probes > 0, "probe never fired on the trace"
        assert d_retraces == 0, \
            f"{d_retraces} decode retrace(s) after warmup with probing on"
        assert p_retraces == 0, \
            f"{p_retraces} probe/recon retrace(s) after warmup — the " \
            "probe executables must precompile in warmup()"
        assert "repro_quality_probes_total" in expo, \
            "exposition is missing the repro_quality_* families"
        assert snap["schema_version"] == 7 and "quality_probes" in snap, \
            "snapshot() must report the quality fields at schema v7"
        if check_overhead:
            assert ratio >= overhead_gate, \
                f"probing keeps only {ratio:.1%} of probes-off decode " \
                f"throughput, below the {overhead_gate:.0%} gate"
    return rows


def _ttft(rs):
    if rs.first_token_time is None:
        return None
    return rs.first_token_time - rs.request.arrival_time


def run_gateway(log=print, cfg=None, n_bulk=4, n_interactive=6,
                bulk_gen=64, int_gen=8, int_start=0.3, int_rate=8.0,
                max_slots=2, max_queue=32, seed=0, reps=2,
                ttft_gate=0.7, check=True, check_ttft=True):
    """Two-tenant burst sweep: priority + preemption vs FIFO admission.

    A ``batch`` tenant floods the pool with long best-effort generations
    at t=0; a ``chat`` tenant's short interactive requests arrive while
    every KV slot is decoding bulk work.  The same trace replays against
    a FIFO baseline engine (no :class:`SchedulerConfig`; all requests at
    the default class) and a priority engine with preemption armed — the
    interactive arrivals suspend bulk victims to host memory and take
    their slots, so their time-to-first-token stops queuing behind bulk
    decode.

    Hard gates: (1) whole-trace per-request token parity between the two
    engines — a preempted-then-resumed bulk request must finish with
    exactly the tokens it produces when never preempted (dense decode is
    per-row deterministic, so batch composition cannot excuse a diff);
    (2) at least one preemption actually happened; (3) zero decode *and*
    zero suspend/resume-segment retraces after warmup; (4) interactive
    p95 TTFT <= ``ttft_gate`` x the FIFO baseline's (skipped in smoke
    mode, where the trace is too small to gate timing)."""
    cfg = cfg or bench_config()
    params = api.init_model(cfg, 0)
    prompt_lens = (24, 32)
    rng = np.random.default_rng(seed)
    n = n_bulk + n_interactive
    lens = rng.choice(prompt_lens, size=n)
    pool = np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, max(prompt_lens), n)).batch(0))
    prompts = [pool[i, :lens[i]] for i in range(n)]
    # bulk floods at t=0; interactive arrives once the pool is decoding
    arrivals = np.concatenate([
        np.full(n_bulk, 0.0),
        int_start + np.cumsum(rng.exponential(1.0 / int_rate,
                                              size=n_interactive))])
    gens = [bulk_gen] * n_bulk + [int_gen] * n_interactive
    pri_kw = ([dict(priority=Priority.BEST_EFFORT, tenant="batch")]
              * n_bulk
              + [dict(priority=Priority.INTERACTIVE, tenant="chat")]
              * n_interactive)
    int_ids = set(range(n_bulk, n))
    max_len = max(prompt_lens) + bulk_gen

    def fresh(scheduler=None):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32,
            scheduler=scheduler), None)
        eng.warmup()
        eng.submit(prompts[0], 2)     # absorb first-dispatch overheads
        eng.run()
        return eng

    engines = {
        "fifo": fresh(),
        "priority": fresh(SchedulerConfig(max_queue=max_queue,
                                          preemption=True)),
    }
    kw = {"fifo": None, "priority": pri_kw}

    best = {}
    total_preemptions = 0
    for _rep in range(reps):
        rep_states = {}
        for mode, eng in engines.items():
            eng.stats = EngineStats()
            states = replay(eng, prompts, arrivals, gens, submit_kw=kw[mode])
            rep_states[mode] = states
            ttfts = [t for s in states if s.request.request_id in int_ids
                     and (t := _ttft(s)) is not None]
            p95 = percentile(ttfts, 95)
            if mode not in best or p95 < best[mode][1]:
                best[mode] = (eng.stats, p95, states)
        total_preemptions += engines["priority"].stats.preemptions
        # parity gate on EVERY rep, keyed by request id: preempted bulk
        # requests must resume to exactly their unpreempted tokens
        ref = {s.request.request_id: s.tokens for s in rep_states["fifo"]}
        for s in rep_states["priority"]:
            rid = s.request.request_id
            assert s.tokens == ref[rid], \
                f"priority engine diverged from FIFO on trace request " \
                f"{rid} ({s.preemptions} preemption(s))"
    log(f"preemption parity vs FIFO: OK ({n} requests x {reps} reps)")
    rows = [("serving/gateway/parity_vs_fifo", 0.0, "ok")]

    pri_eng = engines["priority"]
    d_retraces = pri_eng.decode_retraces_after_warmup
    s_retraces = pri_eng.segment_retraces_after_warmup
    for mode in engines:
        s, p95, _ = best[mode]
        log(f"{mode:9s} interactive ttft p95 {p95*1e3:7.1f}ms | decode "
            f"{s.decode_tps:7.1f} tok/s | preemptions {s.preemptions} "
            f"resumes {s.resumes}")
        rows.append((f"serving/gateway/interactive_ttft_p95/{mode}", 0.0,
                     f"{p95:.4f}s"))
    ratio = best["priority"][1] / best["fifo"][1]
    log(f"interactive ttft p95: {ratio:.2f}x FIFO (gate <= {ttft_gate}) | "
        f"preemptions {total_preemptions} | retraces after warmup: "
        f"decode {d_retraces} segment {s_retraces}")
    rows.append(("serving/gateway/interactive_ttft_ratio", 0.0,
                 f"x{ratio:.3f};gate<={ttft_gate}"))
    rows.append(("serving/gateway/preemptions", 0.0,
                 str(total_preemptions)))
    rows.append(("serving/gateway/retraces_after_warmup", 0.0,
                 f"decode={d_retraces};segment={s_retraces}"))
    if check:
        assert total_preemptions > 0, \
            "no preemption on a trace built to saturate the pool with " \
            "best-effort decode"
        assert d_retraces == 0, \
            f"{d_retraces} decode retrace(s) after warmup — suspend/" \
            "resume must not disturb the decode executable"
        assert s_retraces == 0, \
            f"{s_retraces} suspend/resume segment retrace(s) after " \
            "warmup — warm_segments must precompile every quantized " \
            "length"
        if check_ttft:
            assert ratio <= ttft_gate, \
                f"interactive p95 TTFT is {ratio:.2f}x FIFO, above the " \
                f"{ttft_gate}x gate — preemption is not buying latency"
    return rows


# the spec sweep's synthetic language: lower Markov branching, denser
# copy motifs and a steeper Zipf base than the stock defaults.  The
# paper's premise is a *confident trained* model whose outputs 50%
# weight-aware sparsity preserves; the stock branch-8 language keeps
# greedy-argmax margins so thin that acceptance is noisy run-to-run,
# while this one reaches ~0.9 conditional acceptance within ~50 quick
# training steps (more steps do NOT help — the model over-specializes
# and sparse/dense agreement degrades again, measured 0.92 -> 0.68 from
# step 60 to 80 on the stock recipe).
SPEC_DATA = dict(branch=4, motif_period=32, zipf_a=1.4)


def quick_train(cfg, steps=50, batch=4, seq=64, lr=5e-3, seed=0, log=print,
                data_kw=SPEC_DATA):
    """Sharpen the bench model on the synthetic language.  Speculative
    decoding's speedup is proportional to the drafter's acceptance rate,
    and acceptance is a property of the *model*, not the machinery: a
    random-init model's greedy argmax margins are ~0, so 50% sparsity
    flips essentially every token (measured ~0% conditional acceptance),
    while a few dozen training steps push the margins far enough that the
    weight-aware sparse rung mostly reproduces the dense argmax (~0.9)."""
    import jax
    from repro.optim import adamw
    params = api.init_model(cfg, seed)
    opt_cfg = adamw.AdamWConfig(lr_peak=lr, warmup_steps=max(3, steps // 20),
                                decay_steps=steps)
    ds = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=seed,
                                **(data_kw or {})))
    opt = adamw.init(params, opt_cfg)
    jstep = jax.jit(api.make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    t0 = obs.now()
    metrics = {}
    for i in range(steps):
        params, opt, metrics = jstep(params, opt,
                                     {"tokens": jnp.asarray(ds.batch(i))})
    loss = float(metrics["loss"])
    log(f"quick-train: {steps} steps in {obs.now() - t0:.0f}s, "
        f"final loss {loss:.3f} (uniform {np.log(cfg.vocab_size):.2f})")
    return params


def run_spec(log=print, cfg=None, sparsity=0.5, gamma=2, gammas=(1, 2, 3),
             budgets=None, n_requests=10, rate_hz=8.0, gen_tokens=48,
             max_slots=2, seed=0, reps=2, train_steps=50,
             expect_speedup=True, check=True):
    """Self-speculative decoding sweep (see the module docstring).

    The default scenario: dense verifier (rung 0), drafter at
    ``sparsity`` (rung 1), draft length ``gamma``, a small slot pool —
    the latency-bound low-batch regime speculation targets (batched GEMM
    rows are not free on CPU, so wide pools amortize the dense verifier
    as well as speculation does and the gap closes).  The acceptance
    table sweeps every sparse rung x ``gammas`` on the same trace so the
    accept-rate-per-(drafter, gamma) surface lands in the CSV."""
    cfg = cfg or bench_config()
    params = quick_train(cfg, steps=train_steps, seed=seed, log=log) \
        if train_steps else api.init_model(cfg, seed)
    if budgets is None:
        budgets = (0.0, sparsity, min(0.9, sparsity + 0.25))
    # every rung prefills dense (same rationale as the controller sweep;
    # the verifier rung is dense anyway, and identical prefill across the
    # engines keeps the comparison to pure decode mechanics)
    ladder = PolicyLadder.uniform(
        params, cfg, budgets,
        dense_phases=("prefill_dense", "prefill_sparse"))

    prompt_lens = (24, 32, 48)
    arrivals, lens = poisson_trace(n_requests, rate_hz, prompt_lens, seed)
    pool = np.asarray(SyntheticLM(DataConfig(
        cfg.vocab_size, max(prompt_lens), n_requests,
        **SPEC_DATA)).batch(3))
    prompts = [pool[i, :lens[i]] for i in range(n_requests)]
    max_len = max(prompt_lens) + gen_tokens

    def fresh(rung=0, spec=None):
        eng = Engine(params, cfg, EngineConfig(
            max_slots=max_slots, max_len=max_len, prefill_chunk=32,
            initial_rung=rung, spec=spec), ladder=ladder)
        if spec is None:
            eng.warmup()              # spec engines warm up in __init__
        return eng

    scenarios = {
        "verifier_only": fresh(rung=0),
        "sparse_only": fresh(rung=1),
        "spec": fresh(spec=SpecConfig(gamma=gamma, drafter_rung=1)),
    }

    # interleaved best-of reps, same drift-cancelling protocol as run()
    results = {m: 0.0 for m in scenarios}
    best = {}
    for _rep in range(reps):
        for mode, engine in scenarios.items():
            engine.stats = EngineStats()
            states = replay(engine, prompts, arrivals, gen_tokens)
            if mode not in best or engine.stats.decode_tps > results[mode]:
                results[mode] = engine.stats.decode_tps
                best[mode] = (engine.stats, states)
            # hard parity gate on EVERY spec rep: token-identical to the
            # verifier-only engine across the whole Poisson trace, keyed
            # by request id (replay() resets the id namespace per rep)
            if mode == "spec":
                ref = {s.request.request_id: s.tokens
                       for s in best["verifier_only"][1]}
                for s in states:
                    rid = s.request.request_id
                    assert s.tokens == ref[rid], \
                        f"spec diverged from verifier-only decode on " \
                        f"trace request {rid}"

    rows = [("serving/spec/parity_vs_verifier", 0.0, "ok")]
    log("spec parity vs verifier-only decode: OK "
        f"({n_requests} requests x {reps} reps)")
    spec_eng = scenarios["spec"]
    assert spec_eng.decode_retraces_after_warmup == 0, \
        "spec drafting retraced the decode step after warmup"
    assert spec_eng.verify_retraces_after_warmup == 0, \
        "spec verify retraced after warmup"
    rows.append(("serving/spec/retraces_after_warmup", 0.0, "0"))

    s, _ = best["spec"]
    accept = s.spec_accepted_tokens / max(1, s.spec_draft_tokens)
    for mode in scenarios:
        st, states = best[mode]
        lat = latency_percentiles(states)
        log(f"{mode:14s} decode {st.decode_tps:7.1f} tok/s | latency p50 "
            f"{lat['latency_p50']:.2f}s p95 {lat['latency_p95']:.2f}s")
        rows.append((f"serving/spec/decode_tps/{mode}", 0.0,
                     f"{st.decode_tps:.1f}tok/s"))
    ratio = results["spec"] / results["verifier_only"]
    ratio_sparse = results["spec"] / results["sparse_only"]
    log(f"spec vs verifier-only decode speedup: x{ratio:.2f} | vs plain "
        f"sparse: x{ratio_sparse:.2f} | accept rate {accept:.1%} "
        f"(gamma={gamma}, drafter sparsity {budgets[1]:.0%})")
    rows.append(("serving/spec/decode_speedup_vs_verifier", 0.0,
                 f"x{ratio:.3f};accept={accept:.3f};gamma={gamma}"))
    rows.append(("serving/spec/decode_speedup_vs_sparse", 0.0,
                 f"x{ratio_sparse:.3f}"))
    if check and expect_speedup:
        assert ratio >= 1.1, \
            f"spec decode speedup x{ratio:.2f} below the 1.1x gate at " \
            f"{budgets[1]:.0%} drafter sparsity"

    # --- accept rate per (drafter rung, gamma) ---------------------------
    # one engine per drafter rung; the adaptive-range warmup precompiles
    # every gamma once so the gamma sweep is pure replay (and pinning via
    # set_gamma with the controller detached keeps each entry fixed)
    log("accept rate per (drafter rung, gamma):")
    for rung in range(1, len(budgets)):
        eng = fresh(spec=SpecConfig(
            gamma=min(gammas), drafter_rung=rung, adaptive=True,
            gamma_min=min(gammas), gamma_max=max(gammas)))
        eng.spec_decoder.controller = None
        for g in gammas:
            eng.spec_decoder.set_gamma(g)
            eng.stats = EngineStats()
            replay(eng, prompts, arrivals, gen_tokens)
            st = eng.stats
            acc = st.spec_accepted_tokens / max(1, st.spec_draft_tokens)
            per_verify = st.spec_accepted_tokens / max(1, st.spec_verifies)
            log(f"  drafter rung {rung} (sparsity {budgets[rung]:.0%}) "
                f"gamma {g}: accept {acc:.1%}, "
                f"{per_verify + 1:.2f} tokens/verify, "
                f"{st.decode_tps:7.1f} tok/s")
            rows.append((f"serving/spec/accept/rung{rung}_gamma{g}", 0.0,
                         f"{acc:.3f};tps={st.decode_tps:.1f}"))
        assert eng.verify_retraces_after_warmup == 0, \
            "gamma sweep retraced the verify executable"
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", default="off,topk_shared,topk_block,mixed")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--slots", type=int, default=None,
                    help="KV pool slots (default: 8; the --prefix-cache "
                         "sweep defaults to 4 — the latency-bound regime "
                         "its TTFT gate is calibrated for)")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--sensitive-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + trace for CI: exercises every "
                         "scenario (incl. mixed) and the parity gate in "
                         "about a minute; no throughput expectations")
    ap.add_argument("--controller", action="store_true",
                    help="run only the SLO-aware adaptive sweep (stepped "
                         "burst trace, ladder engine vs fixed dense)")
    ap.add_argument("--spec", action="store_true",
                    help="run only the self-speculative decoding sweep "
                         "(quick-trained model, draft/verify vs plain "
                         "decode, parity + retrace gates)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="run only the shared-system-prompt prefix-cache "
                         "sweep (warm vs cold prefill, token-parity + "
                         "TTFT + hit-rate + retrace gates)")
    ap.add_argument("--telemetry", action="store_true",
                    help="run only the telemetry parity + overhead sweep "
                         "(full repro.obs telemetry vs plain engine: "
                         "bit-identical tokens, <3% decode overhead, "
                         "valid exposition/trace artifacts)")
    ap.add_argument("--gateway", action="store_true",
                    help="run only the two-tenant burst sweep (priority "
                         "+ preemption engine vs FIFO baseline: "
                         "preempted-token parity, interactive TTFT gate, "
                         "zero decode/segment retraces)")
    ap.add_argument("--quality", action="store_true",
                    help="run only the quality-observability sweep "
                         "(shadow dense probes on vs off: bit-identical "
                         "tokens, <3% wall-clock overhead, zero decode/"
                         "probe retraces, repro_quality_* exposition)")
    ap.add_argument("--quality-probe-rate", type=float, default=0.25,
                    help="probe sampling rate for the --quality sweep")
    ap.add_argument("--trace-out", default=None,
                    help="export the telemetry sweep's Chrome trace JSON "
                         "here (with --telemetry or --quality)")
    ap.add_argument("--metrics-out", default=None,
                    help="export the telemetry sweep's Prometheus "
                         "exposition dump here (with --telemetry or "
                         "--quality)")
    ap.add_argument("--events-out", default=None,
                    help="stream the telemetry sweep's event log as "
                         "JSONL here (with --telemetry or --quality)")
    ap.add_argument("--spec-gamma", type=int, default=2,
                    help="draft length for the main spec scenario")
    ap.add_argument("--spec-train-steps", type=int, default=50,
                    help="quick-train steps before the spec sweep (0 "
                         "skips training; expect ~zero acceptance)")
    args = ap.parse_args()
    if args.gateway:
        if args.smoke:
            # tiny model + trace: exercises preemption, suspend/resume
            # parity and the retrace gates; TTFT timing is too noisy to
            # gate at this scale
            rows = run_gateway(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                n_bulk=3, n_interactive=3, bulk_gen=48, int_gen=6,
                int_start=0.05, max_slots=2, seed=args.seed, reps=1,
                check_ttft=False)
        else:
            rows = run_gateway(max_slots=args.slots or 2,
                               seed=args.seed, reps=args.reps)
    elif args.quality:
        art = dict(trace_out=args.trace_out, metrics_out=args.metrics_out,
                   events_out=args.events_out)
        if args.smoke:
            # tiny model + trace: exercises the probe/recon/saliency path
            # and the parity/retrace/artifact gates every decode step;
            # wall-clock too noisy at this scale to gate the overhead
            rows = run_quality(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                n_requests=4, rate_hz=4.0, gen_tokens=10, max_slots=2,
                seed=args.seed, reps=1, probe_rate=1.0, recon_every=2,
                check_overhead=False, **art)
        else:
            rows = run_quality(n_requests=args.requests,
                               rate_hz=args.rate, gen_tokens=args.gen,
                               max_slots=args.slots or 4,
                               seed=args.seed, reps=max(args.reps, 3),
                               probe_rate=args.quality_probe_rate, **art)
    elif args.telemetry:
        art = dict(trace_out=args.trace_out, metrics_out=args.metrics_out,
                   events_out=args.events_out)
        if args.smoke:
            # tiny model + trace: exercises every emit site and the
            # parity/retrace/artifact gates; throughput too noisy at
            # this scale to gate the overhead ratio
            rows = run_telemetry(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                n_requests=4, rate_hz=4.0, gen_tokens=8, max_slots=2,
                seed=args.seed, reps=1, check_overhead=False, **art)
        else:
            rows = run_telemetry(n_requests=args.requests,
                                 rate_hz=args.rate, gen_tokens=args.gen,
                                 max_slots=args.slots or 4,
                                 seed=args.seed, reps=max(args.reps, 3),
                                 **art)
    elif args.prefix_cache:
        if args.smoke:
            # tiny model + trace: exercises admission copy, mid-edge
            # radix matching, publish and the parity/retrace gates; the
            # TTFT ratio is too noisy to gate at this scale
            rows = run_prefix(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                n_requests=4, rate_hz=4.0, sys_len=24, sfx_lens=(4, 8),
                gen_tokens=6, max_slots=2, chunk=8, seed=args.seed,
                reps=1, check_ttft=False)
        else:
            rows = run_prefix(n_requests=args.requests, rate_hz=args.rate,
                              gen_tokens=args.gen,
                              max_slots=args.slots or 4,
                              seed=args.seed, reps=args.reps)
    elif args.spec:
        if args.smoke:
            # tiny + untrained: exercises the full draft/verify/rollback
            # path, the parity gate and the retrace gate; no acceptance
            # or throughput expectations
            rows = run_spec(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                gamma=2, gammas=(2,), n_requests=4, rate_hz=4.0,
                gen_tokens=10, max_slots=2, seed=args.seed, reps=1,
                train_steps=0, expect_speedup=False)
        else:
            rows = run_spec(gamma=args.spec_gamma, sparsity=args.sparsity,
                            gen_tokens=args.gen, seed=args.seed,
                            reps=args.reps,
                            train_steps=args.spec_train_steps)
    elif args.controller:
        if args.smoke:
            rows = run_controller(
                cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                 vocab=512),
                budgets=(0.0, 0.5), segments=((2, 4.0), (8, 50.0),
                                              (2, 4.0)),
                gen_tokens=10, max_slots=2, seed=args.seed,
                max_queue=1, dwell=2, check=False)
        else:
            rows = run_controller(gen_tokens=args.gen,
                                  max_slots=args.slots or 8,
                                  seed=args.seed)
    else:
        kw = dict(modes=tuple(args.modes.split(",")),
                  n_requests=args.requests,
                  rate_hz=args.rate, gen_tokens=args.gen,
                  max_slots=args.slots or 8,
                  sparsity=args.sparsity, seed=args.seed, reps=args.reps,
                  sensitive_frac=args.sensitive_frac)
        if args.smoke:
            kw.update(cfg=bench_config(d_model=128, d_ff=512, layers=4,
                                       vocab=512),
                      n_requests=4, gen_tokens=8, max_slots=4, reps=1,
                      expect_speedup=False, controller=False)
        rows = run(**kw)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
