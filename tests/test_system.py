"""End-to-end system behaviour: train a tiny LM on synthetic data, apply
the full WiSparse pipeline, and serve with sparsity — the paper's
train-free sparsification story on a model that actually learned."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import calibration, pipeline
from repro.core.allocation import EvoConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.launch.train import train
from repro.models import api


@pytest.fixture(scope="module")
def trained():
    params, cfg, data_cfg, hist, final = train(
        arch="llama31_8b", use_reduced=True, steps=80, batch=8, seq=96,
        lr=5e-3, log=lambda *a: None)
    return params, cfg, data_cfg, hist, final


def test_training_reduces_loss(trained):
    _, cfg, _, hist, final = trained
    assert hist[0]["loss"] > final + 0.05
    assert final < np.log(cfg.vocab_size)      # better than uniform


def test_wisparse_on_trained_model(trained):
    """50% sparsity on the trained model: full pipeline beats
    activation-only (the paper's core accuracy claim, mechanism-level)."""
    params, cfg, data_cfg, _, _ = trained
    calib = SyntheticLM(dataclasses.replace(data_cfg, global_batch=2)
                        ).batch(991)
    batch = {"tokens": jnp.asarray(calib)}
    ctx = calibration.build_context(params, cfg, batch)
    plan_act = pipeline.activation_only_plan(params, cfg, batch, 0.5, ctx=ctx)
    kl_act = ctx.fitness(plan_act.per_depth_sp)
    plan = pipeline.run_pipeline(
        params, cfg, batch, 0.5,
        evo=EvoConfig(generations=2, offspring=4, eps=0.1),
        delta=0.25, coord_passes=0, ctx=ctx)
    kl_full = ctx.fitness(plan.per_depth_sp)
    assert kl_full < kl_act
    assert kl_full < 1.0                       # sparse model stays sane


def test_serve_generates_with_sparsity(trained):
    params, cfg, data_cfg, _, _ = trained
    from repro.core.sp_schema import default_sp_stacked
    prompts = jnp.asarray(SyntheticLM(
        dataclasses.replace(data_cfg, global_batch=2, seq_len=32)).batch(5))
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    from repro.sparsity import SparsityPolicy
    toks_sparse = generate(params, cfg, prompts, 8, sp,
                           policy=SparsityPolicy.uniform("topk_shared",
                                                         k_max_frac=0.5))
    toks_dense = generate(params, cfg, prompts, 8, None,
                          policy=SparsityPolicy.dense())
    assert toks_sparse.shape == (2, 8) == toks_dense.shape
    # a trained model + 50% weight-aware sparsity should mostly agree with
    # the dense decode on easy synthetic text
    agree = float((toks_sparse == toks_dense).mean())
    assert agree >= 0.5


def test_decode_equals_prefill_continuation(trained):
    """Greedy decode continuation is consistent with re-running prefill."""
    params, cfg, data_cfg, _, _ = trained
    prompts = jnp.asarray(SyntheticLM(
        dataclasses.replace(data_cfg, global_batch=2, seq_len=16)).batch(6))
    from repro.sparsity import SparsityPolicy
    toks = generate(params, cfg, prompts, 4, None,
                    policy=SparsityPolicy.dense())
    # re-run with the first generated token appended: next token must match
    ext = jnp.concatenate([prompts, toks[:, :1]], axis=1)
    toks2 = generate(params, cfg, ext, 3, None,
                     policy=SparsityPolicy.dense())
    np.testing.assert_array_equal(np.asarray(toks[:, 1:]),
                                  np.asarray(toks2))
