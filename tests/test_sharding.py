"""Sharding rules: divisibility fallback, per-array axis accounting,
host-mesh execution of the constrained model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.sharding import (LOGICAL_RULES_SERVE,
                                        LOGICAL_RULES_TRAIN, mesh_axes_for,
                                        sharding_context)
from repro.launch.mesh import make_host_mesh
from repro.models import api


class FakeMesh:
    """Just enough of a Mesh for rule resolution tests."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def _ctx(rules, shape=(16, 16), names=("data", "model")):
    from repro.distributed.sharding import ShardingCtx
    return ShardingCtx(FakeMesh(shape, names), dict(rules))


def test_divisible_dims_shard():
    ctx = _ctx(LOGICAL_RULES_TRAIN)
    spec = mesh_axes_for(("embed", "mlp"), (8192, 22016), ctx)
    assert spec == P("data", "model")


def test_indivisible_falls_back_to_replicated():
    ctx = _ctx(LOGICAL_RULES_TRAIN)
    # 8 heads cannot shard over model=16
    spec = mesh_axes_for(("batch", None, "heads", None), (256, 4096, 8, 256),
                         ctx)
    assert spec == P("data", None, None, None)


def test_axis_used_once_per_array():
    ctx = _ctx(LOGICAL_RULES_SERVE)
    # batch takes data; kv_seq then skips (data, model) and lands on model
    spec = mesh_axes_for(("batch", "kv_seq", "kv_heads", None),
                         (128, 32768, 8, 128), ctx)
    assert spec == P("data", "model", None, None)


def test_multipod_batch_spans_pod_and_data():
    ctx = _ctx(LOGICAL_RULES_TRAIN, (2, 16, 16), ("pod", "data", "model"))
    spec = mesh_axes_for(("batch", "seq"), (256, 4096), ctx)
    assert spec == P(("pod", "data"), None)


def test_batch_one_replicates_seq_shards():
    ctx = _ctx(LOGICAL_RULES_SERVE)
    spec = mesh_axes_for(("batch", "kv_seq", "kv_heads", None),
                         (1, 524288, 4, 256), ctx)
    assert spec == P(None, ("data", "model"), None, None)


def test_moe_expert_fallback():
    ctx = _ctx(LOGICAL_RULES_TRAIN)
    # 32 experts shard over model; expert_mlp then replicates
    spec = mesh_axes_for(("experts", "embed", "expert_mlp"),
                         (32, 1024, 512), ctx)
    assert spec == P("model", "data", None)
    # 40 experts don't divide -> expert_mlp gets model instead
    spec = mesh_axes_for(("experts", "embed", "expert_mlp"),
                         (40, 1536, 512), ctx)
    assert spec == P(None, "data", "model")


def test_constrain_noop_without_context():
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(constrain(x, "batch", None)),
                                  np.asarray(x))


def test_model_runs_under_host_mesh():
    """The fully-constrained model executes on a 1x1 mesh (plumbing check:
    every constrain() resolves)."""
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0,
                              cfg.vocab_size)
    mesh = make_host_mesh()
    with sharding_context(mesh, LOGICAL_RULES_TRAIN):
        loss = jax.jit(api.make_loss_fn(cfg))(params, {"tokens": toks})
    assert np.isfinite(float(loss))
