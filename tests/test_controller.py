"""Adaptive sparsity controller: synthetic-trace rung dynamics
(escalation under pressure, de-escalation when idle, hysteresis against
oscillation) and ladder-serving engine integration (pinned-rung parity,
retrace-free rung switches)."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.serving import (AdaptiveController, Engine, EngineConfig,
                           SLOConfig)
from repro.sparsity import PolicyLadder


# ---------------------------------------------------------------------------
# controller unit tests (plain numbers, no engine)
# ---------------------------------------------------------------------------

def _slo(**kw):
    base = dict(tpot_p95=1.0, max_queue=4, ewma_alpha=0.5, hysteresis=0.3,
                dwell=2)
    base.update(kw)
    return SLOConfig(**base)


def test_queue_pressure_up_then_idle_down():
    """A synthetic load trace: sustained queue pressure climbs the ladder
    one rung per dwell; a drained queue with low latency walks back
    down."""
    c = AdaptiveController(3, _slo())
    for _ in range(12):
        c.update(gaps=[0.1], queue_depth=10)
    assert c.rung == 2
    assert [t[3] for t in c.transitions] == ["queue", "queue"]
    for _ in range(12):
        c.update(gaps=[0.1], queue_depth=0)
    assert c.rung == 0
    assert [t[3] for t in c.transitions][-2:] == ["idle", "idle"]
    assert sum(c.residency) == 24


def test_tpot_violation_escalates():
    c = AdaptiveController(2, _slo())
    for _ in range(6):
        c.update(gaps=[2.0], queue_depth=0)     # p95 target is 1.0
    assert c.rung == 1
    assert c.transitions[0][3] == "tpot"


def test_hysteresis_prevents_oscillation():
    """Noisy TPOT inside the hysteresis band [target*(1-h), target]
    produces zero switches."""
    rng = np.random.default_rng(0)
    c = AdaptiveController(3, _slo(), initial_rung=1)
    for _ in range(200):
        gap = rng.uniform(0.75, 0.98)           # inside [0.7, 1.0]
        c.update(gaps=[gap], queue_depth=0)
    assert c.rung == 1
    assert c.transitions == []


def test_no_limit_cycle_after_tpot_escalation():
    """After escalating *because* the lower rung violated the target, the
    controller refuses to bounce back down while that rung's estimate is
    fresh — the classic down-up limit cycle."""
    c = AdaptiveController(2, _slo(estimate_ttl=1000))
    for _ in range(6):
        c.update(gaps=[2.0], queue_depth=0)     # rung 0 measured at 2.0
    assert c.rung == 1
    for _ in range(100):
        c.update(gaps=[0.1], queue_depth=0)     # rung 1 is comfortable
    assert c.rung == 1                          # but rung 0 is known-bad
    # once the estimate expires, a probe down is allowed again
    c2 = AdaptiveController(2, _slo(estimate_ttl=20))
    for _ in range(6):
        c2.update(gaps=[2.0], queue_depth=0)
    for _ in range(100):
        c2.update(gaps=[0.1], queue_depth=0)
    assert c2.rung == 0


def test_dwell_limits_switch_rate():
    c = AdaptiveController(4, _slo(dwell=10))
    for _ in range(15):
        c.update(gaps=[5.0], queue_depth=50)
    # first decision is free, then one switch per dwell window: steps 1
    # and 11 under constant overload
    assert c.rung == 2
    assert len(c.transitions) == 2


def test_slo_validation():
    with pytest.raises(ValueError, match="tpot_p95"):
        SLOConfig(tpot_p95=0.0)
    with pytest.raises(ValueError, match="hysteresis"):
        SLOConfig(tpot_p95=1.0, hysteresis=1.0)
    with pytest.raises(ValueError, match="dwell"):
        SLOConfig(tpot_p95=1.0, dwell=0)
    with pytest.raises(ValueError, match="initial_rung"):
        AdaptiveController(2, _slo(), initial_rung=5)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    ladder = PolicyLadder.uniform(params, cfg, budgets=(0.0, 0.5))
    return params, cfg, ladder


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def test_pinned_rung_matches_fixed_policy_engine(model):
    """A ladder engine pinned at rung r emits bit-identical tokens to a
    fixed-policy engine built from that rung's (policy, sp)."""
    params, cfg, ladder = model
    prompts = _prompts(cfg, 2, 12, step=5)
    outs = []
    for mode in ("pinned", "fixed"):
        if mode == "pinned":
            eng = Engine(params, cfg,
                         EngineConfig(max_slots=2, max_len=32,
                                      prefill_chunk=8, initial_rung=1),
                         ladder=ladder)
            assert eng.rung == 1 and eng.controller is None
        else:
            pol, sp = ladder.rung(1)
            eng = Engine(params, cfg,
                         EngineConfig(max_slots=2, max_len=32,
                                      prefill_chunk=8, policy=pol), sp)
        for b in range(2):
            eng.submit(prompts[b], 6)
        outs.append(eng.run())
    assert outs[0] == outs[1]


def test_controller_switches_rungs_without_retrace(model):
    """Queue pressure drives the engine up the ladder mid-run, the drain
    brings it back down, rung indices are recorded per token, and no
    decode step retraces after the warmup precompile."""
    params, cfg, ladder = model
    slo = SLOConfig(tpot_p95=1e6, max_queue=1, dwell=2, hysteresis=0.25)
    eng = Engine(params, cfg,
                 EngineConfig(max_slots=2, max_len=32, prefill_chunk=8,
                              slo=slo), ladder=ladder)
    assert eng.decode_retraces_after_warmup == 0
    prompts = _prompts(cfg, 8, 10, step=9)
    for b in range(8):                    # 8 requests into 2 slots: queue
        eng.submit(prompts[b], 10)
    out = eng.run()
    assert all(len(t) == 10 for t in out.values())
    c = eng.controller
    assert sum(1 for r in c.residency if r > 0) >= 2   # visited >= 2 rungs
    reasons = [t[3] for t in c.transitions]
    assert "queue" in reasons             # escalated under pressure
    assert "idle" in reasons              # and came back down
    assert eng.rung == 0                  # drained -> densest rung
    # the compile-cache assertion: switches never retraced decode
    assert eng.decode_retraces_after_warmup == 0
    # every emitted token knows the rung that produced it
    for rs in eng.states.values():
        assert len(rs.token_rungs) == len(rs.tokens)
    assert {r for rs in eng.states.values() for r in rs.token_rungs} == \
        {0, 1}


def test_ladder_engine_rejects_bad_wiring(model):
    params, cfg, ladder = model
    with pytest.raises(ValueError, match="not both"):
        Engine(params, cfg, EngineConfig(), sp=ladder.sps[1],
               ladder=ladder)
    with pytest.raises(ValueError, match="outside"):
        Engine(params, cfg, EngineConfig(initial_rung=7), ladder=ladder)
    with pytest.raises(ValueError, match="needs a PolicyLadder"):
        Engine(params, cfg, EngineConfig(slo=SLOConfig(tpot_p95=1.0)))
    # a pinned rung without a ladder is a config error, not a silent rung 0
    with pytest.raises(ValueError, match="needs a\n?.*PolicyLadder"):
        Engine(params, cfg, EngineConfig(initial_rung=1))


def test_warmup_refuses_busy_engine(model):
    """warmup() writes garbage into slot 0's cache prefix — legal only
    while the pool is empty."""
    params, cfg, ladder = model
    eng = Engine(params, cfg,
                 EngineConfig(max_slots=2, max_len=32, prefill_chunk=8),
                 ladder=ladder)
    eng.warmup()                              # idle: fine
    eng.submit(_prompts(cfg, 1, 10)[0], 4)
    with pytest.raises(RuntimeError, match="busy"):
        eng.warmup()
    eng.run()
    eng.warmup()                              # drained again: fine
