"""repro.analysis: corpus precision, suppressions, baseline semantics,
the PR 9 regression tripwire, and negative coverage for the jaxpr
passes (a de-donated engine and a collapsed tile plan must be caught).

The corpus test is *exact*: the passes must flag every line marked
``# EXPECT: <rule-id>`` under ``tests/analysis_corpus`` and nothing
else — over-flagging is a failure just like under-flagging, because a
noisy linter gets baselined into oblivion.
"""
import json
import os
import re
import shutil

import pytest

from repro.analysis import (Baseline, BaselineError, Finding,
                            is_suppressed, parse_suppressions,
                            run_ast_passes)
from repro.analysis.cli import main as cli_main

HERE = os.path.dirname(__file__)
REPO = os.path.abspath(os.path.join(HERE, os.pardir))
CORPUS = os.path.join(HERE, "analysis_corpus")

_EXPECT_RE = re.compile(r"#\s*EXPECT:\s*([A-Za-z0-9-]+)")


def _expected_corpus_findings():
    expected = set()
    for dirpath, _, files in os.walk(os.path.join(CORPUS, "src")):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, CORPUS)
            with open(path) as fh:
                for i, line in enumerate(fh, 1):
                    m = _EXPECT_RE.search(line)
                    if m:
                        expected.add((rel, i, m.group(1)))
    return expected


# ---------------------------------------------------------------------------
# AST passes: corpus precision
# ---------------------------------------------------------------------------

def test_corpus_flags_exactly_the_marked_lines():
    expected = _expected_corpus_findings()
    assert expected, "corpus has no EXPECT markers — did the files move?"
    got = {(f.path, f.line, f.rule)
           for f in run_ast_passes(CORPUS, roots=("src",))}
    missing = expected - got
    extra = got - expected
    assert not missing, f"rules failed to flag known-bad lines: {missing}"
    assert not extra, f"rules over-flagged unmarked lines: {extra}"


def test_corpus_covers_every_ast_rule():
    """Each AST rule must have at least one corpus trigger, or a rule
    regression ships silently."""
    from repro.analysis import ast_passes as _  # noqa: F401 (register)
    from repro.analysis.registry import ast_passes
    covered = {rule for _, _, rule in _expected_corpus_findings()}
    assert covered == set(ast_passes())


def test_inline_suppression_silences_one_rule_on_one_line():
    src = ("import time\n"
           "a = time.time()  # repro: ignore[no-raw-time]\n"
           "b = time.time()  # repro: ignore[some-other-rule]\n"
           "c = time.time()  # repro: ignore\n")
    sup = parse_suppressions(src)
    f = lambda line: Finding(rule="no-raw-time", path="x.py", line=line,
                             message="m")  # noqa: E731
    assert is_suppressed(f(2), sup)
    assert not is_suppressed(f(3), sup)        # names a different rule
    assert is_suppressed(f(4), sup)            # bare ignore = all rules
    assert not is_suppressed(f(1), sup)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_absorbs_exactly_its_findings(tmp_path):
    findings = run_ast_passes(CORPUS, roots=("src",))
    base = Baseline.from_findings(findings, justification="corpus test")
    assert base.filter(findings) == []          # everything grandfathered
    # a NEW finding (different snippet) still surfaces
    fresh = Finding(rule="no-raw-time", path="src/new.py", line=3,
                    message="m", snippet="t = time.time()")
    assert base.filter(findings + [fresh]) == [fresh]
    # per-fingerprint counts: a second identical offender is NOT covered
    dup = findings[0]
    assert base.filter(findings + [dup]) == [dup]
    path = tmp_path / "base.json"
    base.save(str(path))
    assert Baseline.load(str(path)).filter(findings) == []


def test_baseline_refuses_unjustified_entries(tmp_path):
    path = tmp_path / "base.json"
    path.write_text(json.dumps({
        "version": 1,
        "findings": [{"rule": "no-raw-time", "path": "a.py",
                      "snippet": "x", "justification": "  "}],
    }))
    with pytest.raises(BaselineError, match="justification"):
        Baseline.load(str(path))
    path.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(BaselineError, match="version"):
        Baseline.load(str(path))


def test_baseline_is_line_number_robust():
    """Moving a grandfathered line (edits above it) must not resurrect
    the finding: fingerprints use the stripped source line, not the
    line number."""
    f1 = Finding(rule="r", path="p.py", line=10, message="m",
                 snippet="x = hash(k)")
    base = Baseline.from_findings([f1], justification="j")
    moved = Finding(rule="r", path="p.py", line=42, message="m",
                    snippet="x = hash(k)")
    assert base.filter([moved]) == []


# ---------------------------------------------------------------------------
# the PR 9 tripwire: reverting the crc32 fix must re-flag params.py
# ---------------------------------------------------------------------------

def test_reverted_crc32_fix_is_redetected(tmp_path):
    with open(os.path.join(REPO, "src/repro/models/params.py")) as fh:
        src = fh.read()
    assert "zlib.crc32" in src, "params.py lost the PR 9 crc32 fix?"
    reverted = src.replace(
        "zlib.crc32(_path_str(path).encode())",
        "hash(_path_str(path))")
    assert reverted != src
    scratch = tmp_path / "src" / "repro" / "models"
    scratch.mkdir(parents=True)
    (scratch / "params.py").write_text(reverted)
    findings = run_ast_passes(str(tmp_path), roots=("src",),
                              rules=["no-builtin-hash-persistence"])
    assert findings, "the reverted PR 9 hash() bug was not re-detected"
    assert all(f.rule == "no-builtin-hash-persistence" for f in findings)


def test_tree_is_clean_under_ast_passes():
    """The acceptance bar: the real tree carries zero AST findings."""
    assert run_ast_passes(REPO) == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_clean_tree_exits_zero(capsys):
    assert cli_main(["--ast-only", "--root", REPO]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_cli_json_format_and_nonzero_on_findings(tmp_path, capsys):
    shutil.copytree(CORPUS, tmp_path / "c")
    (tmp_path / "c" / "pyproject.toml").write_text("")
    rc = cli_main(["--ast-only", "--root", str(tmp_path / "c"),
                   "--format", "json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert len(doc["findings"]) == len(_expected_corpus_findings())
    assert {f["rule"] for f in doc["findings"]} >= {
        "no-builtin-hash-persistence", "no-raw-time"}


def test_cli_baseline_flow(tmp_path, capsys):
    shutil.copytree(CORPUS, tmp_path / "c")
    root = str(tmp_path / "c")
    (tmp_path / "c" / "pyproject.toml").write_text("")
    assert cli_main(["--ast-only", "--root", root, "--write-baseline",
                     str(tmp_path / "b.json")]) == 0
    capsys.readouterr()
    # TODO justifications must be rejected...
    assert cli_main(["--ast-only", "--root", root, "--baseline",
                     str(tmp_path / "b.json")]) == 2
    doc = json.loads((tmp_path / "b.json").read_text())
    for e in doc["findings"]:
        e["justification"] = "known-bad corpus, grandfathered on purpose"
    (tmp_path / "b.json").write_text(json.dumps(doc))
    capsys.readouterr()
    # ...and a justified baseline swallows every corpus finding
    assert cli_main(["--ast-only", "--root", root, "--baseline",
                     str(tmp_path / "b.json")]) == 0
    assert "baselined" in capsys.readouterr().out


def test_cli_rejects_unknown_rule(capsys):
    assert cli_main(["--ast-only", "--root", REPO,
                     "--rules", "not-a-rule"]) == 2


# ---------------------------------------------------------------------------
# jaxpr / executable passes
# ---------------------------------------------------------------------------

def test_global_passes_clean_on_tree():
    """Acceptance: donation took, statics hash, Pallas plans in bounds —
    zero findings over the full 3-rung warmup executable set."""
    from repro.analysis import run_global_passes
    assert run_global_passes(REPO) == []


def test_donation_pass_catches_dedonated_engine(monkeypatch):
    """Strip donate_argnums from the engine's step construction and the
    pass must flag every rung's decode/chunk executable."""
    import jax

    from repro.analysis.registry import global_passes
    from repro.models import api
    from repro.serving import engine as engine_mod

    def undonated(cfg, on_decode_trace=None, on_chunk_trace=None):
        slot_decode = api.make_slot_decode_step(cfg)
        chunk_step = api.make_chunk_prefill_step(cfg)
        prefill_step = api.make_prefill_step(cfg)

        def _decode(params, tokens, positions, caches, sp, active, *,
                    policy):
            return slot_decode(params, tokens, positions, caches, sp,
                               active, policy=policy)

        def _chunk(params, tokens, offset, slot, caches, sp, weights, *,
                   policy):
            return chunk_step(params, tokens, offset, slot, caches, sp,
                              weights, policy=policy)

        def _prefill(params, tokens, sp, *, policy):
            return prefill_step(params, {"tokens": tokens}, sp,
                                policy=policy)

        return (jax.jit(_decode, static_argnames=("policy",)),
                jax.jit(_chunk, static_argnames=("policy",)),
                jax.jit(_prefill, static_argnames=("policy",)))

    monkeypatch.setattr(engine_mod, "make_engine_steps", undonated)
    findings = global_passes()["jit-donation"].run(REPO)
    flagged = {f.snippet for f in findings}
    # 3 rungs x (decode + 2 chunk phases) lowered, plus the compiled
    # representative — every one must be caught
    assert len(findings) >= 9, findings
    assert any("decode[rung=0]" in s for s in flagged)
    assert any("chunk[rung=2" in s for s in flagged)


def test_pallas_pass_catches_collapsed_tiles(monkeypatch):
    """Re-introduce the pre-PR 5 behaviour (degrade to 1-wide tiles on
    awkward dims instead of padding) and the pass must flag it."""
    from repro.analysis.registry import global_passes
    from repro.kernels import sparse_matmul as K

    def collapsing_fit(size, want):
        want = min(want, size)
        t = want
        while size % t:
            t -= 1              # the old bug: walks all the way to 1
        return t

    monkeypatch.setattr(K, "_fit_tile", collapsing_fit)
    findings = global_passes()["pallas-blockspec"].run(REPO)
    assert any("_fit_tile" in f.snippet for f in findings), findings


def test_static_args_pass_catches_unhashable_policy():
    from repro.analysis.registry import global_passes

    class Unhashable:
        __hash__ = None

    p = global_passes()["jit-static-args"]
    sites = [("src/repro/serving/engine.py", 1, object())]
    findings = p._check_policy(Unhashable(), sites)
    assert any("unhashable" in f.message for f in findings)

    class IdentityHashed:
        pass

    findings = p._check_policy(IdentityHashed(), sites)
    assert any("identity" in f.message or "frozen" in f.message
               for f in findings)
