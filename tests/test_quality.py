"""Sparsity quality observability (``repro.obs.quality``): shadow dense
probes, reconstruction error vs calibration baselines, saliency drift
attribution, roofline counters, and the quality-aware controller hint."""
import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.serving import Engine, EngineConfig, SLOConfig
from repro.serving.controller import AdaptiveController
from repro.sparsity import PolicyLadder


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


@pytest.fixture(scope="module")
def ladder(model):
    params, cfg = model
    return PolicyLadder.uniform(
        params, cfg, (0.0, 0.5),
        dense_phases=("prefill_dense", "prefill_sparse"))


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _engine(params, cfg, ladder=None, telemetry=None, rung=0, **kw):
    defaults = dict(max_slots=2, max_len=32, prefill_chunk=8,
                    initial_rung=rung)
    defaults.update(kw)
    return Engine(params, cfg, EngineConfig(**defaults), None,
                  ladder=ladder, telemetry=telemetry)


# ---------------------------------------------------------------------------
# config + stride
# ---------------------------------------------------------------------------

def test_quality_config_validation():
    for kw, msg in [(dict(probe_rate=0.0), "probe_rate"),
                    (dict(probe_rate=1.5), "probe_rate"),
                    (dict(drift_threshold=1.0), "drift_threshold"),
                    (dict(drift_threshold=0.0), "drift_threshold"),
                    (dict(drift_alpha=0.0), "drift_alpha"),
                    (dict(topk=0), "topk"),
                    (dict(recon_every=-1), "recon_every"),
                    (dict(recon_window=0), "recon_window"),
                    (dict(saliency_topk=0), "saliency_topk")]:
        with pytest.raises(ValueError, match=msg):
            obs.QualityConfig(**kw)
    with pytest.raises(TypeError, match="not both"):
        obs.QualityMonitor(obs.QualityConfig(), probe_rate=0.5)


def test_probe_stride_is_deterministic():
    q = obs.QualityMonitor(probe_rate=0.5)
    assert not q.should_probe()          # inert until attach() arms it
    q.armed = True
    assert [q.should_probe() for _ in range(6)] \
        == [True, False, True, False, True, False]
    assert q.retraces_after_warmup is None   # no warm baseline yet


# ---------------------------------------------------------------------------
# null path: monitor off must cost (and change) nothing
# ---------------------------------------------------------------------------

def test_null_path_off_by_default(model):
    params, cfg = model
    assert obs.NULL_TELEMETRY.quality is None
    eng = _engine(params, cfg)
    eng.submit(_prompts(cfg, 1, 8)[0], 3)
    eng.run()
    snap = eng.snapshot()
    assert snap["schema_version"] == 7
    assert not any(k.startswith("quality_") for k in snap)
    assert eng.probe_retraces_after_warmup is None
    assert "repro_quality_probes_total" not in eng.metrics_exposition()


# ---------------------------------------------------------------------------
# shadow probes
# ---------------------------------------------------------------------------

def test_probe_parity_dense_agreement_and_roofline(model, ladder):
    """Probing at the dense rung: tokens identical to a probe-free run,
    agreement exactly 1.0 (the probe IS the serving policy), zero probe
    retraces, roofline counters captured for every rung."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 8)

    def run(telemetry):
        eng = _engine(params, cfg, ladder=ladder, telemetry=telemetry)
        eng.warmup()
        for p in prompts:
            eng.submit(p, 6)
        return eng, eng.run()

    tel = obs.Telemetry(quality=obs.QualityMonitor(probe_rate=1.0,
                                                   recon_every=0))
    q = tel.quality
    eng, out = run(tel)
    _, ref = run(None)
    assert out == ref                    # probes never alter served tokens
    assert q.probes > 0 and q.probe_tokens > 0
    assert eng.probe_retraces_after_warmup == 0
    assert eng.decode_retraces_after_warmup == 0

    snap = eng.snapshot()
    assert snap["schema_version"] == 7
    assert snap["quality_probes"] == q.probes
    assert snap["quality_agreement_mean"] == 1.0
    assert snap["quality_topk_overlap_mean"] >= 0.75
    assert snap["quality_recon_mean"] is None    # recon_every=0 disables

    # roofline counters: decode captured per rung at attach()
    assert ("decode", 0) in q.roofline and ("decode", 1) in q.roofline
    assert all(c["flops"] >= 0 and c["bytes"] >= 0
               for c in q.roofline.values())
    util = q.decode_utilization(1e-3)
    assert set(util) == {0, 1} and all(u >= 0 for u in util.values())
    assert q.decode_utilization(0.0) == {}


def test_sparse_rung_recon_baseline_and_exposition(model, ladder):
    """Probing at the sparse rung with injected calibration baselines:
    parity holds, the recon pass runs and reports the live-vs-baseline
    ratio, and the repro_quality_* families reach the exposition."""
    params, cfg = model
    L = cfg.num_layers
    with_base = dataclasses.replace(ladder, baselines={
        "recon": np.full((2, L), 1e-8),
        "channels": tuple(tuple(np.arange(4, dtype=np.int64)
                                for _ in range(L)) for _ in range(2))})
    prompts = _prompts(cfg, 2, 8, step=1)

    tel = obs.Telemetry(quality=obs.QualityMonitor(
        probe_rate=1.0, recon_every=1, recon_window=8, saliency_topk=4))
    q = tel.quality
    eng = _engine(params, cfg, ladder=with_base, telemetry=tel, rung=1)
    eng.warmup()
    for p in prompts:
        eng.submit(p, 6)
    out = eng.run()

    plain = _engine(params, cfg, ladder=ladder, rung=1)
    plain.warmup()
    for p in prompts:
        plain.submit(p, 6)
    assert out == plain.run()            # bit-identical probes-on vs off

    assert q.recon_passes > 0
    assert q.recon_baseline_mean(1) == pytest.approx(1e-8)
    snap = eng.snapshot()
    assert snap["quality_recon_mean"] is not None
    assert snap["quality_recon_vs_baseline"] > 0
    assert eng.probe_retraces_after_warmup == 0

    expo = eng.metrics_exposition()
    assert obs.validate_exposition(expo) > 0
    for family in ("repro_quality_probes_total",
                   "repro_quality_probe_agreement_rung1",
                   "repro_quality_recon_error_rung1",
                   "repro_quality_recon_baseline_rung1",
                   "repro_quality_roofline_flops_decode_rung1",
                   "repro_quality_pressure"):
        assert family in expo, f"{family} missing from exposition"


def test_forced_saliency_drift_event_attribution(model, ladder):
    """Re-baselining a block to channels live traffic never selects must
    fire exactly one attributed saliency_drift event (transition edge,
    not one per pass) and raise the pressure gauge."""
    params, cfg = model
    tel = obs.Telemetry(
        events=obs.EventLog(capacity=128),
        quality=obs.QualityMonitor(probe_rate=1.0, recon_every=1,
                                   recon_window=8, saliency_topk=8,
                                   drift_threshold=0.9, drift_alpha=1.0))
    q = tel.quality
    eng = _engine(params, cfg, ladder=ladder, telemetry=tel, rung=1)
    eng.warmup()
    eng.submit(_prompts(cfg, 1, 8, step=2)[0], 6)
    eng.run()
    assert q.recon_passes > 0
    # (the untrained model's window-to-window saliency jitter may trip
    # the tight 0.9 threshold on its own; the forced-drift assertions
    # below are relative to this baseline)
    n0 = q.drift_events
    ev0 = len(tel.events.events("saliency_drift"))

    live = q.saliency_ref[(1, 0)]
    disjoint = np.setdiff1d(np.arange(cfg.d_model), live)[:8]
    q.seed_reference(1, 0, disjoint)     # clears the key's EWMA + state
    eng.submit(_prompts(cfg, 1, 8, step=3)[0], 6)
    eng.run()

    assert q.drift_events > n0
    assert q.pressure > 0.0
    new = tel.events.events("saliency_drift")[ev0:]
    b0 = [e for e in new if e["block"] == 0]
    assert len(b0) == 1                  # edge-triggered, not per-pass
    assert b0[0]["rung"] == 1 and b0[0]["overlap"] < 0.9
    assert eng.snapshot()["quality_drift_events"] == q.drift_events


# ---------------------------------------------------------------------------
# ladder artifact v4
# ---------------------------------------------------------------------------

def test_ladder_v4_baselines_roundtrip_and_backcompat(model, ladder,
                                                      tmp_path):
    params, cfg = model
    L = cfg.num_layers
    recon = np.arange(2 * L, dtype=float).reshape(2, L) + 1e-6
    channels = tuple(tuple(np.arange(d, d + 4, dtype=np.int64)
                           for d in range(L)) for _ in range(2))
    lad = dataclasses.replace(ladder,
                              baselines={"recon": recon,
                                         "channels": channels})
    p = str(tmp_path / "ladder.npz")
    lad.save(p)
    l2 = PolicyLadder.load(p)
    assert np.allclose(l2.baselines["recon"], recon)
    for per_a, per_b in zip(channels, l2.baselines["channels"]):
        for a, b in zip(per_a, per_b):
            assert np.array_equal(a, b)

    # a ladder without baselines round-trips to None, still at v4
    plain = str(tmp_path / "plain.npz")
    ladder.save(plain)
    assert PolicyLadder.load(plain).baselines is None

    # pre-v4 back-compat: rewrite the meta at version 3 without quality
    z = np.load(p, allow_pickle=False)
    meta = json.loads(str(z["__meta__"]))
    meta["version"] = 3
    meta.pop("quality")
    arrays = {k: z[k] for k in z.files
              if k != "__meta__" and not k.startswith("qc")}
    with open(p, "wb") as f:
        np.savez(f, __meta__=np.array(json.dumps(meta)), **arrays)
    assert PolicyLadder.load(p).baselines is None


# ---------------------------------------------------------------------------
# quality-aware controller hint
# ---------------------------------------------------------------------------

def test_controller_quality_deescalation():
    slo = SLOConfig(tpot_p95=1.0, dwell=1, quality_aware=True)
    ctl = AdaptiveController(2, slo, initial_rung=1)
    rung = ctl.update([0.01], queue_depth=0, quality_pressure=0.5)
    assert rung == 0
    assert ctl.quality_deescalations == 1
    assert ctl.transitions[-1][3] == "quality"
    assert ctl.snapshot()["quality_deescalations"] == 1


def test_controller_quality_hint_never_overrides_slo():
    # a violated TPOT target escalates even under maximal drift pressure
    slo = SLOConfig(tpot_p95=0.001, dwell=1, quality_aware=True)
    ctl = AdaptiveController(3, slo, initial_rung=1)
    assert ctl.update([0.1], queue_depth=0, quality_pressure=1.0) == 2
    assert ctl.quality_deescalations == 0
    # queued work blocks the hint: de-escalating would slow the drain
    ctl2 = AdaptiveController(
        2, SLOConfig(tpot_p95=1.0, dwell=1, quality_aware=True),
        initial_rung=1)
    assert ctl2.update([0.01], queue_depth=3,
                       quality_pressure=1.0) == 1
    assert ctl2.quality_deescalations == 0
    # without quality_aware the pressure signal is ignored entirely
    ctl3 = AdaptiveController(2, SLOConfig(tpot_p95=1.0, dwell=1),
                              initial_rung=1)
    assert ctl3.update([0.9], queue_depth=0, quality_pressure=1.0) == 1
    assert ctl3.quality_deescalations == 0
    assert "quality_deescalations" not in ctl3.snapshot()
