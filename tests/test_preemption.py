"""Priority scheduling + KV preemption: scheduler admission order, WFQ,
bounded-queue backpressure, deadline expiry, suspend/resume bit-identity
(pinned rung / ladder / spec decoding), segment dtype round-trips, and
the priority-aware controller."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.serving import (Engine, EngineConfig, Priority, QueueFull,
                           Scheduler, SchedulerConfig, SlotKVPool, Status)
from repro.serving.controller import AdaptiveController, SLOConfig
from repro.serving.request import Request, RequestState
from repro.serving.spec import SpecConfig
from repro.sparsity import PolicyLadder


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _rs(rid, priority=Priority.STANDARD, tenant="default", prompt_len=8,
        max_new=8, arrival=0.0, deadline=None):
    return RequestState(Request(
        request_id=rid, prompt=np.zeros(prompt_len, np.int32),
        max_new_tokens=max_new, arrival_time=arrival, priority=priority,
        tenant=tenant, queue_deadline_s=deadline))


# ---------------------------------------------------------------------------
# scheduler unit tests (no model)
# ---------------------------------------------------------------------------

def test_strict_priority_across_classes():
    """Admission drains classes strictly: every interactive request
    before any standard one, standard before best-effort — regardless of
    enqueue order."""
    s = Scheduler()
    order = [Priority.BEST_EFFORT, Priority.INTERACTIVE, Priority.STANDARD,
             Priority.INTERACTIVE, Priority.BEST_EFFORT]
    for i, p in enumerate(order):
        s.enqueue(_rs(i, p))
    popped = [s.pop_admit().request.priority for _ in range(len(order))]
    assert popped == sorted(order)


def test_default_config_is_fifo():
    """Single class, single tenant: exactly the old FIFO order."""
    s = Scheduler()
    for i in range(5):
        s.enqueue(_rs(i))
    assert [s.pop_admit().request.request_id for _ in range(5)] \
        == [0, 1, 2, 3, 4]


def test_wfq_weights_share_admissions():
    """Within a class, a weight-2 tenant is served ~2x as often as a
    weight-1 tenant under contention (virtual-start-time fair queuing
    with cost = request tokens / weight)."""
    cfg = SchedulerConfig(tenant_weights=(("heavy", 2.0), ("light", 1.0)))
    s = Scheduler(cfg)
    rid = 0
    for _ in range(8):
        for tenant in ("heavy", "light"):
            s.enqueue(_rs(rid, tenant=tenant))
            rid += 1
    first6 = [s.pop_admit().request.tenant for _ in range(6)]
    assert first6.count("heavy") == 4 and first6.count("light") == 2


def test_bounded_queue_raises_queue_full():
    s = Scheduler(SchedulerConfig(max_queue=2))
    s.enqueue(_rs(0))
    s.enqueue(_rs(1))
    assert not s.can_accept()
    with pytest.raises(QueueFull):
        s.enqueue(_rs(2))
    s.pop_admit()
    assert s.can_accept()


def test_expire_sweeps_overdue_requests():
    s = Scheduler()
    s.enqueue(_rs(0, arrival=0.0, deadline=1.0))
    s.enqueue(_rs(1, arrival=0.0, deadline=10.0))
    s.enqueue(_rs(2, arrival=5.0, deadline=1.0))
    expired = s.expire(now=4.0)
    assert {rs.request.request_id for rs in expired} == {0}
    assert s.queue_depth == 2


def test_pick_victim_least_important_youngest():
    """The victim is the least important decoding request, youngest
    first within a class — and never one at (or above) the arrival's
    own class."""
    s = Scheduler(SchedulerConfig(preemption=True))
    for rid, (p, t) in enumerate([(Priority.STANDARD, 0.0),
                                  (Priority.BEST_EFFORT, 1.0),
                                  (Priority.BEST_EFFORT, 2.0)]):
        rs = _rs(rid, p, arrival=t)
        rs.slot = rid
        rs.status = Status.DECODE
        s.decoding[rid] = rs
    v = s.pick_victim(Priority.INTERACTIVE)
    assert v.request.request_id == 2          # best-effort, youngest
    assert s.pick_victim(Priority.BEST_EFFORT) is None   # no lower class
    s.suspend(v)
    assert v.status is Status.SUSPENDED
    assert s.pick_victim(Priority.INTERACTIVE).request.request_id == 1


def test_resume_outranks_by_class_then_suspend_order():
    s = Scheduler(SchedulerConfig(preemption=True))
    for rid, p in enumerate([Priority.BEST_EFFORT, Priority.STANDARD,
                             Priority.BEST_EFFORT]):
        rs = _rs(rid, p)
        rs.slot = rid
        rs.status = Status.DECODE
        s.decoding[rid] = rs
        s.suspend(rs)
    assert s.pop_resume().request.request_id == 1   # standard first
    assert s.pop_resume().request.request_id == 0   # then suspend order
    assert s.pop_resume().request.request_id == 2


# ---------------------------------------------------------------------------
# preempt -> resume bit-identity (the tentpole guarantee)
# ---------------------------------------------------------------------------

def _reference(params, cfg, prompts, gens, ladder=None, spec=None,
               initial_rung=0):
    """Uncontended run: every request gets a slot, nothing preempts."""
    eng = Engine(params, cfg, EngineConfig(
        max_slots=len(prompts), max_len=32, prefill_chunk=8,
        initial_rung=initial_rung, spec=spec), None, ladder=ladder)
    for b, g in enumerate(gens):
        eng.submit(prompts[b], g)
    return eng.run()


def _preempted(params, cfg, prompts, gens, ladder=None, spec=None,
               initial_rung=0):
    """Contended run on a 2-slot pool: two best-effort requests fill the
    pool, then an interactive arrival forces a preemption.  Returns
    (tokens-by-id, engine)."""
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8,
        initial_rung=initial_rung, spec=spec,
        scheduler=SchedulerConfig(preemption=True)), None, ladder=ladder)
    eng.submit(prompts[0], gens[0], priority="best-effort", tenant="batch")
    eng.submit(prompts[1], gens[1], priority="best-effort", tenant="batch")
    # run until both victims are decoding with tokens in flight, so the
    # suspension happens mid-generation, not at a boundary
    for _ in range(64):
        eng.step()
        if (len(eng.scheduler.decoding) == 2
                and all(len(rs.tokens) >= 2
                        for rs in eng.scheduler.decoding.values())):
            break
    else:
        pytest.fail("bulk requests never reached steady decode")
    eng.submit(prompts[2], gens[2], priority=Priority.INTERACTIVE,
               tenant="chat")
    out = eng.run()
    assert eng.stats.preemptions >= 1, "no preemption on a full pool"
    assert eng.stats.resumes == eng.stats.preemptions
    return out, eng


def _assert_preempt_parity(params, cfg, **kw):
    prompts = _prompts(cfg, 3, 12, step=5)
    # bulk generations long enough that both victims are still decoding
    # when the interactive arrival lands, even under multi-token spec
    # steps (12 prompt + 16 gen fits max_len 32)
    gens = [16, 16, 4]
    ref = _reference(params, cfg, prompts, gens, **kw)
    out, eng = _preempted(params, cfg, prompts, gens, **kw)
    for rid in range(3):
        assert out[rid] == ref[rid], \
            f"request {rid} diverged after preemption"
    preempted = [rs for rs in eng.states.values() if rs.preemptions > 0]
    assert preempted, "no request records a preemption"
    assert eng.decode_retraces_after_warmup == 0
    assert eng.segment_retraces_after_warmup == 0


def test_preempt_resume_bit_identity_pinned(model):
    """Dense fixed-policy engine: a preempted-then-resumed request
    finishes with exactly the tokens of its uncontended run."""
    params, cfg = model
    _assert_preempt_parity(params, cfg)


def test_preempt_resume_bit_identity_ladder(model):
    """Same guarantee pinned at a sparse rung of a ladder.  The mask
    backend is per-token deterministic, so changed batch composition
    after the preemption cannot excuse a diff."""
    params, cfg = model
    ladder = PolicyLadder.uniform(params, cfg, (0.0, 0.5), backend="mask")
    _assert_preempt_parity(params, cfg, ladder=ladder, initial_rung=1)


def test_preempt_resume_bit_identity_spec(model):
    """Same guarantee under speculative decoding: the dense verifier
    pins the output tokens no matter how suspension perturbs the
    drafter's accept pattern."""
    params, cfg = model
    ladder = PolicyLadder.uniform(params, cfg, (0.0, 0.5))
    _assert_preempt_parity(params, cfg, ladder=ladder,
                           spec=SpecConfig(gamma=2, drafter_rung=1))


def test_suspend_at_uncommitted_boundary_rejected(model):
    """_preempt refuses to suspend a slot whose pool length disagrees
    with the request's committed position — the corruption guard."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 12)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=32, prefill_chunk=8,
        scheduler=SchedulerConfig(preemption=True)), None)
    eng.submit(prompts[0], 6, priority="best-effort")
    for _ in range(32):
        eng.step()
        if eng.scheduler.decoding:
            break
    victim = next(iter(eng.scheduler.decoding.values()))
    eng.pool.lengths[victim.slot] += 1        # simulate a torn commit
    with pytest.raises(RuntimeError, match="committed boundary"):
        eng._preempt(victim)


# ---------------------------------------------------------------------------
# segment dtype preservation (suspend/resume and prefix share the path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
def test_suspend_resume_roundtrip_bit_exact(dtype):
    """suspend() -> resume() restores the live prefix bit-exactly and
    preserves every leaf's dtype, bf16 and fp32."""
    cfg = dataclasses.replace(reduced(get_config("llama31_8b")),
                              dtype=dtype)
    pool = SlotKVPool(cfg, max_slots=2, max_len=16)
    rng = np.random.default_rng(0)
    pool.caches = jax.tree_util.tree_map(
        lambda leaf: jnp.asarray(
            rng.standard_normal(leaf.shape), leaf.dtype), pool.caches)
    src = pool.alloc()
    pool.lengths[src] = 11                    # not a quantum multiple
    seg = pool.suspend(src, quantum=4)
    assert seg.length == 11 and seg.phys == 12
    for leaf in jax.tree_util.tree_leaves(seg.caches):
        assert leaf.dtype == jnp.dtype(dtype)

    before = jax.tree_util.tree_map(np.asarray, pool.caches)
    dst = pool.alloc()
    pool.resume(seg, dst)
    assert pool.lengths[dst] == 11
    after = jax.tree_util.tree_map(np.asarray, pool.caches)
    for b, a, axes in zip(jax.tree_util.tree_leaves(before),
                          jax.tree_util.tree_leaves(after),
                          pool._flat_axes):
        bdim, tdim = axes.index("batch"), axes.index("kv_seq")
        got = np.take(np.take(a, dst, bdim), range(12), tdim - 1)
        want = np.take(np.take(b, src, bdim), range(12), tdim - 1)
        assert got.dtype == want.dtype == np.asarray(
            jnp.zeros((), jnp.dtype(cfg.dtype))).dtype
        assert np.array_equal(got, want), "segment round-trip not bit-exact"


def test_mixed_dtype_leaves_roundtrip():
    """A cache tree with both bf16 and fp32 leaves round-trips through
    extract_prefix/write_prefix with every leaf's dtype intact."""
    cfg = reduced(get_config("llama31_8b"))
    pool = SlotKVPool(cfg, max_slots=2, max_len=16)
    rng = np.random.default_rng(1)
    flip = [False]

    def fill(leaf):
        flip[0] = not flip[0]
        dt = jnp.bfloat16 if flip[0] else jnp.float32
        return jnp.asarray(rng.standard_normal(leaf.shape), dt)

    pool.caches = jax.tree_util.tree_map(fill, pool.caches)
    dtypes = [leaf.dtype
              for leaf in jax.tree_util.tree_leaves(pool.caches)]
    assert len(set(dtypes)) == 2              # genuinely mixed
    src = pool.alloc()
    pool.lengths[src] = 8
    seg = pool.suspend(src, quantum=8)
    seg_dtypes = [leaf.dtype
                  for leaf in jax.tree_util.tree_leaves(seg.caches)]
    assert seg_dtypes == dtypes
    dst = pool.alloc()
    pool.resume(seg, dst)
    for leaf, axes, dt in zip(
            jax.tree_util.tree_leaves(pool.caches), pool._flat_axes,
            dtypes):
        assert leaf.dtype == dt
        bdim, tdim = axes.index("batch"), axes.index("kv_seq")
        got = np.take(np.take(np.asarray(leaf), dst, bdim),
                      range(8), tdim - 1)
        want = np.take(np.take(np.asarray(leaf), src, bdim),
                       range(8), tdim - 1)
        assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# engine-level admission control
# ---------------------------------------------------------------------------

def test_engine_queue_full_and_deadline(model):
    """A full admission queue raises QueueFull with a retry estimate;
    a queued request whose deadline passes finishes EXPIRED without
    touching a slot."""
    params, cfg = model
    prompts = _prompts(cfg, 4, 8)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=24, prefill_chunk=8,
        scheduler=SchedulerConfig(max_queue=1)), None)
    eng.submit(prompts[0], 4)
    eng.step()                                # admit into the only slot
    eng.submit(prompts[1], 4)                 # fills the queue
    with pytest.raises(QueueFull) as exc:
        eng.submit(prompts[2], 4)
    assert exc.value.retry_after >= 1.0
    assert eng.stats.rejected == 1

    while eng.scheduler.queue_depth:          # drain until there's room
        eng.step()
    expired = eng.submit(prompts[3], 4, queue_deadline_s=1e-9,
                         priority="best-effort")
    out = eng.run()
    assert expired.finish_reason is not None
    assert expired.finish_reason.value == "expired"
    assert expired.tokens == []
    assert eng.stats.expired == 1
    assert out[0] is not None and len(out[1]) == 4


def test_controller_priority_aware_holds_escalation():
    """priority_aware: a TPOT violation with no best-effort traffic in
    the decode batch holds the rung (counted), but escalates as soon as
    best-effort requests are present or the queue backs up."""
    slo = SLOConfig(tpot_p95=0.01, max_queue=4, dwell=1,
                    priority_aware=True)
    ctl = AdaptiveController(num_rungs=3, slo=slo)
    over = [0.05] * 4                         # way over target
    rung = ctl.update(over, queue_depth=0, best_effort_frac=0.0)
    assert rung == 0 and ctl.held_escalations == 1
    rung = ctl.update(over, queue_depth=0, best_effort_frac=0.5)
    assert rung == 1                          # best-effort present: act
    rung = ctl.update(over, queue_depth=10, best_effort_frac=0.0)
    assert rung == 2                          # queue pressure still acts
    assert ctl.snapshot()["held_escalations"] == 1

    plain = AdaptiveController(
        num_rungs=3, slo=SLOConfig(tpot_p95=0.01, dwell=1))
    assert plain.update(over, queue_depth=0) == 1   # default: escalate
