"""Roofline machinery: the trip-count-aware HLO analyzer vs XLA's own
cost_analysis, collective parsing, and model-FLOPs accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis as H
from repro.launch import roofline as R


def _cost(compiled) -> dict:
    """compiled.cost_analysis() returns a per-device list on older jax."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_analyzer_matches_cost_analysis_unrolled():
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)

    def g(x, ws):
        y = x
        for i in range(4):
            y = y @ ws[i]
        return y

    c = jax.jit(g).lower(x, ws).compile()
    a = H.analyze(c.as_text())
    expected = 2 * 64 * 256 * 256 * 4
    assert a["flops"] == expected
    # XLA agrees on scan-free modules (upto convert/noise ops)
    assert abs(a["flops"] - _cost(c)["flops"]) / expected < 0.2


def test_analyzer_scales_scan_by_trip_count():
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(f).lower(x, ws).compile()
    a = H.analyze(c.as_text())
    expected = 2 * 64 * 256 * 256 * 12
    assert a["flops"] == expected
    # ...which is what cost_analysis misses (counts the body once)
    assert _cost(c)["flops"] < expected / 6


def test_collective_regex():
    line = ("%all-gather.3 = f32[8,192]{0,1} all-gather(%x), channel_id=1, "
            "replica_groups=[128,2]<=[16,8,2]T(1,0,2)")
    out = R.collective_bytes(line)
    assert out["all-gather"] == 8 * 192 * 4


def test_wire_bytes_allreduce_double():
    assert R.wire_bytes({"all-reduce": 100, "all-gather": 50,
                         "reduce-scatter": 0, "all-to-all": 0,
                         "collective-permute": 0}) == 250


def test_model_flops_moe_uses_active_params():
    dense = get_config("deepseek_67b")
    moe = get_config("granite_moe_1b_a400m")
    n_moe = R.active_matmul_params(moe)
    # granite-1b: active ~= attn + 8/32 of expert params
    total_expert = moe.num_layers * moe.num_experts * 3 * \
        moe.d_model * moe.expert_d_ff
    active_expert = total_expert * moe.num_experts_per_tok / moe.num_experts
    assert n_moe < total_expert            # sanity: activity discount applied
    attn = moe.num_layers * (2 * moe.d_model * moe.num_heads * moe.head_dim
                             + 2 * moe.d_model * moe.num_kv_heads * moe.head_dim)
    expect = attn + active_expert + moe.num_layers * moe.d_model * moe.num_experts \
        + moe.vocab_size * moe.d_model
    assert abs(n_moe - expect) / expect < 0.05
    # dense: ~67B plus head
    n_dense = R.active_matmul_params(dense)
    assert 6.0e10 < n_dense < 7.5e10


def test_roofline_terms_and_bottleneck():
    rl = R.Roofline("a", "s", "single", 256, hlo_flops=197e12,
                    hlo_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                    model_flops_total=197e12 * 256 * 0.5)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 2.0) < 1e-9
    assert abs(rl.collective_s - 0.5) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.mfu - 0.25) < 1e-9


@pytest.mark.parametrize("shape,expected_factor", [
    ("train_4k", 6.0), ("prefill_32k", 2.0)])
def test_model_flops_mode_factor(shape, expected_factor):
    cfg = get_config("llama31_8b")
    s = SHAPES[shape]
    n = R.active_matmul_params(cfg)
    assert R.model_flops(cfg, s) == pytest.approx(
        expected_factor * n * s.global_batch * s.seq_len)
