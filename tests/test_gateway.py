"""Gateway HTTP/SSE front door, engine lifecycle (close / reset_ids /
context manager), and serve-CLI flag validation."""
import http.client
import json
import socket
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import build_parser, validate_args, validate_rungs
from repro.models import api
from repro.serving import Engine, EngineConfig, SchedulerConfig
from repro.serving.gateway import Gateway


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _engine(params, cfg, **kw):
    defaults = dict(max_slots=2, max_len=32, prefill_chunk=8)
    defaults.update(kw)
    return Engine(params, cfg, EngineConfig(**defaults), None)


@pytest.fixture(scope="module")
def gateway(model):
    params, cfg = model
    eng = _engine(params, cfg,
                  scheduler=SchedulerConfig(max_queue=8, preemption=True))
    gw = Gateway(eng, port=0)
    port = gw.start()
    yield gw, eng, port
    gw.stop()


def _request(port, method, path, body=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request(method, path,
                     body=json.dumps(body) if body is not None else None,
                     headers={"Content-Type": "application/json"}
                     if body is not None else {})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# HTTP endpoints
# ---------------------------------------------------------------------------

def test_health(gateway):
    _, _, port = gateway
    status, _, body = _request(port, "GET", "/v1/health")
    assert status == 200
    health = json.loads(body)
    assert health["status"] == "ok"
    assert {"queue_depth", "occupancy", "suspended", "rung"} <= set(health)


def test_generate_non_streaming(gateway, model):
    _, eng, port = gateway
    _, cfg = model
    prompt = [int(t) for t in _prompts(cfg, 1, 10)[0]]
    status, _, body = _request(port, "POST", "/v1/generate", {
        "prompt": prompt, "max_new_tokens": 5, "priority": "interactive"})
    assert status == 200
    out = json.loads(body)
    assert len(out["tokens"]) == 5
    assert out["finish_reason"] == "max_tokens"
    assert out["usage"] == {"prompt_tokens": 10, "completion_tokens": 5}


def test_generate_streaming_sse_framing(gateway, model):
    """Raw-socket SSE request: chunked transfer framing, one event per
    token, a done event carrying usage, then the [DONE] sentinel."""
    _, _, port = gateway
    _, cfg = model
    prompt = [int(t) for t in _prompts(cfg, 1, 8, step=3)[0]]
    payload = json.dumps({"prompt": prompt, "max_new_tokens": 3,
                          "stream": True}).encode()
    req = (b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
           b"Content-Type: application/json\r\n"
           b"Content-Length: " + str(len(payload)).encode()
           + b"\r\n\r\n" + payload)
    with socket.create_connection(("127.0.0.1", port), timeout=30) as s:
        s.sendall(req)
        raw = b""
        while b"0\r\n\r\n" not in raw:
            chunk = s.recv(65536)
            if not chunk:
                break
            raw += chunk
    head, _, rest = raw.partition(b"\r\n\r\n")
    assert b"HTTP/1.1 200" in head
    assert b"Transfer-Encoding: chunked" in head
    assert b"Content-Type: text/event-stream" in head
    # de-chunk
    body, buf = b"", rest
    while buf:
        size, _, buf = buf.partition(b"\r\n")
        n = int(size, 16)
        if n == 0:
            break
        body += buf[:n]
        buf = buf[n + 2:]
    events = [e for e in body.decode().split("\n\n") if e.strip()]
    assert events[-1] == "data: [DONE]"
    parsed = [json.loads(e[len("data: "):]) for e in events[:-1]]
    tokens = [e for e in parsed if "token" in e]
    assert [e["index"] for e in tokens] == [0, 1, 2]
    done = parsed[-1]
    assert done["done"] is True
    assert done["usage"]["completion_tokens"] == 3


def test_metrics_exposition_validates(gateway):
    _, _, port = gateway
    status, headers, body = _request(port, "GET", "/metrics")
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain")
    text = body.decode()
    assert obs.validate_exposition(text) > 0
    # the admission/preemption families are exported when a
    # SchedulerConfig is armed
    for name in ("repro_preemptions_total", "repro_queue_wait_seconds",
                 "repro_suspended_requests"):
        assert name in text


def test_debug_flight_404_without_recorder(gateway):
    _, _, port = gateway
    status, _, body = _request(port, "GET", "/v1/debug/flight")
    assert status == 404
    assert "flight-record" in json.loads(body)["error"]


def test_debug_flight_serves_ring_and_dumps(model, tmp_path):
    """With a recorder armed the endpoint returns the ring snapshot and
    triggers an http-reason black-box dump on every hit."""
    from repro.obs import Telemetry
    from repro.obs.flight import FLIGHT_SCHEMA_VERSION, FlightRecorder

    params, cfg = model
    fr = FlightRecorder(dump_dir=str(tmp_path / "dumps"))
    eng = Engine(params, cfg,
                 EngineConfig(max_slots=2, max_len=32, prefill_chunk=8),
                 None, telemetry=Telemetry(flight=fr))
    gw = Gateway(eng, port=0)
    port = gw.start()
    try:
        status, _, body = _request(port, "POST", "/v1/generate", {
            "prompt": _prompts(cfg, 1, 8)[0].tolist(),
            "max_new_tokens": 4})
        assert status == 200
        status, _, body = _request(port, "GET", "/v1/debug/flight")
        assert status == 200
        snap = json.loads(body)
        assert snap["flight_schema_version"] == FLIGHT_SCHEMA_VERSION
        assert snap["count"] > 0 and snap["complete"]
        kinds = {r["k"] for r in snap["records"]}
        assert {"header", "submit", "clock", "finish"} <= kinds
        assert snap["dump_path"].endswith("flight-http-0.jsonl")
        assert (tmp_path / "dumps" / "flight-http-0.jsonl").exists()
    finally:
        gw.stop()


def test_concurrent_metrics_scrapes_under_decode(gateway, model):
    """GET /metrics from several threads while a generation is decoding:
    every scrape returns a valid exposition and the generation finishes
    untouched (the registry renders from live engine state, so scrapes
    must tolerate the state mutating mid-decode)."""
    _, _, port = gateway
    _, cfg = model
    prompt = [int(t) for t in _prompts(cfg, 1, 10, step=7)[0]]
    samples, gen_out, errors = [], [], []

    def scrape():
        try:
            status, _, body = _request(port, "GET", "/metrics")
            assert status == 200
            samples.append(obs.validate_exposition(body.decode()))
        except Exception as e:        # surface in the main thread
            errors.append(e)

    def generate():
        try:
            status, _, body = _request(port, "POST", "/v1/generate",
                                       {"prompt": prompt,
                                        "max_new_tokens": 16})
            assert status == 200
            gen_out.append(json.loads(body)["tokens"])
        except Exception as e:
            errors.append(e)

    g = threading.Thread(target=generate)
    g.start()
    scrapers = [threading.Thread(target=scrape) for _ in range(6)]
    for s in scrapers:
        s.start()
        time.sleep(0.01)     # spread the scrapes across the decode window
    for s in scrapers:
        s.join(timeout=60)
    g.join(timeout=120)
    assert not errors, errors
    assert len(samples) == 6 and all(n > 0 for n in samples)
    assert len(gen_out) == 1 and len(gen_out[0]) == 16


def test_validation_errors_are_400(gateway):
    _, _, port = gateway
    for bad in ({}, {"prompt": []}, {"prompt": [1.5]},
                {"prompt": [1], "max_new_tokens": 0},
                {"prompt": [1], "priority": "vip"}):
        status, _, body = _request(port, "POST", "/v1/generate", bad)
        assert status == 400, f"payload {bad} not rejected"
        assert "error" in json.loads(body)
    status, _, _ = _request(port, "GET", "/nope")
    assert status == 404


def test_drain_closes_engine(model):
    """stop() drains in-flight work, shuts the listener, and closes the
    engine (telemetry flushed)."""
    params, cfg = model
    eng = _engine(params, cfg)
    gw = Gateway(eng, port=0)
    port = gw.start()
    prompt = [int(t) for t in _prompts(cfg, 1, 8)[0]]
    status, _, _ = _request(port, "POST", "/v1/generate",
                            {"prompt": prompt, "max_new_tokens": 2})
    assert status == 200
    gw.stop()
    assert eng._closed
    with pytest.raises(ConnectionRefusedError):
        socket.create_connection(("127.0.0.1", port), timeout=2)


# ---------------------------------------------------------------------------
# engine lifecycle
# ---------------------------------------------------------------------------

def test_close_flushes_trace_sink_and_is_idempotent(model, tmp_path):
    params, cfg = model
    sink = str(tmp_path / "trace.json")
    tel = obs.Telemetry(tracer=obs.SpanTracer(), trace_sink=sink)
    with Engine(params, cfg,
                EngineConfig(max_slots=2, max_len=32, prefill_chunk=8),
                None, telemetry=tel) as eng:
        eng.submit(_prompts(cfg, 1, 8)[0], 3)
        eng.run()
    with open(sink) as f:
        assert obs.validate_chrome_trace(json.load(f)) > 0
    eng.close()                               # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(_prompts(cfg, 1, 8)[0], 3)


def test_close_flushes_on_exception(model, tmp_path):
    params, cfg = model
    sink = str(tmp_path / "trace.json")
    tel = obs.Telemetry(tracer=obs.SpanTracer(), trace_sink=sink)
    with pytest.raises(RuntimeError, match="boom"), \
            Engine(params, cfg,
                   EngineConfig(max_slots=2, max_len=32, prefill_chunk=8),
                   None, telemetry=tel) as eng:
        eng.submit(_prompts(cfg, 1, 8)[0], 3)
        eng.run()
        raise RuntimeError("boom")
    with open(sink) as f:
        json.load(f)                          # exported despite the raise


def test_reset_ids_gives_fresh_namespace(model):
    """reset_ids() restarts request ids at 0 (per-rep benchmark replays
    key cross-engine parity on the id); busy engines refuse."""
    params, cfg = model
    eng = _engine(params, cfg)
    prompts = _prompts(cfg, 2, 8)
    first = eng.submit(prompts[0], 2)
    assert first.request.request_id == 0
    with pytest.raises(RuntimeError, match="busy engine"):
        eng.reset_ids()
    eng.run()
    eng.reset_ids()
    again = eng.submit(prompts[1], 2)
    assert again.request.request_id == 0
    eng.run()


# ---------------------------------------------------------------------------
# serve CLI validation (build_parser + validate_args, no process spawn)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("argv,msg", [
    (["--spec-gamma", "2"], "needs --ladder"),
    (["--spec-adaptive"], "--spec-gamma"),
    (["--ladder", "x.npz", "--spec-gamma", "2", "--slo-tpot-p95", "0.1"],
     "conflicts"),
    (["--rung", "3"], "needs --ladder"),
    (["--sparsity", "1.5"], "sparsity"),
    (["--gen", "0"], "--gen"),
    (["--max-queue", "-1"], "--max-queue"),
    (["--gateway", "--legacy"], "engine path"),
    (["--gateway", "--metrics-out", "m.jsonl"], "owns the engine loop"),
    (["--gateway", "--metrics-port", "9090"], "already serves /metrics"),
    (["--gateway-port", "9999"], "need --gateway"),
    (["--preemption", "--legacy"], "engine path"),
    (["--quality-probe-rate", "1.5"], "quality-probe-rate"),
    (["--quality-probe-rate", "-0.1"], "quality-probe-rate"),
    (["--quality-probe-rate", "0.5", "--legacy"], "engine path"),
    (["--quality-drift-threshold", "0.3"], "quality-probe-rate > 0"),
    (["--quality-probe-rate", "0.5", "--quality-drift-threshold", "1.0"],
     "quality-drift-threshold must be in"),
    (["--quality-probe-rate", "0.5", "--quality-drift-threshold", "0.0"],
     "quality-drift-threshold must be in"),
    (["--flight-record", "f.jsonl", "--flight-ring", "0"],
     "--flight-ring must be > 0"),
    (["--flight-record", "f.jsonl", "--flight-ring", "-8"],
     "--flight-ring must be > 0"),
    (["--flight-ring", "1024"], "needs --flight-record"),
    (["--flight-dump-dir", "/tmp"], "needs --flight-record"),
    (["--flight-record", "f.jsonl", "--legacy"], "engine path"),
])
def test_serve_cli_rejects_bad_flags(argv, msg):
    args = build_parser().parse_args(argv)
    with pytest.raises(SystemExit, match=msg):
        validate_args(args)


def test_serve_cli_flight_dump_dir_must_be_writable_dir(tmp_path):
    not_dir = tmp_path / "plainfile"
    not_dir.write_text("x")
    args = build_parser().parse_args(
        ["--flight-record", "f.jsonl", "--flight-dump-dir", str(not_dir)])
    with pytest.raises(SystemExit, match="not a directory"):
        validate_args(args)


def test_serve_cli_accepts_good_flags(tmp_path):
    for argv in ([], ["--gateway", "--max-queue", "8", "--preemption"],
                 ["--ladder", "x.npz", "--rung", "1"],
                 ["--ladder", "x.npz", "--spec-gamma", "2",
                  "--spec-drafter", "1"],
                 ["--quality-probe-rate", "0.25"],
                 ["--quality-probe-rate", "1.0",
                  "--quality-drift-threshold", "0.3"],
                 ["--flight-record"],          # bounded ring, no sink
                 ["--flight-record", "f.jsonl", "--flight-ring", "1024",
                  "--flight-dump-dir", str(tmp_path)],
                 ["--gateway", "--flight-record", "f.jsonl"]):
        validate_args(build_parser().parse_args(argv))


def test_serve_cli_rung_range_checked_against_ladder():
    args = build_parser().parse_args(["--ladder", "x.npz", "--rung", "3"])
    with pytest.raises(SystemExit, match="out of range"):
        validate_rungs(args, num_rungs=2)
    args = build_parser().parse_args(
        ["--ladder", "x.npz", "--spec-gamma", "2", "--spec-drafter", "5"])
    with pytest.raises(SystemExit, match="spec-drafter 5 out of range"):
        validate_rungs(args, num_rungs=2)
    validate_rungs(build_parser().parse_args(
        ["--ladder", "x.npz", "--rung", "1"]), num_rungs=2)
