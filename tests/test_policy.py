"""SparsityPolicy: eager validation, phase derivation, per-role/per-block
backend resolution, the self-contained save/load artifact, policy
isolation across interleaved/threaded engines, and bit-exact parity of
the explicit-policy path against the deprecated thread-local shims."""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core import sparse_linear as sl
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.models import api, model as M
from repro.serving import Engine, EngineConfig
from repro.sparsity import PHASES, VALID_BACKENDS, SparsityPolicy


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


# ---------------------------------------------------------------------------
# construction-time validation (fail fast, not inside a jit trace)
# ---------------------------------------------------------------------------

def test_policy_validates_backends_eagerly():
    with pytest.raises(ValueError, match="topk_sharedd.*valid backends"):
        SparsityPolicy(backend="topk_sharedd")
    with pytest.raises(ValueError, match="valid backends"):
        SparsityPolicy(role_backends=(("attn/wq", "maskk"),))
    with pytest.raises(ValueError, match="valid backends"):
        SparsityPolicy(block_backends=((0, 2, "nope"),))
    with pytest.raises(ValueError, match="start < end"):
        SparsityPolicy(block_backends=((2, 2, "mask"),))
    with pytest.raises(ValueError, match="k_max_frac"):
        SparsityPolicy(k_max_frac=0.0)
    with pytest.raises(ValueError, match="valid phases"):
        SparsityPolicy(dense_phases=("warmup",))


def test_engine_config_validates_eagerly(model):
    import dataclasses
    with pytest.raises(TypeError):
        EngineConfig(policy="mask")     # mode strings are gone
    # the removed deprecated knobs are really gone (not silently ignored)
    with pytest.raises(TypeError):
        EngineConfig(mode="topk_shared")
    with pytest.raises(TypeError):
        EngineConfig(k_max_frac=0.5)
    # no policy = dense execution
    assert EngineConfig().policy == SparsityPolicy.dense()
    # dataclasses.replace keeps working on constructed configs
    base = EngineConfig(policy=SparsityPolicy.uniform("mask"))
    e2 = dataclasses.replace(base, max_len=1024)
    assert e2.policy == base.policy and e2.max_len == 1024
    # slo without a ladder is rejected at Engine construction
    from repro.serving import SLOConfig
    params, cfg = model
    with pytest.raises(ValueError, match="needs a PolicyLadder"):
        Engine(params, cfg, EngineConfig(slo=SLOConfig(tpot_p95=0.1)))


def test_thread_local_shims_removed():
    """The one-release deprecation shims are gone: execution state is
    explicit-only now."""
    from repro.core import sparse_linear as sl2
    for name in ("sparsity_mode", "capture_inputs", "token_weights",
                 "current_mode", "current_token_weights", "record",
                 "SparsityMode", "resolve_execution"):
        assert not hasattr(sl2, name), name


def test_backend_resolution_precedence():
    pol = SparsityPolicy(
        backend="topk_shared",
        role_backends=(("mlp/wo", "mask"), ("wq", "off")),
        block_backends=((0, 2, "pallas"),))
    # role beats depth beats default; leaf-name entries match any scope
    assert pol.backend_at(depth=0, role="mlp/wo") == "mask"
    assert pol.backend_at(depth=5, role="attn/wq") == "off"
    assert pol.backend_at(depth=1, role="attn/wk") == "pallas"
    assert pol.backend_at(depth=5, role="attn/wk") == "topk_shared"
    # depth-resolved per-layer policies keep role overrides
    lp = pol.resolve_depth(1)
    assert lp.backend == "pallas" and lp.block_backends == ()
    assert lp.backend_at(role="mlp/wo") == "mask"


def test_for_phase_is_stable_for_jit_caching():
    pol = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5)
    for ph in PHASES:
        assert pol.for_phase(ph) == pol.for_phase(ph)
        assert hash(pol.for_phase(ph)) == hash(pol.for_phase(ph))
    assert pol.for_phase("prefill_dense").is_dense
    assert pol.for_phase("decode") == pol
    with pytest.raises(ValueError, match="valid phases"):
        pol.for_phase("warmup")
    # every backend is constructible + phase-derivable
    for b in VALID_BACKENDS:
        SparsityPolicy.uniform(b).for_phase("decode")


# ---------------------------------------------------------------------------
# explicit-policy defaults
# ---------------------------------------------------------------------------

def test_policy_none_is_dense_bitwise(model):
    """With the thread-local contexts removed, policy=None must be exactly
    dense execution (no ambient state left to consult)."""
    params, cfg = model
    toks = jnp.asarray(_prompts(cfg, 2, 16))
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    ref, _ = M.forward(params, cfg, tokens=toks, mode="train", sp=sp,
                       policy=SparsityPolicy.dense())
    new, _ = M.forward(params, cfg, tokens=toks, mode="train", sp=sp)
    assert (np.asarray(ref) == np.asarray(new)).all()
    # and at the single-projection level too
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (16, 8)))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (4, 16)))
    spd = sl.default_sp(w)
    y = sl.project(jnp.asarray(x), jnp.asarray(w), spd)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=1e-5, atol=1e-5)


def test_mixed_block_policy_matches_per_depth_reference(model):
    """Per-block mixed backends through the scanned model equal the
    unstacked per-depth reference (dense blocks = sp dropped)."""
    from repro.core import unstacked as U
    params, cfg = model
    toks = jnp.asarray(_prompts(cfg, 2, 16, step=5))
    L = cfg.num_layers
    assert L >= 2
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    mixed = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5,
                                   block_backends=((0, L // 2, "off"),))
    got, _ = M.forward(params, cfg, tokens=toks, mode="train", sp=sp,
                       policy=mixed)
    # reference: python-loop model, sp=None on the dense blocks
    layers = U.unstack_layers(cfg, params)
    per_depth = []
    for dl in layers:
        if dl.depth < L // 2:
            per_depth.append(None)
        else:
            per_depth.append(jax.tree_util.tree_map(
                lambda a, r=dl.rep: a[r], sp[dl.group][f"l{dl.pos}"]))
    ref, _ = U.forward_unstacked(
        params, cfg, toks, per_depth_sp=per_depth,
        policy=SparsityPolicy.uniform("topk_shared", k_max_frac=0.5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# policy isolation: the regression the thread-local removal fixes
# ---------------------------------------------------------------------------

def _run_alone(params, cfg, policy, sp, prompts, gen=5):
    eng = Engine(params, cfg, EngineConfig(max_slots=2, max_len=32,
                                           prefill_chunk=8, policy=policy),
                 sp)
    for b in range(2):
        eng.submit(prompts[b], gen)
    return eng.run(), eng


def test_policy_isolation_interleaved_and_threaded(model):
    """Two engines with different policies — interleaved step-by-step and
    on separate threads — produce bit-identical tokens to each engine run
    alone."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 12, step=23)
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    pol_a = SparsityPolicy.dense()
    pol_b = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5)

    ref_a, _ = _run_alone(params, cfg, pol_a, None, prompts)
    ref_b, _ = _run_alone(params, cfg, pol_b, sp, prompts)
    assert ref_a != ref_b          # the policies genuinely diverge

    # interleaved stepping on one thread
    engs = []
    for pol, s in ((pol_a, None), (pol_b, sp)):
        e = Engine(params, cfg, EngineConfig(max_slots=2, max_len=32,
                                             prefill_chunk=8, policy=pol), s)
        for b in range(2):
            e.submit(prompts[b], 5)
        engs.append(e)
    while any(e.scheduler.has_work() for e in engs):
        for e in engs:
            if e.scheduler.has_work():
                e.step()
    assert {r: s.tokens for r, s in engs[0].states.items()} == ref_a
    assert {r: s.tokens for r, s in engs[1].states.items()} == ref_b
    assert engs[0].decode_traces == 1 and engs[1].decode_traces == 1

    # concurrent threads
    outs = {}

    def drive(name, pol, s):
        outs[name] = _run_alone(params, cfg, pol, s, prompts)[0]

    ts = [threading.Thread(target=drive, args=("a", pol_a, None)),
          threading.Thread(target=drive, args=("b", pol_b, sp))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert outs["a"] == ref_a
    assert outs["b"] == ref_b


# ---------------------------------------------------------------------------
# self-contained artifact
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_reproduces_decode_tokens(tmp_path, model):
    """A saved policy+sp artifact reloads without model params (g rides in
    the file) and reproduces the saver's sparse decode tokens exactly."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 12, step=31)
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    pol = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5,
                                 block_backends=((0, 1, "off"),))
    ref, _ = _run_alone(params, cfg, pol, sp, prompts, gen=6)

    f = str(tmp_path / "plan.npz")
    pol.save(f, sp=sp)

    pol2, sp2 = SparsityPolicy.load(f)
    assert pol2 == pol
    # the artifact carries g (the piece SparsePlan.save used to drop)
    leaves = jax.tree_util.tree_leaves_with_path(sp2)
    assert any(str(p[-1]) == "['g']" or getattr(p[-1], "key", "") == "g"
               for p, _ in leaves)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), sp, sp2)
    out2, _ = _run_alone(params, cfg, pol2, sp2, prompts, gen=6)
    assert out2 == ref


def test_artifact_version_gate(tmp_path):
    f = str(tmp_path / "bad.npz")
    import json
    np.savez(f, __meta__=np.array(json.dumps({"version": 99, "policy": {}})))
    with pytest.raises(ValueError, match="version"):
        SparsityPolicy.load(f)


def test_legacy_artifact_interpret_normalized(tmp_path):
    """v<=2 artifacts baked the old unconditional interpret=True default;
    the loader normalizes it to None (auto-detect) so a pre-v3 ladder no
    longer forces interpreter mode on TPU.  A v3 artifact's explicit
    True is honored — it became expressible the same release auto
    appeared, so it can only be deliberate."""
    import json
    legacy = SparsityPolicy.uniform("pallas", k_max_frac=0.5).to_dict()
    legacy["interpret"] = True
    f = str(tmp_path / "legacy.npz")
    np.savez(f, __meta__=np.array(json.dumps(
        {"version": 2, "kind": "policy", "policy": legacy})))
    pol, sp = SparsityPolicy.load(f)
    assert pol.interpret is None and sp is None
    f3 = str(tmp_path / "v3.npz")
    np.savez(f3, __meta__=np.array(json.dumps(
        {"version": 3, "kind": "policy", "policy": legacy})))
    pol3, _ = SparsityPolicy.load(f3)
    assert pol3.interpret is True


def test_from_plan_mixed_backend_map():
    class FakePlan:
        block_ratios = np.array([0.1, 0.6, 0.7, 0.2])
        layer_ratios = {(0, "attn/wq"): 0.1, (1, "mlp/wo"): 0.7}
    pol = SparsityPolicy.from_plan(FakePlan(), backend="topk_block",
                                   sensitive_backend="mask",
                                   sensitive_frac=0.5)
    # blocks 0 and 3 have the lowest prune ratios -> most sensitive
    assert pol.backend_at(depth=0) == "mask"
    assert pol.backend_at(depth=3) == "mask"
    assert pol.backend_at(depth=1) == "topk_block"
    # k_max bounds the largest per-layer keep ratio
    assert pol.k_max_frac == pytest.approx(0.9)
