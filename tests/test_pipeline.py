"""WiSparse pipeline tests: component ordering (paper Table 2), allocation
invariants, plan (de)serialization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import calibration, pipeline
from repro.core.allocation import (EvoConfig, block_level_allocation,
                                   intra_block_allocation, weighted_average)
from repro.models import api


@pytest.fixture(scope="module")
def ctx():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    # inject weight-column outliers (paper Obs. 1: low-|x| channels can
    # carry high-norm weight columns) — random-init weights are isotropic,
    # where activation-only and weight-aware scores coincide by symmetry
    from repro.core.unstacked import SPARSIFIABLE

    def spike(path, a):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in SPARSIFIABLE and a.ndim >= 3:   # stacked (reps, n, m)
            n = a.shape[-2]
            key = jax.random.fold_in(jax.random.PRNGKey(7), n)
            mask = jax.random.bernoulli(key, 0.1, (n,))
            scale = jnp.where(mask, 4.0, 1.0).astype(a.dtype)
            return a * scale[..., :, None]
        return a

    params = jax.tree_util.tree_map_with_path(spike, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                              cfg.vocab_size)
    return calibration.build_context(params, cfg, {"tokens": toks}), \
        params, cfg, {"tokens": toks}


def test_context_shapes(ctx):
    c, params, cfg, _ = ctx
    assert c.num_blocks == cfg.num_layers
    assert len(c.block_io) == c.num_blocks + 1
    # every sparsifiable linear captured exactly once
    for d in range(c.num_blocks):
        for path in c.keys_by_depth[d]:
            key = (d, path)
            assert key in c.acts and key in c.g
            assert c.acts[key].shape[-1] == c.g[key].shape[-1]


def test_tau_monotone_in_sparsity(ctx):
    c = ctx[0]
    key = (0, c.keys_by_depth[0][0])
    taus = [c.tau_for(key, 1.0, keep) for keep in (0.9, 0.5, 0.2)]
    assert taus[0] <= taus[1] <= taus[2]


def test_weight_aware_beats_activation_only(ctx):
    """Paper Table 2 first step: +weight importance improves over
    activation-only at matched 50% sparsity."""
    c = ctx[0]
    ratios = {(d, p): 0.5 for d in range(c.num_blocks)
              for p in c.keys_by_depth[d]}
    kl_act = c.fitness(c.make_sp({k: 0.0 for k in ratios}, ratios))
    kl_w = c.fitness(c.make_sp({k: 1.0 for k in ratios}, ratios))
    assert np.isfinite(kl_act) and np.isfinite(kl_w)
    assert kl_w < kl_act


def test_evolutionary_allocation_invariants(ctx):
    c = ctx[0]
    evo = EvoConfig(generations=2, offspring=4, eps=0.1, seed=0)
    p = block_level_allocation(c, 0.5, evo)
    assert weighted_average(c, p) <= 0.5 + 1e-9
    assert (p >= 0).all() and (p <= 0.95).all()


def test_greedy_allocation_meets_budget(ctx):
    c = ctx[0]
    alloc = intra_block_allocation(c, 0, 0.5, delta=0.25)
    sizes = np.array([c.sizes[k] for k in alloc])
    vals = np.array([alloc[k] for k in alloc])
    eff = float(np.sum(vals * sizes) / np.sum(sizes))
    assert eff >= 0.5 - 0.25           # within one delta of the budget


def test_full_pipeline_beats_uniform_activation_only(ctx):
    c, params, cfg, batch = ctx
    plan_a = pipeline.activation_only_plan(params, cfg, batch, 0.5, ctx=c)
    kl_a = c.fitness(plan_a.per_depth_sp)
    plan = pipeline.run_pipeline(
        params, cfg, batch, 0.5,
        evo=EvoConfig(generations=2, offspring=4, eps=0.1),
        delta=0.25, coord_passes=0, ctx=c)
    kl_f = c.fitness(plan.per_depth_sp)
    assert kl_f < kl_a
    # global budget respected at block level
    assert weighted_average(c, plan.block_ratios) <= 0.5 + 1e-9


def test_plan_save_load(tmp_path, ctx):
    c, params, cfg, batch = ctx
    plan = pipeline.activation_only_plan(params, cfg, batch, 0.4, ctx=c)
    f = str(tmp_path / "plan.json")
    plan.save(f)
    p_target, blocks, layers, alphas, taus = pipeline.SparsePlan.load_ratios(f)
    assert p_target == 0.4
    assert len(blocks) == c.num_blocks
    assert set(layers) == set(plan.layer_ratios)


def test_plan_save_load_pipe_in_path(tmp_path):
    """Keys split once on "|": a path component containing "|" survives
    the round-trip instead of silently truncating."""
    weird = {(0, "attn/wq"): 0.5, (1, "exp|0/wi_gate"): 0.25}
    plan = pipeline.SparsePlan(
        cfg=None, p_target=0.5, block_ratios=np.array([0.5, 0.25]),
        layer_ratios=dict(weird), alphas={k: 1.0 for k in weird},
        taus={k: 0.1 for k in weird}, per_depth_sp=[], stacked_sp=[])
    f = str(tmp_path / "plan.json")
    plan.save(f)
    _, _, layers, alphas, taus = pipeline.SparsePlan.load_ratios(f)
    assert set(layers) == set(weird)
    assert set(alphas) == set(weird) and set(taus) == set(weird)
    assert layers[(1, "exp|0/wi_gate")] == 0.25


def test_stacked_sp_matches_unstacked_numerics(ctx):
    """The re-stacked sp tree drives the scan model to the same logits as
    the unstacked calibration model."""
    from repro.core import sparse_linear as sl
    from repro.core import unstacked as U
    from repro.models import model as M
    c, params, cfg, batch = ctx
    plan = pipeline.activation_only_plan(params, cfg, batch, 0.5, ctx=c)
    mask = sl.SparsityPolicy.uniform("mask")
    lu, _ = U.forward_unstacked(params, cfg, batch["tokens"],
                                per_depth_sp=plan.per_depth_sp, policy=mask)
    ls, _ = M.forward(params, cfg, tokens=batch["tokens"], mode="train",
                      sp=plan.stacked_sp, policy=mask)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls),
                               rtol=1e-4, atol=1e-4)
