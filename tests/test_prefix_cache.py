"""Prefix-sharing KV cache: radix-tree semantics (unit + hypothesis
property), the pool's segment layer and free-set bookkeeping, and
engine-level bit-parity of cache-hit generations vs cold prefill —
under a plain policy, a mixed ladder rung, and speculative decoding."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.serving import (SNAPSHOT_SCHEMA_VERSION, Engine, EngineConfig,
                           PrefixCache, RadixTree, SlotKVPool, SpecConfig)
from repro.sparsity import PolicyLadder, SparsityPolicy


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


# ---------------------------------------------------------------------------
# radix tree
# ---------------------------------------------------------------------------

def test_radix_insert_match_and_limit_clamp():
    t = RadixTree()
    t.insert((1, 2, 3, 4), "seg4", 8)
    # exact path, limit caps the reuse below the node's end
    node, n = t.match((1, 2, 3, 4), limit=3)
    assert n == 3 and node.payload == "seg4" and node.end >= n
    # shorter query: the longer segment still sources the slice
    node, n = t.match((1, 2, 3, 9), limit=3)
    assert n == 3 and node.payload == "seg4"
    # diverging immediately: miss
    assert t.match((7, 8), limit=1) == (None, 0)
    # limit 0 (1-token prompt): never a hit
    assert t.match((1,), limit=0) == (None, 0)


def test_radix_mid_edge_source_and_split_insert():
    t = RadixTree()
    t.insert((5, 5, 1, 1), "a", 4)
    # query shares only (5, 5): mid-edge match slices "a"
    node, n = t.match((5, 5, 2, 2), limit=3)
    assert (node.payload, n) == ("a", 2)
    # publishing the second prompt splits the edge; both stay matchable
    t.insert((5, 5, 2, 2), "b", 4)
    assert t.match((5, 5, 1, 1, 9), limit=4)[0].payload == "a"
    assert t.match((5, 5, 2, 2, 9), limit=4)[0].payload == "b"
    assert t.match((5, 5, 9), limit=2)[1] == 2
    # the split node is structural (no payload of its own)
    assert t.num_payloads == 2
    assert t.total_size == 8


def test_radix_eviction_lru_leaves_only_and_pins():
    t = RadixTree()
    a = t.insert((1, 1, 1), "a", 4)
    b = t.insert((1, 1, 1, 2, 2), "b", 8)
    c = t.insert((3, 3), "c", 4)
    t.match((3, 3), limit=2)                     # c most recently used
    # a has a payload-bearing descendant (b) -> only b and c evictable;
    # b is LRU among them
    ev = t.evict(budget=8)
    assert [n.end for n in ev] == [b.end] and t.total_size == 8
    # pinned c cannot be evicted even under a zero budget
    t.pin(c)
    ev = t.evict(budget=0)
    assert c not in ev and c.payload is not None
    assert all(n.refcount == 0 for n in ev)      # evicted never pinned
    t.unpin(c)
    assert t.evict(budget=0) == [c] and t.total_size == 0
    assert a.payload is None                     # a fell once b was gone
    with pytest.raises(ValueError):
        t.unpin(c)                               # refcount never negative


def test_radix_hypothesis_property():
    """Random insert/match/pin/unpin/evict sequences vs a brute-force
    model: longest-prefix match correctness, refcounts never negative,
    evicted segments never pinned, size accounting exact."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    tokens = st.lists(st.integers(0, 3), min_size=1, max_size=6)
    ops = st.lists(st.one_of(
        st.tuples(st.just("insert"), tokens),
        st.tuples(st.just("match"), tokens),
        st.tuples(st.just("pin"), tokens),
        st.tuples(st.just("evict"), st.integers(0, 30)),
    ), max_size=40)

    @hyp.given(ops)
    @hyp.settings(max_examples=60, deadline=None)
    def run(seq):
        t = RadixTree()
        live = {}                                 # path -> size
        pinned = {}                               # path -> node
        for op, arg in seq:
            if op == "insert":
                path = tuple(arg)
                t.insert(path, f"seg{path}", len(path))
                live.setdefault(path, len(path))
            elif op == "match":
                q = tuple(arg)
                limit = len(q) - 1
                node, n = t.match(q, limit=limit)
                want = 0
                for path in live:
                    lcp = 0
                    while lcp < min(len(path), len(q)) \
                            and path[lcp] == q[lcp]:
                        lcp += 1
                    want = max(want, min(lcp, limit))
                assert n == want, (q, n, want, sorted(live))
                if n:
                    assert node.payload is not None and node.end >= n
                    assert node.path[:n] == q[:n]
            elif op == "pin":
                path = tuple(arg)
                node, n = t.match(path, limit=len(path))
                if node is not None and path not in pinned:
                    t.pin(node)
                    pinned[path] = node
            elif op == "evict":
                before = {n.path for n in t.payload_nodes()}
                ev = t.evict(arg)
                for n in ev:
                    assert n.refcount == 0       # evicted never pinned
                    assert n not in pinned.values()
                # sizes stay exact
                gone = before - {n.path for n in t.payload_nodes()}
                for path in gone:
                    live.pop(path, None)
            assert t.total_size == sum(live.values())
            assert t.total_size == sum(
                n.size for n in t.payload_nodes())
            assert all(n.refcount >= 0 for n in t.payload_nodes())
        for node in pinned.values():
            t.unpin(node)
            assert node.refcount >= 0

    run()


# ---------------------------------------------------------------------------
# pool segment layer + free-set bookkeeping
# ---------------------------------------------------------------------------

def test_pool_extract_write_roundtrip(model):
    """A slot's prefix survives extract -> write into another slot
    bit-exactly, and segment leaf shapes match api.prefix_segment_schema."""
    import jax
    import repro.models.params as P
    _, cfg = model
    pool = SlotKVPool(cfg, max_slots=3, max_len=16)
    s0, s1 = pool.alloc(), pool.alloc()
    # fill the pool with recognizable values
    pool.caches = jax.tree_util.tree_map(
        lambda leaf: jnp.arange(leaf.size, dtype=jnp.float32)
        .reshape(leaf.shape).astype(leaf.dtype), pool.caches)
    seg = pool.extract_prefix(s0, 8)
    want = P.abstract_params(api.prefix_segment_schema(cfg, 8), cfg.dtype)
    for sl, wl in zip(jax.tree_util.tree_leaves(seg),
                      jax.tree_util.tree_leaves(want)):
        assert sl.shape == wl.shape
    before = jax.tree_util.tree_map(lambda x: np.asarray(x), pool.caches)
    pool.write_prefix(seg, s1)                   # whole physical segment
    for axes, pl_new, pl_old, sl in zip(
            pool._flat_axes,
            jax.tree_util.tree_leaves(pool.caches),
            jax.tree_util.tree_leaves(before),
            jax.tree_util.tree_leaves(seg)):
        b_ax, t_ax = axes.index("batch"), axes.index("kv_seq")
        new, old, s = (np.moveaxis(np.asarray(a), (b_ax, t_ax), (0, 1))
                       for a in (pl_new, pl_old, sl))
        np.testing.assert_array_equal(new[s1, :8], s[0, :8])
        np.testing.assert_array_equal(new[s1, 8:], old[s1, 8:])  # untouched
        np.testing.assert_array_equal(new[s0], old[s0])  # donor intact
    with pytest.raises(ValueError):
        pool.extract_prefix(s0, 99)              # beyond the pool length
    with pytest.raises(ValueError):
        pool.extract_prefix(2, 4)                # unallocated slot


def test_pool_free_set_stays_consistent(model):
    """The O(1) free-set mirrors the free list through arbitrary
    alloc/free/commit/rollback cycles, and state guards still fire."""
    _, cfg = model
    pool = SlotKVPool(cfg, max_slots=5, max_len=16)

    def consistent():
        assert pool._free_set == set(pool._free)
        assert len(pool._free_set) == len(pool._free)  # no duplicates

    rng = np.random.default_rng(0)
    held = []
    consistent()
    for _ in range(100):
        if held and rng.random() < 0.45:
            slot = held.pop(rng.integers(len(held)))
            pool.free(slot)
        elif pool.num_free:
            slot = pool.alloc()
            pool.commit(slot, int(rng.integers(0, 4)))
            held.append(slot)
        consistent()
    for slot in held:
        pool.free(slot)
    consistent()
    assert pool.num_free == 5
    slot = pool.alloc()
    pool.free(slot)
    with pytest.raises(ValueError):
        pool.free(slot)                          # double free
    with pytest.raises(ValueError):
        pool.commit(slot, 1)                     # freed slot
    consistent()


def test_prefix_cache_rejects_sliced_layouts():
    cfg = reduced(get_config("mamba2_130m"))
    pool = SlotKVPool(cfg, max_slots=2, max_len=16)
    assert not pool.can_cache_prefix
    with pytest.raises(ValueError, match="full-length self-attention"):
        PrefixCache(pool, chunk=8)


# ---------------------------------------------------------------------------
# engine-level parity: cache hits are bit-identical to cold prefill
# ---------------------------------------------------------------------------

def _run_serialized(eng, prompts, gen):
    """Submit/run one request at a time (single-slot batches make even
    the shared-saliency backends per-request deterministic)."""
    out = []
    for p in prompts:
        rs = eng.submit(p, gen)
        eng.run()
        out.append(rs.tokens)
    return out


def test_engine_hit_parity_and_stats(model):
    params, cfg = model
    base = _prompts(cfg, 1, 16, step=5)
    shared = base[0]
    # distinct suffix first-tokens, so each match stops exactly at the
    # 16-token shared prefix (no accidental deeper matches)
    prompts = [np.concatenate([shared, np.full(4, 10 + i, np.int32)])
               for i in range(3)]
    prompts.append(prompts[0])                   # identical repeat
    cold = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8), None)
    warm = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8, prefix_cache=True), None)
    warm.warmup()
    warm_traces = warm.pool._segment_traces      # warmup's compile set
    assert _run_serialized(cold, prompts, 5) == \
        _run_serialized(warm, prompts, 5)
    s = warm.stats
    assert s.prefix_lookups == 4
    assert s.prefix_hits == 3                    # all but the first
    # two mid-edge hits at the 16-token shared prefix + one full repeat
    # clamped to P-1 = 19
    assert s.prefix_tokens_saved == 16 + 16 + 19
    assert warm.decode_retraces_after_warmup == 0
    snap = warm.snapshot()
    assert snap["schema_version"] == SNAPSHOT_SCHEMA_VERSION
    assert snap["prefix_hit_rate"] == 0.75
    assert snap["prefix_segments"] == 3          # repeat not re-published
    assert warm.prefix_cache.cached_tokens > 0
    assert warm.pool._segment_traces == warm_traces  # warmup covered all


def test_engine_hit_parity_mixed_ladder_rung(model):
    """A pinned sparse rung with a mixed per-block decode policy: the
    cache-hit generation must reproduce the cold generation exactly.
    Prefill stays dense on every rung (the prefix-cache precondition);
    requests run serialized so shared-saliency decode is deterministic."""
    params, cfg = model
    mixed = SparsityPolicy.uniform(
        "topk_shared", k_max_frac=0.5, block_backends=((0, 1, "off"),),
        dense_phases=("prefill_dense", "prefill_sparse"))
    ladder = PolicyLadder(
        budgets=(0.0, 0.5),
        policies=(SparsityPolicy.dense(
            dense_phases=("prefill_dense", "prefill_sparse")), mixed),
        sps=(default_sp_stacked(params, cfg, keep_frac=1.0),
             default_sp_stacked(params, cfg, keep_frac=0.5)))
    base = _prompts(cfg, 2, 20, step=9)
    shared = base[0, :14]
    prompts = [np.concatenate([shared, base[i, 14:18]]) for i in range(2)]

    def fresh(prefix):
        return Engine(params, cfg, EngineConfig(
            max_slots=2, max_len=32, prefill_chunk=8, initial_rung=1,
            prefix_cache=prefix), ladder=ladder)

    assert _run_serialized(fresh(False), prompts, 5) == \
        _run_serialized(fresh(True), prompts, 5)


def test_engine_hit_parity_under_spec_decode(model):
    """Speculative decoding over a prefix-cache engine: hits happen and
    the output stays token-identical to the no-cache spec engine."""
    params, cfg = model
    ladder = PolicyLadder.uniform(
        params, cfg, (0.0, 0.5),
        dense_phases=("prefill_dense", "prefill_sparse"))
    base = _prompts(cfg, 3, 20, step=13)
    shared = base[0, :12]
    prompts = [np.concatenate([shared, base[i, 12:16]]) for i in range(3)]

    def fresh(prefix):
        return Engine(params, cfg, EngineConfig(
            max_slots=2, max_len=32, prefill_chunk=8,
            spec=SpecConfig(gamma=2, drafter_rung=1),
            prefix_cache=prefix), ladder=ladder)

    warm = fresh(True)
    cold_out, warm_out = [], []
    for eng, out in ((fresh(False), cold_out), (warm, warm_out)):
        for _i, p in enumerate(prompts):
            eng.submit(p, 6)
        got = eng.run()
        out.extend(got[i] for i in range(3))
    assert cold_out == warm_out
    assert warm.stats.prefix_hits >= 1
    assert warm.decode_retraces_after_warmup == 0
    assert warm.verify_retraces_after_warmup == 0


def test_engine_eviction_respects_budget(model):
    params, cfg = model
    prompts = [_prompts(cfg, 1, 12, step=20 + i)[0] for i in range(4)]
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=24, prefill_chunk=8, prefix_cache=True,
        prefix_cache_tokens=32), None)
    for p in prompts:
        eng.submit(p, 3)
        eng.run()
    # each 12-token prompt stores a 16-token (chunk-quantized) segment
    assert eng.prefix_cache.cached_tokens <= 32
    assert eng.stats.prefix_evicted_segments >= 2
    assert eng.prefix_cache.num_segments <= 2


def test_prefix_cache_guards(model):
    params, cfg = model
    with pytest.raises(ValueError, match="chunked"):
        EngineConfig(prefix_cache=True, prefill_strategy="whole")
    with pytest.raises(ValueError, match="prefix_cache_tokens"):
        EngineConfig(prefix_cache_tokens=-1)
    # sparse prefill under the default phase split is not
    # prefix-deterministic -> rejected eagerly
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    pol = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5,
                                 dense_phases=())
    with pytest.raises(ValueError, match="prefix-deterministic"):
        Engine(params, cfg, EngineConfig(
            max_slots=2, max_len=24, prefill_chunk=8, policy=pol,
            prefill_dense_frac=0.0, prefix_cache=True), sp)
    # prompt-length-dependent dense/sparse boundary -> rejected
    pol2 = SparsityPolicy.uniform("mask")
    with pytest.raises(ValueError, match="phase"):
        Engine(params, cfg, EngineConfig(
            max_slots=2, max_len=24, prefill_chunk=8, policy=pol2,
            prefill_dense_frac=0.5, prefix_cache=True), sp)
    # SSM archs resolve to whole-prompt prefill -> rejected
    mcfg = reduced(get_config("mamba2_130m"))
    with pytest.raises(ValueError, match="chunked"):
        Engine(api.init_model(mcfg, 0), mcfg, EngineConfig(
            max_slots=2, max_len=24, prefill_chunk=8,
            prefix_cache=True), None)
    # paper-exact mask everywhere IS prefix-deterministic -> accepted
    # (prefill_dense_frac=0 -> every chunk runs the prefill_sparse
    # phase, which for the mask policy is mask itself)
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=24, prefill_chunk=8, policy=pol2,
        prefill_dense_frac=0.0, prefix_cache=True), sp)
    assert eng.prefix_cache is not None
