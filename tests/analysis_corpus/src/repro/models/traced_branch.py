"""Python ``if``/``while`` on traced values inside model/kernel code.
Under jit these either raise ``TracerBoolConversionError`` at first
trace or — worse, with concrete aval leakage — silently bake one branch
into the executable.  Data-dependent control flow must use
``jnp.where`` / ``lax.cond`` / ``lax.while_loop``."""
import jax
import jax.numpy as jnp


def clamp_bad(x):
    if jnp.sum(x) > 0:  # EXPECT: traced-value-branch
        return x
    return -x


def loop_bad(x):
    while jnp.linalg.norm(x) > 1.0:  # EXPECT: traced-value-branch
        x = x * 0.5
    return x


def shape_ok(x):
    # static metadata branches are fine: shapes are Python ints
    if x.shape[0] > 1:
        return x.reshape(-1)
    return x


def none_ok(sp):
    # identity tests against None are static too
    if sp is None:
        return jnp.zeros(())
    return sp["g"]


def jit_bound_bad(x):
    y = jax.jit(lambda v: v * 2)(x)
    if y[0] > 0:  # EXPECT: traced-value-branch
        return y
    return -y
