"""The PR 9 determinism bug, verbatim: per-leaf init keys derived with
builtin ``hash()``.  ``str.__hash__`` is salted per process
(PYTHONHASHSEED), so two processes initialising "the same" model from
the same seed got different per-leaf keys — caught as a cross-process
checkpoint divergence, fixed with ``zlib.crc32`` in
``src/repro/models/params.py``.  ``no-builtin-hash-persistence`` exists
so the class of bug can't come back."""
import jax


def _path_str(path) -> str:
    return "/".join(str(p) for p in path)


def init_params_buggy(schema, seed: int):
    out = {}
    for path, _leaf in schema.items():
        # per-leaf fold-in tag: MUST be process-stable; hash() is not
        tag = hash(_path_str(path)) & 0x7FFFFFFF  # EXPECT: no-builtin-hash-persistence
        out[path] = jax.random.fold_in(jax.random.PRNGKey(seed), tag)
    return out
