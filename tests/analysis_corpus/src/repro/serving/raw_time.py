"""Raw clock reads in the serving tree.  Every serving timestamp must
flow through ``repro.obs.clock`` so the flight recorder can capture the
stream live and replay it bit-identically; a raw ``time.*`` read is a
replay divergence waiting to happen (the PR 9 clock unification)."""
import time


def stamp_request(req: dict) -> dict:
    req["arrival"] = time.time()  # EXPECT: no-raw-time
    return req


def measure(fn):
    t0 = time.monotonic()  # EXPECT: no-raw-time
    fn()
    return time.monotonic() - t0  # EXPECT: no-raw-time


def stamp_suppressed(req: dict) -> dict:
    # a justified escape hatch: this site is outside any replayed path
    req["wall"] = time.time()  # repro: ignore[no-raw-time]
    return req
