"""Thread-local execution state in the serving tree — the pre-PR 2
shape of the sparsity mode switch.  A thread-local flag read inside a
traced function is invisible to jit's cache key, so two threads serving
different modes silently share one executable.  Serving state must ride
in the :class:`SparsityPolicy` value (static jit arg) instead."""
import contextvars
import threading

_MODE = threading.local()  # EXPECT: no-thread-local-serving

_PHASE = contextvars.ContextVar("phase", default="decode")  # EXPECT: no-thread-local-serving


def set_mode(mode: str) -> None:
    _MODE.value = mode


def current_mode() -> str:
    return getattr(_MODE, "value", "dense")
