"""Unguarded hot-path telemetry — the bug class PR 6's
zero-cost-when-off contract forbids.  Named ``engine.py`` because the
``hot-path-zero-cost`` pass only audits the engine/scheduler hot path.
Every emit below must be dominated by an ``is not None`` identity check
on the sink; the unguarded ones allocate (f-strings, dict literals,
attribute dispatch) on every decode step even with telemetry off."""


class FakeEngine:
    def __init__(self, obs):
        self.obs = obs

    def decode_step_bad(self, t0: float, t1: float, n: int) -> None:
        # no guard at all: attribute dispatch + kwargs dict per step
        self.obs.events.record("decode", t0=t0, dur=t1 - t0, n=n)  # EXPECT: hot-path-zero-cost

    def decode_step_wrong_guard(self, t0: float, t1: float) -> None:
        # truthiness is not identity: an armed-but-empty sink is falsy
        if self.obs.tracer:
            self.obs.tracer.complete("decode", t0, t1)  # EXPECT: hot-path-zero-cost

    def decode_step_good(self, t0: float, t1: float) -> None:
        ev = self.obs.events
        if ev is not None:
            ev.record("decode", t0=t0, dur=t1 - t0)

    def decode_step_early_return(self, t0: float) -> None:
        if self.obs.metrics is None:
            return
        self.obs.metrics.observe("decode.t0", t0)
