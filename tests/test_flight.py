"""Flight recorder: zero-cost null path, capture validation, ReplayClock
divergence taxonomy, record→replay bit-identity round trips (controller +
preemption, speculative decoding), incomplete-dump refusal, dump
triggers, the injected-divergence CLI report, and the no-raw-time lint
over the serving tree."""
import glob
import json
import os

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.obs import NULL_TELEMETRY, ReplayClock, ReplayDivergence, Telemetry
from repro.obs.flight import FLIGHT_SCHEMA_VERSION, FlightRecorder
from repro.obs.flight import replay as flight_replay
from repro.serving import Engine, EngineConfig, SchedulerConfig
from repro.serving.controller import SLOConfig
from repro.serving.spec import SpecConfig
from repro.sparsity import PolicyLadder


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


@pytest.fixture(scope="module")
def ladder(model):
    params, cfg = model
    return PolicyLadder.uniform(params, cfg, [0.0, 0.5, 0.7])


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


# ---------------------------------------------------------------------------
# null path + construction validation
# ---------------------------------------------------------------------------

def test_null_path_is_allocation_free(model):
    """With no recorder armed the engine keeps the exact module-level
    singletons — the hot path branches on ``is None`` and never builds
    per-call objects."""
    params, cfg = model
    assert NULL_TELEMETRY.flight is None
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=24, prefill_chunk=8), None)
    assert eng.obs is NULL_TELEMETRY
    assert eng.clock is obs.SYSTEM_CLOCK


def test_recorder_validation_and_double_attach(model):
    params, cfg = model
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)
    with pytest.raises(ValueError, match="max_dumps"):
        FlightRecorder(max_dumps=-1)
    with pytest.raises(TypeError):
        Engine(params, cfg, EngineConfig(
            max_slots=1, max_len=24, prefill_chunk=8), None,
            clock=object())
    fr = FlightRecorder()
    eng = Engine(params, cfg, EngineConfig(
        max_slots=1, max_len=24, prefill_chunk=8), None,
        telemetry=Telemetry(flight=fr))
    assert eng.clock is not obs.SYSTEM_CLOCK     # recording wrapper
    with pytest.raises(RuntimeError, match="already attached"):
        Engine(params, cfg, EngineConfig(
            max_slots=1, max_len=24, prefill_chunk=8), None,
            telemetry=Telemetry(flight=fr))


def test_replay_clock_divergence_taxonomy():
    """Exhausted stream, kind mismatch, and site mismatch each raise a
    ReplayDivergence whose ``detail`` names what desynchronized."""
    clock = ReplayClock([{"k": "clock", "t": 1.5, "s": "decode.t0"}])
    assert clock.now("decode.t0") == 1.5
    assert clock.exhausted
    with pytest.raises(ReplayDivergence, match="exhausted") as exc:
        clock.now("decode.t1")
    assert exc.value.detail["expected"] is None
    assert exc.value.detail["got"] == {"k": "clock", "s": "decode.t1"}

    clock = ReplayClock([{"k": "submit", "prompt": [1]}])
    with pytest.raises(ReplayDivergence, match="'submit' record") as exc:
        clock.now("decode.t0")
    assert exc.value.detail["expected"]["k"] == "submit"
    assert clock.cursor == 0                 # divergence consumes nothing

    clock = ReplayClock([{"k": "clock", "t": 1.5, "s": "decode.t0"}])
    with pytest.raises(ReplayDivergence, match="decode.t0") as exc:
        clock.now("prefill_chunk.t0")
    detail = exc.value.detail
    assert detail["expected"]["s"] == "decode.t0"
    assert detail["got"]["s"] == "prefill_chunk.t0"


# ---------------------------------------------------------------------------
# record → replay round trips
# ---------------------------------------------------------------------------

def _controller_ecfg():
    return EngineConfig(
        max_slots=2, max_len=96, prefill_chunk=16,
        slo=SLOConfig(tpot_p95=1e-9, max_queue=2),
        scheduler=SchedulerConfig(max_queue=8, preemption=True))


def _record_controller_run(model, ladder, sink, dump_dir=None):
    """The incident scenario: an impossible TPOT SLO forces rung
    escalation while an interactive arrival preempts a best-effort
    decoder."""
    params, cfg = model
    fr = FlightRecorder(sink=sink, dump_dir=dump_dir)
    prompts = _prompts(cfg, 3, 20)
    with Engine(params, cfg, _controller_ecfg(), ladder=ladder,
                telemetry=Telemetry(flight=fr)) as eng:
        for i in range(2):
            eng.submit(prompts[i], 24, priority="best-effort")
        for _ in range(10):
            eng.step()
        eng.submit(prompts[2], 12, priority="interactive")
        while eng.scheduler.has_work():
            eng.step()
    return fr


def test_controller_preemption_replays_bit_identical(model, ladder,
                                                     tmp_path):
    params, cfg = model
    sink = str(tmp_path / "controller.jsonl")
    fr = _record_controller_run(model, ladder, sink)
    kinds = {r["kind"] for r in fr.records("decision")}
    assert "rung_switch" in kinds, "scenario must exercise the controller"
    assert "preempt" in kinds and "resume" in kinds

    report = flight_replay.replay(
        sink, engine_factory=lambda clock, telemetry: Engine(
            params, cfg, _controller_ecfg(), ladder=ladder,
            telemetry=telemetry, clock=clock))
    assert report.ok, report.failures
    assert report.divergence is None
    assert report.requests == 3 and report.tokens > 0
    assert all(v == 0 for v in report.retraces.values()), report.retraces


def test_header_reconstruction_replays_without_factory(model, ladder,
                                                       tmp_path):
    """No factory passed: the engine is rebuilt purely from the header
    (arch/reduced/seed/ladder meta + serialized EngineConfig) — the
    path the CLI takes on a foreign dump."""
    sink = str(tmp_path / "controller.jsonl")
    ladder_path = str(tmp_path / "ladder.npz")
    ladder.save(ladder_path)
    params, cfg = model
    fr = FlightRecorder(sink=sink, meta={
        "arch": "llama31_8b", "reduced": True, "seed": 0,
        "ladder_path": ladder_path})
    prompts = _prompts(cfg, 1, 20)
    with Engine(params, cfg, _controller_ecfg(), ladder=ladder,
                telemetry=Telemetry(flight=fr)) as eng:
        eng.submit(prompts[0], 12)
        while eng.scheduler.has_work():
            eng.step()
    report = flight_replay.replay(sink)
    assert report.ok, report.failures


def test_spec_round_replays_bit_identical(model, ladder, tmp_path):
    params, cfg = model
    sink = str(tmp_path / "spec.jsonl")
    ecfg = EngineConfig(
        max_slots=2, max_len=96, prefill_chunk=16,
        spec=SpecConfig(gamma=2, drafter_rung=1, verifier_rung=0,
                        adaptive=True))
    fr = FlightRecorder(sink=sink)
    prompts = _prompts(cfg, 2, 20)
    with Engine(params, cfg, ecfg, ladder=ladder,
                telemetry=Telemetry(flight=fr)) as eng:
        for i in range(2):
            eng.submit(prompts[i], 16)
        while eng.scheduler.has_work():
            eng.step()
    assert fr.records("finish"), "spec scenario recorded no finishes"

    report = flight_replay.replay(
        sink, engine_factory=lambda clock, telemetry: Engine(
            params, cfg, ecfg, ladder=ladder,
            telemetry=telemetry, clock=clock))
    assert report.ok, report.failures
    assert report.retraces.get("verify") == 0


def test_injected_divergence_cli_reports_structured_diff(model, ladder,
                                                         tmp_path,
                                                         capsys):
    """``--inject-divergence`` corrupts one recorded token; the CLI must
    exit 1 and name the request/token/record that diverged."""
    params, cfg = model
    sink = str(tmp_path / "one.jsonl")
    ladder_path = str(tmp_path / "ladder.npz")
    ladder.save(ladder_path)
    fr = FlightRecorder(sink=sink, meta={
        "arch": "llama31_8b", "reduced": True, "seed": 0,
        "ladder_path": ladder_path})
    prompts = _prompts(cfg, 1, 20)
    with Engine(params, cfg, _controller_ecfg(), ladder=ladder,
                telemetry=Telemetry(flight=fr)) as eng:
        eng.submit(prompts[0], 12)
        while eng.scheduler.has_work():
            eng.step()

    rc = flight_replay.main([sink, "--inject-divergence"])
    assert rc == 1
    report = json.loads(capsys.readouterr().out)
    assert not report["ok"]
    div = report["divergence"]
    assert div is not None
    assert {"record", "request", "token_index",
            "recorded_token", "replayed_token"} <= set(div)


# ---------------------------------------------------------------------------
# dumps
# ---------------------------------------------------------------------------

def test_incomplete_ring_dump_is_refused(model, ladder, tmp_path):
    """A dump whose ring overflowed is marked incomplete and the loader
    refuses it — a partial history cannot gate bit-identity."""
    dump_dir = str(tmp_path / "dumps")
    fr = _record_controller_run(model, ladder, sink=None,
                                dump_dir=dump_dir)
    assert fr.capacity == 4096 and fr.dropped == 0
    # shrink a copy of the history into a 8-record ring and dump it
    small = FlightRecorder(capacity=8, dump_dir=dump_dir)
    small._attached = True
    for rec in fr.records():
        small._append(rec)
    assert small.dropped > 0
    path = small.dump("manual")
    prologue = json.loads(open(path).readline())
    assert prologue["complete"] is False
    with pytest.raises(ValueError, match="incomplete"):
        flight_replay.load_recording(path)


def test_dump_triggers_slo_breach_and_exception(model, ladder, tmp_path):
    """The impossible SLO's first escalation auto-dumps (slo_breach);
    a crashed driving loop dumps on the way out (exception)."""
    params, cfg = model
    dump_dir = str(tmp_path / "dumps")
    fr = _record_controller_run(model, ladder, sink=None,
                                dump_dir=dump_dir)
    reasons = {os.path.basename(p).split("-")[1] for p in fr.dumps}
    assert "slo_breach" in reasons, fr.dumps

    fr2 = FlightRecorder(dump_dir=dump_dir)
    prompts = _prompts(cfg, 1, 20)
    with pytest.raises(RuntimeError, match="boom"), \
            Engine(params, cfg, _controller_ecfg(), ladder=ladder,
                   telemetry=Telemetry(flight=fr2)) as eng:
        eng.submit(prompts[0], 12)
        eng.step()
        raise RuntimeError("boom")
    assert any("flight-exception-" in p for p in fr2.dumps)
    assert glob.glob(os.path.join(dump_dir, "flight-exception-*.jsonl"))


def test_sink_is_sealed_and_versioned(model, ladder, tmp_path):
    sink = str(tmp_path / "sealed.jsonl")
    _record_controller_run(model, ladder, sink)
    records = [json.loads(ln) for ln in open(sink)]
    assert records[0]["k"] == "header"
    assert records[0]["flight_schema_version"] == FLIGHT_SCHEMA_VERSION
    assert records[-1] == {"k": "end", "count": len(records) - 1,
                           "complete": True}


# ---------------------------------------------------------------------------
# no raw time reads in the serving tree (satellite lint)
# ---------------------------------------------------------------------------

def test_no_raw_time_calls_in_serving_tree():
    """Every serving-path timestamp must flow through the engine clock
    (``repro.obs.clock``) or the recorder can't capture it.  The old
    grep-level lint graduated into the ``no-raw-time`` AST pass of
    ``repro.analysis`` (which also covers ``from time import ...``
    aliasing and the ``*_ns`` variants, and scans ALL of ``src/`` plus
    ``benchmarks/`` and ``examples/``, not just the serving tree);
    this thin wrapper keeps the invariant in the tier-1 suite.
    ``time.sleep`` is fine — it advances no clocks."""
    from repro.analysis import run_ast_passes
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    findings = run_ast_passes(root, rules=["no-raw-time"])
    assert not findings, "\n".join(f.format() for f in findings)
