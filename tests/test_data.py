"""Data pipeline: determinism, host-disjointness, resumability."""
import numpy as np

from repro.data import DataConfig, SyntheticLM, eval_batch


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=64, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_per_step():
    ds = SyntheticLM(_cfg())
    np.testing.assert_array_equal(ds.batch(5), ds.batch(5))
    assert not np.array_equal(ds.batch(5), ds.batch(6))


def test_host_sharding_partitions_global_batch():
    cfg = _cfg()
    ds = SyntheticLM(cfg)
    full = ds.batch(3, host_id=0, num_hosts=1)
    halves = [ds.batch(3, host_id=h, num_hosts=2) for h in (0, 1)]
    np.testing.assert_array_equal(np.concatenate(halves), full)


def test_resume_replays_identically():
    ds = SyntheticLM(_cfg())
    it1 = ds.iterator(start_step=0)
    seen = [next(it1) for _ in range(6)]
    it2 = ds.iterator(start_step=4)       # "restart" from step 4
    np.testing.assert_array_equal(next(it2), seen[4])
    np.testing.assert_array_equal(next(it2), seen[5])


def test_eval_disjoint_from_train():
    cfg = _cfg()
    ev = eval_batch(cfg, n=4)
    tr = SyntheticLM(cfg).batch(0)
    assert not np.array_equal(ev[:4, :16], tr[:4, :16])


def test_tokens_in_vocab_and_structured():
    cfg = _cfg(seq_len=160)        # > motif_period so a copy motif fits
    b = SyntheticLM(cfg).batch(0)
    assert b.min() >= 0 and b.max() < cfg.vocab_size
    # motif copies exist: some offset repeats
    row = b[0]
    period, L = cfg.motif_period, cfg.motif_len
    assert np.array_equal(row[period:period + L],
                          row[period - L:period])
