"""repro.obs telemetry: instrument semantics, exposition/trace-schema
validity, null-path zero-cost guarantees, clock discipline, and the
engine-level contracts — bit-identical tokens with telemetry on vs off,
retrace-free dispatch annotations, and event-log attribution for forced
rung switches, spec rollbacks and prefix evictions."""
import json
import math
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.obs import (NULL_CONTEXT, NULL_TELEMETRY, EventLog, Histogram,
                       MetricsRegistry, SpanTracer, Telemetry, log_buckets,
                       parse_exposition, serve_metrics,
                       validate_chrome_trace, validate_exposition)
from repro.serving import Engine, EngineConfig, SLOConfig, SpecConfig
from repro.serving.metrics import EngineStats, RingBuffer, percentile
from repro.sparsity import PolicyLadder


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


@pytest.fixture(scope="module")
def ladder(model):
    params, cfg = model
    return PolicyLadder.uniform(params, cfg, (0.0, 0.5))


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _engine(params, cfg, sp=None, telemetry=None, ladder=None, **kw):
    defaults = dict(max_slots=4, max_len=32, prefill_chunk=8)
    defaults.update(kw)
    return Engine(params, cfg, EngineConfig(**defaults), sp,
                  ladder=ladder, telemetry=telemetry)


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

def test_histogram_exact_whole_run():
    h = Histogram()
    assert h.count == 0 and math.isnan(h.quantile(50))
    for v in (1e-4, 1e-3, 1e-2, 1e-2, 10.0, 100.0):
        h.observe(v)
    assert h.count == 6
    assert h.sum == pytest.approx(110.0211)
    assert h.cumulative()[-1] == h.count
    # 100.0 overflows the 10s top bound into the +Inf slot
    assert h.counts[-1] == 1
    # quantile reports the selected bucket's upper bound, clamped to the
    # last finite bound for overflow
    assert h.quantile(100) == h.bounds[-1]
    assert h.quantile(0) >= 1e-4

    with pytest.raises(ValueError, match="increasing"):
        Histogram((1.0, 1.0))
    with pytest.raises(ValueError, match="increasing"):
        Histogram(())


def test_histogram_unit_buckets_exact():
    """Unit-width integer buckets (the accepted-per-verify layout) make
    nearest-rank quantiles exact, not just bucket-resolved."""
    h = Histogram(tuple(float(i) for i in range(9)))
    data = [0, 1, 1, 2, 2, 2, 3, 5, 8]
    for v in data:
        h.observe(v)
    for p in (0, 25, 50, 75, 95, 100):
        assert h.quantile(p) == percentile(data, p)


def test_histogram_never_windows():
    """A ring percentile silently becomes windowed past capacity; the
    histogram keeps the whole run."""
    ring = RingBuffer(capacity=16)
    hist = Histogram()
    for v in [5.0] * 100 + [1e-4] * 16:     # old mass: 5s, recent: 100us
        ring.append(v)
        hist.observe(v)
    assert percentile(ring, 95) == pytest.approx(1e-4)   # window-blind
    assert hist.quantile(95) >= 5.0                      # whole-run
    assert hist.count == 116 and len(ring) == 16


def test_log_buckets_and_counter_gauge():
    bs = log_buckets(1e-3, 1.0, per_decade=3)
    assert bs[0] == pytest.approx(1e-3) and bs[-1] == pytest.approx(1.0)
    assert list(bs) == sorted(bs) and len(bs) == 10
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc(); c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7); g.set(-2)
    assert g.value == -2
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("c_total")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")


def test_registry_render_roundtrip():
    reg = MetricsRegistry()
    reg.counter("x_total", "a counter").inc(3)
    reg.gauge("y").set(1.5)
    h = reg.histogram("z_seconds", bounds=(0.1, 1.0))
    h.observe(0.05); h.observe(0.5); h.observe(99.0)
    text = reg.render()
    assert validate_exposition(text) > 0
    types, samples = parse_exposition(text)
    assert types == {"x_total": "counter", "y": "gauge",
                     "z_seconds": "histogram"}
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["x_total"] == [({}, 3.0)]
    assert by_name["z_seconds_count"] == [({}, 3.0)]
    les = {ls["le"]: v for ls, v in by_name["z_seconds_bucket"]}
    assert les == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}   # cumulative


def test_validate_exposition_rejects_malformed():
    with pytest.raises(ValueError, match="no samples"):
        validate_exposition("")
    with pytest.raises(ValueError, match="TYPE"):
        validate_exposition("orphan 1\n")
    bad_hist = ("# TYPE h histogram\n"
                'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
                "h_sum 1\nh_count 3\n")
    with pytest.raises(ValueError, match="not monotone"):
        validate_exposition(bad_hist)
    missing_inf = ("# TYPE h histogram\n"
                   'h_bucket{le="1"} 1\nh_sum 1\nh_count 1\n')
    with pytest.raises(ValueError, match=r"\+Inf"):
        validate_exposition(missing_inf)


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

def test_clock_monotonic_and_wall():
    a, b = obs.now(), obs.now()
    assert b >= a
    import time
    assert abs(obs.to_wall(obs.now()) - time.time()) < 1.0


# ---------------------------------------------------------------------------
# tracer / event log
# ---------------------------------------------------------------------------

def test_tracer_schema_and_thread_names():
    tr = SpanTracer()
    t0 = obs.now()
    tr.thread_name(3, "req 2")
    tr.thread_name(3, "renamed")            # first name wins, no dup M
    tr.complete("decode_step", t0, t0 + 1e-3, active=2, rung=1)
    tr.instant("finish", tid=3, reason="eos")
    tr.counter("engine_load", queue_depth=4, occupancy=2)
    doc = tr.to_dict()
    assert validate_chrome_trace(doc) == len(tr)
    names = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(names) == 2                  # engine tid 0 + tid 3, once
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert span["dur"] == pytest.approx(1e3)     # us
    assert span["args"] == {"active": 2, "rung": 1}
    # exported file parses back through the same validator
    json.dumps(doc)


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})
    with pytest.raises(ValueError, match="non-empty"):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="bad phase"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "?", "name": "x", "pid": 1, "tid": 0, "ts": 0}]})
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "x", "pid": 1, "tid": 0, "ts": 0,
             "dur": -1}]})


def test_event_log_ring_sink_and_filter(tmp_path):
    sink = tmp_path / "events.jsonl"
    with EventLog(capacity=4, sink=str(sink)) as ev:
        for i in range(10):
            ev.emit("tick", i=i)
        ev.emit("rung_switch", from_rung=0, to_rung=1, reason="tpot")
    assert ev.count == 11 and len(ev) == 4          # ring kept the tail
    assert [e["i"] for e in ev.events("tick")] == [7, 8, 9]
    sw = ev.events("rung_switch")[0]
    assert sw["reason"] == "tpot" and "t" in sw
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert len(lines) == 11                         # sink got everything
    assert lines[-1]["kind"] == "rung_switch"


def test_event_log_sink_rotation_preserves_ring(tmp_path):
    """A byte-capped sink rotates to <path>.1 instead of growing without
    bound — and rotation must never drop events from the in-memory ring
    view (the ring is capacity-bounded, not byte-bounded)."""
    sink = tmp_path / "events.jsonl"
    with EventLog(capacity=64, sink=str(sink), max_sink_bytes=512) as ev:
        for i in range(40):
            ev.emit("tick", i=i)
        assert ev.sink_rotations >= 1
        assert sink.stat().st_size <= 512
        rotated = tmp_path / "events.jsonl.1"
        assert rotated.exists()
        # the live file + the rotation hold a contiguous tail of events
        recent = [json.loads(ln)["i"]
                  for ln in rotated.read_text().splitlines()
                  + sink.read_text().splitlines()]
        assert recent == list(range(40 - len(recent), 40))
        # the ring view is untouched by rotation: all 40, in order
        assert [e["i"] for e in ev.events("tick")] == list(range(40))
        assert ev.count == 40

    # rotation needs a log-owned path sink — a file handle can't be
    # renamed out from under its owner
    with pytest.raises(ValueError, match="path sink"):
        EventLog(sink=open(tmp_path / "h.jsonl", "w"), max_sink_bytes=10)
    with pytest.raises(ValueError, match=">= 0"):
        EventLog(sink=str(sink), max_sink_bytes=-1)


def test_event_log_rotation_boundary_exact(tmp_path):
    """Rotation happens strictly *before* the write that would overflow
    the budget: no line is ever split across the rotation, a write that
    lands exactly at the cap does not rotate, and every event appears
    exactly once across <path>.1 + <path>."""
    sink = tmp_path / "events.jsonl"
    # fixed-width payloads (explicit t) make every line the same length
    with EventLog(capacity=256, sink=str(sink)) as probe:
        probe.emit("e", t=0.0, i="0000")
    line_len = len((tmp_path / "events.jsonl").read_bytes())

    sink = tmp_path / "boundary.jsonl"
    rotated = tmp_path / "boundary.jsonl.1"
    with EventLog(capacity=256, sink=str(sink),
                  max_sink_bytes=3 * line_len) as ev:
        for i in range(3):                      # fills the file exactly
            ev.emit("e", t=0.0, i=f"{i:04d}")
        assert ev.sink_rotations == 0           # at the cap, not over it
        assert sink.stat().st_size == 3 * line_len
        ev.emit("e", t=0.0, i="0003")           # would overflow: rotates
        assert ev.sink_rotations == 1
        assert rotated.stat().st_size == 3 * line_len
        assert sink.stat().st_size == line_len  # whole line, new file
        for i in range(4, 9):                   # drive a second rotation
            ev.emit("e", t=0.0, i=f"{i:04d}")
        assert ev.sink_rotations == 2

    # both files parse end to end; the union is a contiguous, duplicate-
    # free tail (earlier history was dropped with the replaced .1 —
    # the documented disk budget, never a torn or double-written line)
    tail = [json.loads(ln)["i"] for ln in
            rotated.read_text().splitlines() + sink.read_text().splitlines()]
    assert tail == [f"{i:04d}" for i in range(9 - len(tail), 9)]
    assert len(set(tail)) == len(tail)
    # the ring still holds everything, unaffected by disk rotation
    assert [e["i"] for e in ev.events("e")] == [f"{i:04d}" for i in range(9)]


def test_event_log_oversized_line_still_recorded(tmp_path):
    """A single event bigger than the whole byte budget is still
    written intact (rotated onto a fresh file that then exceeds the
    cap) — bounding disk truncates history (older lines leave with the
    replaced ``.1``), never an individual line."""
    sink = tmp_path / "big.jsonl"
    with EventLog(capacity=8, sink=str(sink), max_sink_bytes=64) as ev:
        ev.emit("small", t=0.0, i=0)
        ev.emit("big", t=0.0, blob="x" * 300)   # rotates, then overflows
        ev.emit("small", t=0.0, i=1)            # rotates the big line out
    assert ev.sink_rotations == 2
    lines = [json.loads(ln) for ln in
             (tmp_path / "big.jsonl.1").read_text().splitlines()
             + sink.read_text().splitlines()]
    # the disk holds a contiguous tail with the oversized line whole
    assert [e["kind"] for e in lines] == ["big", "small"]
    assert len(lines[0]["blob"]) == 300 and lines[1]["i"] == 1
    # the ring saw everything regardless
    assert [e["kind"] for e in ev.events()] == ["small", "big", "small"]


# ---------------------------------------------------------------------------
# null path
# ---------------------------------------------------------------------------

def test_null_telemetry_is_allocation_free():
    assert not NULL_TELEMETRY.enabled
    assert NULL_TELEMETRY.tracer is None and NULL_TELEMETRY.events is None
    # annotate returns the one shared reusable null context, not a fresh
    # object per call — the hot path allocates nothing when disabled
    assert NULL_TELEMETRY.annotate("repro/decode") is NULL_CONTEXT
    assert NULL_TELEMETRY.annotate("x") is NULL_TELEMETRY.annotate("y")
    with NULL_TELEMETRY.annotate("a"), NULL_TELEMETRY.annotate("b"):
        pass                                    # reentrant
    NULL_TELEMETRY.close()                      # harmless


def test_engine_defaults_to_null_telemetry(model):
    params, cfg = model
    eng = _engine(params, cfg)
    assert eng.obs is NULL_TELEMETRY
    with pytest.raises(TypeError, match="Telemetry"):
        Engine(params, cfg, EngineConfig(max_slots=2, max_len=32,
                                         prefill_chunk=8),
               telemetry="yes")


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_telemetry_parity_and_artifacts(model, tmp_path):
    """Full telemetry changes no tokens, keeps annotated decode
    retrace-free, and produces valid exposition + trace artifacts."""
    params, cfg = model
    prompts = [_prompts(cfg, 1, n)[0] for n in (9, 17, 5, 13)]

    def run(tel):
        eng = _engine(params, cfg, telemetry=tel)
        eng.warmup()
        for p in prompts:
            eng.submit(p, 8)
        return eng, eng.run()

    e0, out0 = run(None)
    tel = Telemetry.full(events_sink=str(tmp_path / "events.jsonl"))
    e1, out1 = run(tel)
    assert out1 == out0, "telemetry must only observe"
    assert e1.decode_retraces_after_warmup == 0

    # exposition: validates, and counters match the engine's stats
    text = e1.metrics_exposition()
    assert validate_exposition(text) > 0
    _, samples = parse_exposition(text)
    flat = {n: v for n, ls, v in samples if not ls}
    assert flat["repro_requests_finished_total"] == e1.stats.finished
    assert flat["repro_decode_tokens_total"] == e1.stats.decode_tokens
    assert flat["repro_tpot_seconds_count"] == e1.stats.tpot_hist.count
    assert flat["repro_decode_retraces_after_warmup_total"] == 0

    # trace: schema-valid, per-request lifecycle present on its track
    path = tmp_path / "trace.json"
    tel.tracer.export(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == len(tel.tracer.events)
    for rid in range(len(prompts)):
        kinds = [e["name"] for e in doc["traceEvents"]
                 if e.get("tid") == rid + 1 and e["ph"] in ("i", "X")]
        assert kinds[0] == "submit" and "finish" in kinds
        assert "prefill_chunk" in kinds and "first_token" in kinds
    assert any(e["name"] == "decode_step" and e["ph"] == "X"
               for e in doc["traceEvents"])
    tel.close()

    # snapshot v4+ fields (v5 added the admission/preemption block,
    # v6 the quality-probe block, v7 the flight block — both absent
    # here: no QualityMonitor or FlightRecorder armed)
    snap = e1.snapshot()
    assert snap["schema_version"] == 7
    assert "quality_probes" not in snap
    assert "flight_records" not in snap
    assert snap["telemetry_spans"] == len(tel.tracer.events)
    assert snap["tpot_p95_s"] >= snap["tpot_p50_s"]
    assert "tpot_p95_window_s" in snap


def test_summary_reports_both_estimators(model):
    s = EngineStats()
    for v in (0.01, 0.02, 0.03):
        s.observe_tpot(v)
    out = s.summary()
    assert out["tpot_p95_s"] == pytest.approx(
        s.tpot_hist.quantile(95), rel=1e-3)
    assert out["tpot_p95_window_s"] == pytest.approx(
        percentile(s.tpot_s, 95), rel=1e-3)
    assert s.tpot_percentile(95) == s.tpot_hist.quantile(95)


def test_forced_rung_switch_lands_in_event_log(model, ladder):
    """An unmeetable SLO forces escalation; the event log records the
    switch with the controller's reason."""
    params, cfg = model
    tel = Telemetry(events=EventLog())
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8,
        slo=SLOConfig(tpot_p95=1e-9, dwell=1)), ladder=ladder,
        telemetry=tel)
    eng.warmup()
    eng.submit(_prompts(cfg, 1, 9)[0], 12)
    eng.run()
    switches = tel.events.events("rung_switch")
    assert switches, "unmeetable SLO never escalated"
    sw = switches[0]
    assert sw["from_rung"] == 0 and sw["to_rung"] == 1
    assert sw["reason"] == "tpot"
    assert eng.controller.snapshot()["tpot_estimator"] == "ewma"
    assert eng.decode_retraces_after_warmup == 0
    # compile events recorded during warmup, none flagged post-warmup
    compiles = tel.events.events("compile")
    assert compiles and all(not c["post_warmup"] for c in compiles)


def test_spec_rollback_lands_in_event_log(model, ladder):
    """Force every draft to disagree with the verifier (shifted draft
    logits), so each spec round must roll back drafted KV — and the
    event log must record it with slot/request attribution."""
    import jax.numpy as jnp

    params, cfg = model
    tel = Telemetry(events=EventLog(), tracer=SpanTracer())
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8,
        spec=SpecConfig(gamma=2, drafter_rung=1)), ladder=ladder,
        telemetry=tel)
    # drafting routes through eng._dstep; verify uses its own executable,
    # so rolling the draft logits breaks only the drafts (argmax + 1 mod
    # vocab never matches the verifier) — acceptance is exactly zero
    real_dstep = eng._dstep

    def shifted(params, tokens, positions, caches, sp, weights, *, policy):
        logits, caches = real_dstep(params, tokens, positions, caches,
                                    sp, weights, policy=policy)
        return jnp.roll(logits, 1, axis=-1), caches

    eng._dstep = shifted
    eng.submit(_prompts(cfg, 1, 9)[0], 10)
    out = eng.run()
    rb = tel.events.events("kv_rollback")
    assert rb, "zero acceptance produced no rollback events"
    ev = rb[0]
    assert ev["slot"] == 0 and ev["request"] == 0 and ev["gamma"] == 2
    assert ev["accepted"] == 0 and ev["committed"] == 1
    assert ev["tokens"] == ev["gamma"] + 1 - ev["committed"] == 2
    assert len(out[0]) == 10
    # spec phases land as engine-track spans
    names = {e["name"] for e in tel.tracer.events if e["ph"] == "X"}
    assert {"spec_draft", "spec_verify", "spec_commit"} <= names


def test_prefix_eviction_lands_in_event_log(model):
    """A tiny cached-token budget forces LRU eviction on publish; the
    event carries segment accounting."""
    params, cfg = model
    tel = Telemetry(events=EventLog(), tracer=SpanTracer())
    eng = Engine(params, cfg, EngineConfig(
        max_slots=2, max_len=32, prefill_chunk=8, prefix_cache=True,
        prefix_cache_tokens=16), telemetry=tel)
    for step in (0, 1):                     # two unrelated prompts
        eng.submit(_prompts(cfg, 1, 16, step=step)[0], 4)
        eng.run()
    evs = tel.events.events("prefix_evict")
    assert evs, "over-budget publishes never evicted"
    assert evs[0]["segments"] >= 1
    assert 8 <= sum(e["tokens"] for e in evs) <= 16
    assert evs[0]["cached_tokens"] <= 16
    assert evs[0]["trigger_request"] == 1
    # the admission consult is traced whether it hits or misses
    lookups = [e for e in tel.tracer.events
               if e.get("name") == "prefix_lookup"]
    assert lookups and lookups[0]["args"]["hit"] is False


def test_metrics_http_endpoint(model):
    params, cfg = model
    eng = _engine(params, cfg)
    eng.submit(_prompts(cfg, 1, 9)[0], 4)
    eng.run()
    server = serve_metrics(eng.metrics_exposition, port=0)
    try:
        url = f"http://127.0.0.1:{server.server_port}/metrics"
        with urllib.request.urlopen(url) as resp:
            assert resp.status == 200
            assert "0.0.4" in resp.headers["Content-Type"]
            body = resp.read().decode()
        assert validate_exposition(body) > 0
        assert "repro_decode_tokens_total" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{server.server_port}/nope")
    finally:
        server.shutdown()
