"""Checkpoint manager + fault-tolerant runner tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.fault_tolerance import (FailureInjector, Preemption,
                                               RunnerConfig, TrainingRunner)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = _tree()
    m.save(7, t)
    restored, meta = m.restore(t)
    assert meta["step"] == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        t, restored)


def test_keep_k_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, t)
    assert m.all_steps() == [3, 4]
    assert os.path.islink(os.path.join(str(tmp_path), "latest"))


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    m.save(1, t)
    m.wait()
    assert m.latest_step() == 1


def test_restore_empty(tmp_path):
    m = CheckpointManager(str(tmp_path))
    restored, meta = m.restore(_tree())
    assert restored is None and meta is None


def _counter_runner(tmp_path, fail_at=(), total=20, every=5):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    runner = TrainingRunner(
        RunnerConfig(total_steps=total, checkpoint_every=every),
        ckpt, injector=FailureInjector(fail_at) if fail_at else None,
        log=lambda *a: None)

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    def batch_fn(step):
        return jnp.float32(step)          # sum of 0..total-1 expected

    return runner.run({"x": jnp.float32(0)}, step_fn, batch_fn)


def test_runner_uninterrupted(tmp_path):
    out = _counter_runner(tmp_path / "a")
    assert float(out["x"]) == sum(range(20))


def test_runner_preemption_resumes_exactly(tmp_path):
    """A preempted run must produce bit-identical final state (checkpoint +
    deterministic data replay)."""
    clean = _counter_runner(tmp_path / "clean")
    failed = _counter_runner(tmp_path / "fail", fail_at=(7, 13))
    assert float(clean["x"]) == float(failed["x"])


def test_runner_too_many_restarts(tmp_path):
    import pytest
    with pytest.raises(Preemption):
        ckpt = CheckpointManager(str(tmp_path), keep=1)
        runner = TrainingRunner(
            RunnerConfig(total_steps=5, checkpoint_every=100, max_restarts=1),
            ckpt, injector=FailureInjector((0, 1, 2)), log=lambda *a: None)
        # never checkpoints before failing -> restarts from scratch and
        # keeps hitting new injected failures past max_restarts
        runner.run({"x": jnp.float32(0)},
                   lambda s, b: (s, {}), lambda s: jnp.float32(0))
