"""Coverage for the perf-phase execution paths: aligned batched decode,
balanced grouped top-k gather, fused projections."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import sparse_linear as sl
from repro.models import api, model as M
import repro.models.params as P


def _pad_caches(cfg, caches, B, T):
    target = P.abstract_params(api.cache_schema(cfg, B, T), cfg.dtype)

    def fit(src, dst):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for s, d in zip(src.shape, dst.shape)]
        return jnp.pad(src, pads).astype(dst.dtype)

    return jax.tree_util.tree_map(fit, caches, target)


def test_aligned_decode_matches_unaligned():
    """aligned_decode (single DUS cache writes) must be numerically
    identical to the general per-sequence path when positions agree."""
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    B, S, T = 2, 20, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    _, caches = M.forward(params, cfg, tokens=toks[:, :-1], mode="prefill")
    caches = _pad_caches(cfg, caches, B, T)
    pos = jnp.full((B,), S - 1, jnp.int32)
    lo, c0 = M.forward(params, cfg, tokens=toks[:, -1], mode="decode",
                       caches=caches, positions=pos)
    la, c1 = M.forward(params, cfg, tokens=toks[:, -1], mode="decode",
                       caches=caches, positions=pos, aligned=True)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(la), atol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-6),
        c0, c1)


def test_aligned_decode_rolling_window():
    cfg = reduced(get_config("gemma3_4b"))
    params = api.init_model(cfg, 0)
    B, S = 2, 60                      # window 32 < S -> rolling caches
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, tokens=toks, mode="train")
    _, caches = M.forward(params, cfg, tokens=toks[:, :-1], mode="prefill")
    caches = _pad_caches(cfg, caches, B, 64)
    logits, _ = M.forward(params, cfg, tokens=toks[:, -1], mode="decode",
                          caches=caches,
                          positions=jnp.full((B,), S - 1, jnp.int32),
                          aligned=True)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_grouped_gather_matches_global_budget():
    """Balanced per-shard selection keeps the same global channel budget
    and stays close to the global top-k output (beyond-paper A3)."""
    k = jax.random.PRNGKey(0)
    B, n, m, G = 4, 512, 128, 16
    x = jax.random.normal(k, (B, n))
    w = jax.random.normal(jax.random.fold_in(k, 1), (n, m)) * 0.1
    sp = sl.default_sp(w)
    sp = {**sp, "keep_frac": jnp.float32(0.5)}
    pol = sl.SparsityPolicy.uniform("topk_shared", k_max_frac=0.5)
    y_global = sl._topk_gather(x, w, sp, pol, groups=1)
    y_grouped = sl._topk_gather(x, w, sp, pol, groups=G)
    y_dense = x @ w
    # both sparse outputs approximate dense comparably
    e_g = float(jnp.linalg.norm(y_global - y_dense))
    e_b = float(jnp.linalg.norm(y_grouped - y_dense))
    assert e_b < 2.0 * e_g + 1e-6
    # full keep: both are exact
    sp1 = {**sp, "keep_frac": jnp.float32(1.0)}
    pol1 = sl.SparsityPolicy.uniform("topk_shared", k_max_frac=1.0)
    yg = sl._topk_gather(x, w, sp1, pol1, groups=G)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_fused_qkv_matches_separate():
    """The fused dense-path projections (B3) must match the separate
    (sparse/calibration) path exactly."""
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                              cfg.vocab_size)
    fused, _ = M.forward(params, cfg, tokens=toks, mode="train")
    # a capture sink on the policy forces the separate (unfused) path
    cap_pol = sl.SparsityPolicy.dense(capture=sl.CaptureSink())
    sep, _ = M.forward(params, cfg, tokens=toks, mode="train",
                       policy=cap_pol)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(sep),
                               rtol=1e-5, atol=1e-5)
