"""Continuous-batching engine: parity with the legacy generate() loop,
ragged/mid-flight admission, slot reclamation, and decode jit-stability."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api
from repro.serving import Engine, EngineConfig, SlotKVPool, Status
from repro.sparsity import SparsityPolicy


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _engine(params, cfg, sp=None, **kw):
    defaults = dict(max_slots=4, max_len=32, prefill_chunk=8)
    defaults.update(kw)
    return Engine(params, cfg, EngineConfig(**defaults), sp)


# ---------------------------------------------------------------------------
# exact parity with the legacy static-batch loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,keep", [("off", 1.0),
                                          ("topk_shared", 0.5)])
def test_engine_matches_legacy_generate(model, backend, keep):
    """Equal-length prompts through the whole-prefill engine produce the
    exact tokens of the legacy generate() loop, dense and sparse."""
    params, cfg = model
    prompts = _prompts(cfg, 4, 16)
    sp = default_sp_stacked(params, cfg, keep_frac=keep) \
        if backend != "off" else None
    policy = SparsityPolicy.uniform(backend, k_max_frac=keep)
    legacy = np.asarray(generate(params, cfg, jnp.asarray(prompts), 8, sp,
                                 policy=policy))
    eng = _engine(params, cfg, sp, policy=policy,
                  prefill_strategy="whole", prefill_dense_frac=1.0)
    for b in range(4):
        eng.submit(prompts[b], 8)
    out = eng.run()
    for b in range(4):
        assert out[b] == list(legacy[b]), f"request {b} diverged"


def test_chunked_prefill_matches_whole(model):
    """Chunked prefill (in-place pool writes) agrees with the legacy
    whole-prompt prefill + insertion on the same requests."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 24, step=3)
    outs = []
    for strategy in ("whole", "chunked"):
        eng = _engine(params, cfg, max_slots=2, max_len=32, prefill_chunk=8,
                      prefill_strategy=strategy)
        eng.submit(prompts[0], 6)
        eng.submit(prompts[1], 6)
        outs.append(eng.run())
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# continuous batching mechanics
# ---------------------------------------------------------------------------

def test_ragged_midflight_and_slot_reuse(model):
    """Ragged prompt lengths, more requests than slots, and a mid-flight
    submission: everything finishes, slots are reclaimed, and the decode
    step traces exactly once."""
    params, cfg = model
    prompts = _prompts(cfg, 4, 20, step=7)
    eng = _engine(params, cfg, max_slots=2, max_len=32, prefill_chunk=8,
                  prefill_strategy="chunked")
    lens = [9, 14, 20]
    for b, L in enumerate(lens):
        eng.submit(prompts[b][:L], 5)
    for _ in range(6):                       # start prefill/decode
        eng.step()
    late = eng.submit(prompts[3][:11], 5)    # mid-flight admission
    out = eng.run()
    assert set(out) == {0, 1, 2, 3}
    assert all(len(toks) == 5 for toks in out.values())
    assert all(rs.status == Status.FINISHED for rs in eng.states.values())
    assert late.tokens == out[3]
    assert eng.pool.num_free == 2            # all slots reclaimed
    assert eng.decode_traces == 1            # no retrace after warmup
    assert eng.stats.finished == 4
    assert eng.stats.decode_tokens == 20


def test_eos_stop_and_streaming(model):
    """EOS stops a request early; the streaming callback sees every token
    in order."""
    params, cfg = model
    prompts = _prompts(cfg, 1, 12, step=11)
    eng = _engine(params, cfg)
    eng.submit(prompts[0], 6)
    ref = eng.run()[0]
    assert len(ref) == 6

    seen = []
    eng2 = _engine(params, cfg)
    # pick an EOS whose first occurrence is unambiguous: greedy tokens on
    # a random-init model can repeat, and the engine (correctly) stops at
    # the *first* occurrence of the EOS id
    k = next((i for i in range(1, len(ref)) if ref[i] not in ref[:i]), None)
    if k is None:
        pytest.skip("every generated token repeats; no unambiguous EOS")
    rs = eng2.submit(prompts[0], 6, eos_id=ref[k],
                     on_token=lambda rid, t: seen.append((rid, t)))
    out = eng2.run()
    assert out[0] == ref[:k + 1]             # stopped at the EOS token
    assert rs.finish_reason.value == "eos"
    assert seen == [(0, t) for t in ref[:k + 1]]


def test_moe_and_ssm_archs_serve_sparse():
    """The engine serves MoE (expert projections opt out of slot-weighted
    saliency) and SSM archs (whole-prefill fallback) under a sparse
    backend with partially occupied slots."""
    for arch in ("granite_moe_1b_a400m", "mamba2_130m"):
        cfg = reduced(get_config(arch))
        params = api.init_model(cfg, 0)
        sp = default_sp_stacked(params, cfg, keep_frac=0.5)
        eng = Engine(params, cfg, EngineConfig(
            max_slots=3, max_len=24, prefill_chunk=8,
            policy=SparsityPolicy.uniform("topk_shared",
                                          k_max_frac=0.5)), sp)
        prompts = _prompts(cfg, 2, 10, step=17)
        eng.submit(prompts[0], 4)
        eng.submit(prompts[1][:7], 4)        # ragged + a free slot
        out = eng.run()
        assert all(len(t) == 4 for t in out.values()), arch
        assert eng.pool.num_free == 3


def test_pool_alloc_free_cycle(model):
    _, cfg = model
    pool = SlotKVPool(cfg, max_slots=3, max_len=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2] and pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(slots[1])
    assert pool.num_free == 1
    assert pool.alloc() == slots[1]


def test_engine_stats_and_phase_times(model):
    params, cfg = model
    prompts = _prompts(cfg, 2, 16, step=13)
    eng = _engine(params, cfg, max_slots=2)
    eng.submit(prompts[0], 4)
    eng.submit(prompts[1], 4)
    eng.run()
    s = eng.stats.summary()
    assert s["finished"] == 2
    assert s["decode_tokens"] == 8
    assert s["decode_tps"] > 0 and s["prefill_tps"] > 0
    assert eng.stats.prefill_tokens == 32
    for rs in eng.states.values():
        assert rs.first_token_time is not None
        assert rs.finish_time >= rs.first_token_time
