"""Speculative decoding: verify-step equivalence, KV rollback invariants,
engine-level token parity with verifier-only decode, retrace-free gamma
switching, pool slot-state guards and the acceptance controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.sp_schema import default_sp_stacked
from repro.data import DataConfig, SyntheticLM
from repro.models import api
from repro.serving import (SNAPSHOT_SCHEMA_VERSION, Engine, EngineConfig,
                           SlotKVPool, SpecConfig, SpecController)
from repro.sparsity import PolicyLadder, SparsityPolicy


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    return params, cfg


@pytest.fixture(scope="module")
def ladder(model):
    params, cfg = model
    return PolicyLadder.uniform(params, cfg, (0.0, 0.5))


def _prompts(cfg, n, seq, step=0):
    return np.asarray(SyntheticLM(
        DataConfig(cfg.vocab_size, seq, n)).batch(step))


def _copy(tree):
    return jax.tree_util.tree_map(lambda x: jnp.array(x), tree)


def _prefill_slot(params, cfg, pool, slot, prompt):
    """Chunk-prefill one prompt into an allocated pool slot."""
    chunk = jax.jit(api.make_chunk_prefill_step(cfg),
                    static_argnames=("policy",))
    P = prompt.shape[0]
    _, pool.caches = chunk(
        params, jnp.asarray(prompt[None]), jnp.zeros((1,), jnp.int32),
        jnp.int32(slot), pool.caches, None, jnp.ones((P,), jnp.float32),
        policy=SparsityPolicy.dense())
    pool.lengths[slot] = P


# ---------------------------------------------------------------------------
# pool slot-state guards + length bookkeeping
# ---------------------------------------------------------------------------

def test_pool_guards(model):
    _, cfg = model
    pool = SlotKVPool(cfg, max_slots=2, max_len=8)
    slot = pool.alloc()
    pool.free(slot)
    with pytest.raises(ValueError, match=f"slot {slot}"):
        pool.free(slot)                          # double free
    with pytest.raises(ValueError, match="not allocated"):
        pool.insert(pool.caches, 0, slot, 4)     # insert into a free slot
    with pytest.raises(ValueError, match="not allocated"):
        pool.commit(slot, 1)
    with pytest.raises(ValueError, match="not allocated"):
        pool.rollback(slot, 0)
    slot = pool.alloc()
    with pytest.raises(ValueError, match="negative"):
        pool.commit(slot, -1)
    with pytest.raises(ValueError, match="exceeds"):
        pool.commit(slot, 9)                     # past the pool length
    pool.commit(slot, 5)
    with pytest.raises(ValueError, match="roll back"):
        pool.rollback(slot, 6)                   # more than committed
    pool.rollback(slot, 2)
    assert pool.lengths[slot] == 3
    with pytest.raises(ValueError, match="outside"):
        pool.free(99)


def test_commit_rollback_property(model):
    """rollback(n) o commit(m) bookkeeping: the pool's per-slot length
    always matches a pure-python model, and out-of-bounds ops raise
    without corrupting it."""
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings, strategies as st
    _, cfg = model
    pool = SlotKVPool(cfg, max_slots=1, max_len=8)

    @given(st.lists(st.tuples(st.sampled_from(["commit", "rollback"]),
                              st.integers(0, 10)), max_size=8))
    @settings(deadline=None, max_examples=20)
    def run(ops):
        slot = pool.alloc()
        length = 0
        try:
            for op, n in ops:
                if op == "commit":
                    if length + n <= pool.max_len:
                        pool.commit(slot, n)
                        length += n
                    else:
                        with pytest.raises(ValueError):
                            pool.commit(slot, n)
                else:
                    if n <= length:
                        pool.rollback(slot, n)
                        length -= n
                    else:
                        with pytest.raises(ValueError):
                            pool.rollback(slot, n)
                assert pool.lengths[slot] == length
        finally:
            pool.free(slot)

    run()


# ---------------------------------------------------------------------------
# the core spec-decode invariants, at the pool/step level
# ---------------------------------------------------------------------------

def test_draft_rollback_redecode_bitwise(model):
    """Decoding T tokens plainly vs drafting T tokens (sparse drafter,
    garbage KV), rolling them back, then redecoding the same T tokens
    must produce bit-identical caches AND logits — rejected drafts leave
    no trace."""
    params, cfg = model
    T, P = 4, 10
    sp = default_sp_stacked(params, cfg, keep_frac=0.5)
    sparse = SparsityPolicy.uniform("topk_shared", k_max_frac=0.5)
    dense = SparsityPolicy.dense()
    dstep = jax.jit(api.make_slot_decode_step(cfg),
                    static_argnames=("policy",))

    pool = SlotKVPool(cfg, max_slots=2, max_len=24)
    slot = pool.alloc()
    prompt = _prompts(cfg, 1, P, step=5)[0]
    _prefill_slot(params, cfg, pool, slot, prompt)
    state0 = _copy(pool.caches)

    toks = _prompts(cfg, 1, T, step=9)[0]        # teacher-forced tokens
    active = jnp.asarray(np.eye(2, dtype=np.float32)[slot])

    def decode_T(caches):
        logits = []
        for i in range(T):
            tv = np.zeros((2,), np.int32)
            tv[slot] = toks[i]
            pos = np.full((2,), pool.max_len - 1, np.int32)
            pos[slot] = P + i
            lg, caches = dstep(params, jnp.asarray(tv), jnp.asarray(pos),
                               caches, None, active, policy=dense)
            logits.append(np.asarray(lg[slot]))
        return logits, caches

    # path A: plain decode
    logits_a, caches_a = decode_T(_copy(state0))

    # path B: draft T tokens sparsely, roll them back, redecode
    pool.caches = _copy(state0)
    for i in range(T):
        tv = np.zeros((2,), np.int32)
        tv[slot] = toks[i]
        pos = np.full((2,), pool.max_len - 1, np.int32)
        pos[slot] = P + i
        _, pool.caches = dstep(params, jnp.asarray(tv), jnp.asarray(pos),
                               pool.caches, sp, active, policy=sparse)
    pool.commit(slot, T)
    pool.rollback(slot, T)
    assert pool.lengths[slot] == P
    logits_b, caches_b = decode_T(pool.caches)

    for i in range(T):
        np.testing.assert_array_equal(logits_a[i], logits_b[i])
    for a, b in zip(jax.tree_util.tree_leaves(caches_a),
                    jax.tree_util.tree_leaves(caches_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_step_matches_sequential_decode(model):
    """One batched (gamma+1)-token verify forward produces the same greedy
    tokens (and near-identical logits) as gamma+1 sequential decode steps
    over the same tokens — the equivalence the engine-level parity gate
    rests on."""
    params, cfg = model
    g1, P = 4, 8
    dense = SparsityPolicy.dense()
    dstep = jax.jit(api.make_slot_decode_step(cfg),
                    static_argnames=("policy",))
    vstep = jax.jit(api.make_verify_step(cfg), static_argnames=("policy",))

    pool = SlotKVPool(cfg, max_slots=3, max_len=20)
    prompts = _prompts(cfg, 2, P, step=2)
    slots = [pool.alloc(), pool.alloc()]         # slot 2 stays empty
    for s, pr in zip(slots, prompts):
        _prefill_slot(params, cfg, pool, s, pr)
    state0 = _copy(pool.caches)

    toks = _prompts(cfg, 3, g1, step=4).T        # (g1, 3) teacher-forced
    active = np.zeros((3,), np.float32)
    active[slots] = 1.0

    seq_logits = []
    caches = _copy(state0)
    for i in range(g1):
        pos = np.full((3,), pool.max_len - 1, np.int32)
        for s in slots:
            pos[s] = P + i
        lg, caches = dstep(params, jnp.asarray(toks[i].copy()),
                           jnp.asarray(pos), caches, None,
                           jnp.asarray(active), policy=dense)
        seq_logits.append(np.asarray(lg))

    vt = toks.T.copy()                           # (3, g1)
    pos = np.full((3,), pool.max_len - g1, np.int32)
    for s in slots:
        pos[s] = P
    wts = np.repeat(active[:, None], g1, axis=1)
    vlg, _ = vstep(params, jnp.asarray(vt), jnp.asarray(pos), state0,
                   None, jnp.asarray(wts), policy=dense)
    vlg = np.asarray(vlg)

    for s in slots:
        for i in range(g1):
            a, b = seq_logits[i][s], vlg[s, i]
            assert a.argmax() == b.argmax(), (s, i)
            np.testing.assert_allclose(a, b, atol=1e-4)


# ---------------------------------------------------------------------------
# engine-level parity + retrace discipline
# ---------------------------------------------------------------------------

def _ladder_engine(model, ladder, spec=None, **kw):
    params, cfg = model
    defaults = dict(max_slots=2, max_len=32, prefill_chunk=8, spec=spec)
    defaults.update(kw)
    eng = Engine(params, cfg, EngineConfig(**defaults), ladder=ladder)
    if spec is None:
        eng.warmup()
    return eng


def test_spec_engine_token_parity(model, ladder):
    """Ragged prompts, more requests than slots, a mid-flight submission:
    the spec engine's outputs are token-identical to verifier-only decode
    and no decode/verify executable retraces after warmup."""
    params, cfg = model
    prompts = _prompts(cfg, 4, 20, step=7)
    lens = [9, 14, 20, 11]

    def drive(spec):
        eng = _ladder_engine(model, ladder, spec=spec)
        for b in (0, 1, 2):
            eng.submit(prompts[b][:lens[b]], 6)
        for _ in range(6):
            eng.step()
        eng.submit(prompts[3][:lens[3]], 6)      # mid-flight admission
        return eng, eng.run()

    _, ref = drive(None)
    eng, out = drive(SpecConfig(gamma=2, drafter_rung=1))
    assert out == ref
    assert eng.decode_retraces_after_warmup == 0
    assert eng.verify_retraces_after_warmup == 0
    assert eng.pool.num_free == 2
    s = eng.stats
    assert s.spec_rounds > 0
    assert s.spec_committed_tokens == s.decode_tokens - 4  # first tokens
    #                                   come from prefill, not spec rounds
    assert s.spec_accepted_tokens <= s.spec_draft_tokens
    assert len(eng.states[3].token_rungs) == 6   # attributed to verifier


def test_spec_gamma_switch_retrace_free(model, ladder):
    """Adaptive-range warmup precompiles every gamma: switching the draft
    length mid-serve neither retraces nor changes the output tokens."""
    params, cfg = model
    prompts = _prompts(cfg, 2, 12, step=3)
    spec = SpecConfig(gamma=2, drafter_rung=1, adaptive=True,
                      gamma_min=1, gamma_max=3, dwell=10_000)
    eng = _ladder_engine(model, ladder, spec=spec)
    ref = _ladder_engine(model, ladder)

    outs, refs = [], []
    for b, g in ((0, 3), (1, 1)):
        eng.spec_decoder.set_gamma(g)
        rs = eng.submit(prompts[b], 6)
        eng.run()
        outs.append(rs.tokens)
        rr = ref.submit(prompts[b], 6)
        ref.run()
        refs.append(rr.tokens)
    assert outs == refs
    assert eng.decode_retraces_after_warmup == 0
    assert eng.verify_retraces_after_warmup == 0
    with pytest.raises(ValueError, match="gamma"):
        eng.spec_decoder.set_gamma(4)            # beyond the warmed range


def test_spec_eos_stops_like_verifier(model, ladder):
    """An EOS inside a committed draft window stops the request at the
    same token the verifier-only engine stops at."""
    params, cfg = model
    prompts = _prompts(cfg, 1, 12, step=11)
    ref_eng = _ladder_engine(model, ladder)
    ref_eng.submit(prompts[0], 8)
    ref = ref_eng.run()[0]
    k = next((i for i in range(2, len(ref)) if ref[i] not in ref[:i]), None)
    if k is None:
        pytest.skip("every generated token repeats; no unambiguous EOS")
    eng = _ladder_engine(model, ladder,
                         spec=SpecConfig(gamma=3, drafter_rung=1))
    rs = eng.submit(prompts[0], 8, eos_id=ref[k])
    out = eng.run()[0]
    assert out == ref[:k + 1]
    assert rs.finish_reason.value == "eos"
    assert eng.pool.num_free == 2


def test_spec_snapshot_schema(model, ladder):
    eng = _ladder_engine(model, ladder,
                         spec=SpecConfig(gamma=2, drafter_rung=1))
    snap = eng.snapshot()
    assert snap["schema_version"] == SNAPSHOT_SCHEMA_VERSION
    assert snap["spec_gamma"] == 2
    assert snap["spec_drafter_rung"] == 1
    assert "spec_accept_ewma" in snap and "spec_accept_rate" in snap
    plain = _ladder_engine(model, ladder).snapshot()
    assert plain["schema_version"] == SNAPSHOT_SCHEMA_VERSION
    assert "spec_gamma" not in plain


# ---------------------------------------------------------------------------
# configuration validation
# ---------------------------------------------------------------------------

def test_spec_config_validation(model, ladder):
    params, cfg = model
    with pytest.raises(ValueError, match="sparser"):
        SpecConfig(gamma=2, drafter_rung=0)      # drafter == verifier
    with pytest.raises(ValueError, match="gamma"):
        SpecConfig(gamma=0)
    with pytest.raises(ValueError, match="gamma_max"):
        SpecConfig(gamma=5, adaptive=True, gamma_max=4)
    with pytest.raises(ValueError, match="adaptive"):
        SpecConfig(adapt_drafter=True)
    with pytest.raises(ValueError, match="PolicyLadder"):
        Engine(params, cfg,
               EngineConfig(max_slots=2, max_len=32,
                            spec=SpecConfig(gamma=2, drafter_rung=1)))
    with pytest.raises(ValueError, match="outside"):
        _ladder_engine(model, ladder,
                       spec=SpecConfig(gamma=2, drafter_rung=5))
    with pytest.raises(ValueError, match="verifier rung"):
        _ladder_engine(model, ladder, initial_rung=1,
                       spec=SpecConfig(gamma=2, drafter_rung=1))
    # a sparse verifier would break the parity guarantee (shared top-k
    # saliency differs between multi-token verify and single-token decode)
    ladder3 = PolicyLadder.uniform(params, cfg, (0.0, 0.5, 0.75))
    with pytest.raises(ValueError, match="dense verifier"):
        _ladder_engine(model, ladder3, initial_rung=1,
                       spec=SpecConfig(gamma=2, drafter_rung=2,
                                       verifier_rung=1))
    # SSM archs cannot verify (no chunked write-in-place path)
    ssm_cfg = reduced(get_config("mamba2_130m"))
    ssm_params = api.init_model(ssm_cfg, 0)
    ssm_ladder = PolicyLadder.uniform(ssm_params, ssm_cfg, (0.0, 0.5))
    with pytest.raises(ValueError, match="plain-attention"):
        Engine(ssm_params, ssm_cfg,
               EngineConfig(max_slots=2, max_len=32,
                            spec=SpecConfig(gamma=2, drafter_rung=1)),
               ladder=ssm_ladder)


# ---------------------------------------------------------------------------
# acceptance controller
# ---------------------------------------------------------------------------

def test_spec_controller_gamma_dynamics():
    ctl = SpecController(2, 1, 4, drafter_rung=1, drafter_min=1,
                         drafter_max=1, dwell=3)
    # _since_switch starts at dwell: the first high-acceptance tick may act
    assert ctl.update(1.0) == (3, 1)             # high acceptance -> deeper
    assert ctl.accept_ewma is None               # EWMA reset on switch
    assert ctl.update(1.0) == (3, 1)             # dwell holds the next one
    for _ in range(20):
        g, d = ctl.update(1.0)
    assert g == 4                                # saturates at gamma_max
    for _ in range(20):
        g, d = ctl.update(0.0)
    assert g == 1                                # rejections -> gamma_min


def test_spec_controller_dwell_and_drafter():
    ctl = SpecController(1, 1, 1, drafter_rung=2, drafter_min=1,
                         drafter_max=3, adapt_drafter=True, dwell=4)
    assert ctl.update(1.0) == (1, 3)             # gamma maxed -> sparser
    for _ in range(3):
        assert ctl.update(0.0) == (1, 3)         # dwell holds it
    assert ctl.update(0.0) == (1, 2)             # low acceptance -> denser
    for _ in range(20):
        g, d = ctl.update(0.0)
    assert (g, d) == (1, 1)
    snap = ctl.snapshot()
    assert snap["spec_drafter_rung"] == 1
    assert snap["spec_switches"] == len(ctl.transitions)
    with pytest.raises(ValueError, match="gamma"):
        SpecController(3, 1, 2, drafter_rung=1, drafter_min=1,
                       drafter_max=1)
