"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import sparse_linear as sl
from repro.optim import adamw

COMMON = dict(deadline=None, max_examples=25)


@st.composite
def xw(draw, max_n=64, max_m=32):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(2, max_m))
    b = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2**16))
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, n))
    w = jax.random.normal(jax.random.fold_in(k, 1), (n, m)) * 0.2
    return x, w


@given(xw(), st.floats(0.0, 1.5))
@settings(**COMMON)
def test_mask_mode_equals_masked_dense(data, alpha):
    """project(mask) == (x * 1[s>=tau]) @ w exactly (paper Eq. 5)."""
    x, w = data
    g = sl.column_norms(w)
    s = np.asarray(sl.scores(x, g, alpha))
    tau = float(np.median(s))
    sp = {"g": g, "alpha": jnp.float32(alpha), "tau": jnp.float32(tau),
          "keep_frac": jnp.float32(1.0)}
    y = sl.project(x, w, sp, policy=sl.SparsityPolicy.uniform("mask"))
    m = (s >= tau).astype(np.float32)
    np.testing.assert_allclose(np.asarray(y),
                               (np.asarray(x) * m) @ np.asarray(w),
                               rtol=2e-4, atol=2e-4)


@given(xw(), st.floats(0.1, 0.9))
@settings(**COMMON)
def test_threshold_keeps_expected_fraction(data, keep):
    """Eq. 7: tau at the (1-r)-quantile keeps ~r of score mass entries."""
    x, w = data
    g = sl.column_norms(w)
    s = np.asarray(sl.scores(x, g, 1.0)).ravel()
    tau = np.quantile(s, 1.0 - keep)
    frac = float((s >= tau).mean())
    assert abs(frac - keep) < 0.25 + 2.0 / s.size

@given(xw())
@settings(**COMMON)
def test_alpha_zero_is_activation_only(data):
    """alpha=0 collapses the weight-aware score to TEAL's |x| criterion."""
    x, w = data
    g = sl.column_norms(w)
    s = np.asarray(sl.scores(x, g, 0.0))
    np.testing.assert_allclose(s, np.abs(np.asarray(x)), rtol=1e-5)


@given(xw(), st.floats(0.0, 1.5), st.floats(0.1, 1.0))
@settings(**COMMON)
def test_topk_shared_exact_on_kept_channels(data, alpha, kf):
    """Gather backend == dense matmul restricted to its kept channel set."""
    x, w = data
    n = w.shape[0]
    g = sl.column_norms(w)
    sp = {"g": g, "alpha": jnp.float32(alpha),
          "tau": jnp.float32(-jnp.inf), "keep_frac": jnp.float32(kf)}
    y = sl.project(x, w, sp, policy=sl.SparsityPolicy.uniform(
        "topk_shared", k_max_frac=kf))
    # reconstruct the same channel set
    sal = np.asarray(sl.scores(x, g, alpha)).reshape(-1, n).mean(0)
    k_max = max(1, round(n * kf))
    idx = np.argsort(-sal, kind="stable")[:k_max]
    k_l = int(np.round(kf * n))
    keep = idx[np.arange(k_max) < k_l]
    mask = np.zeros(n, np.float32)
    mask[keep] = 1
    yr = (np.asarray(x) * mask) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-3, atol=2e-3)


@given(st.integers(0, 2**16), st.integers(1, 64))
@settings(**COMMON)
def test_int8_error_feedback_preserves_sum(seed, n):
    """Compressed grads with error feedback: cumulative sum drift stays
    bounded by one quantization step (the EF invariant)."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(n,)).astype(np.float32)
    ef = np.zeros_like(g)
    tot_deq = np.zeros_like(g)
    steps = 8
    for _ in range(steps):
        deq, ef = adamw._quantize_int8(jnp.asarray(g), jnp.asarray(ef))
        deq, ef = np.asarray(deq), np.asarray(ef)
        tot_deq += deq
    # total transmitted + residual == total true gradient mass
    np.testing.assert_allclose(tot_deq + ef, g * steps, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**16))
@settings(**COMMON)
def test_column_norms_match_numpy(seed):
    k = jax.random.PRNGKey(seed)
    w = jax.random.normal(k, (16, 4, 3))
    g = np.asarray(sl.column_norms(w))
    ref = np.linalg.norm(np.asarray(w).reshape(16, -1), axis=1)
    np.testing.assert_allclose(g, ref, rtol=1e-5)


@given(st.integers(2, 40), st.floats(0.05, 0.5), st.integers(0, 1000))
@settings(**COMMON)
def test_evo_constraint_invariant(nblocks, eps, seed):
    """Alg. 3 repair loop keeps the weighted average at/below target."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(1, 10, nblocks)
    p_target = 0.5
    p = np.full(nblocks, p_target)
    for _ in range(5):
        q = p.copy()
        for b in rng.choice(nblocks, max(1, nblocks // 10), replace=False):
            q[b] = min(q[b] + eps, 0.95)
        guard = 0
        while np.sum(q * w) / np.sum(w) > p_target + 1e-9 and guard < 10000:
            j = rng.integers(nblocks)
            q[j] = max(q[j] - eps, 0.0)
            guard += 1
        p = q
        assert np.sum(p * w) / np.sum(w) <= p_target + 1e-9
        assert (p >= 0).all() and (p <= 0.95 + 1e-12).all()
