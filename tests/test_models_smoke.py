"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step on CPU (shapes + no
NaNs), plus exact prefill->decode vs full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import api, model as M
import repro.models.params as P
from repro.optim import adamw


def _batch(cfg, B=2, S=24, key=1):
    k = jax.random.PRNGKey(key)
    batch = {}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 9), (B, cfg.vision_prefix, cfg.d_model))
        batch["tokens"] = jax.random.randint(
            k, (B, S - cfg.vision_prefix), 0, cfg.vocab_size)
    elif cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(k, 9), (B, cfg.encoder_frames, cfg.d_model))
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return batch


def _pad_caches(cfg, caches, B, T):
    target = P.abstract_params(api.cache_schema(cfg, B, T), cfg.dtype)

    def fit(src, dst):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for s, d in zip(src.shape, dst.shape)]
        return jnp.pad(src, pads).astype(dst.dtype)

    return jax.tree_util.tree_map(fit, caches, target)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch):
    cfg = reduced(get_config(arch))
    params = api.init_model(cfg, 0)
    loss = api.make_loss_fn(cfg)(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced(get_config(arch))
    params = api.init_model(cfg, 0)
    opt_cfg = adamw.AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
    opt = adamw.init(params, opt_cfg)
    step = api.make_train_step(cfg, opt_cfg)
    new_params, new_opt, m = step(params, opt, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = reduced(get_config(arch))
    if cfg.num_experts:            # capacity-drop differs across seq lengths
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = api.init_model(cfg, 0)
    B, S, T = 2, 24, 32
    batch = _batch(cfg, B, S)
    kwargs = {k: v for k, v in batch.items() if k != "tokens"}
    toks = batch["tokens"]
    full, _ = M.forward(params, cfg, tokens=toks, mode="train", **kwargs)
    logits_pre, caches = M.forward(params, cfg, tokens=toks[:, :-1],
                                   mode="prefill", **kwargs)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full[:, -2]), atol=2e-5)
    caches = _pad_caches(cfg, caches, B, T)
    seq_total = full.shape[1]
    logits_dec, _ = M.forward(
        params, cfg, tokens=toks[:, -1], mode="decode", caches=caches,
        positions=jnp.full((B,), seq_total - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, -1]), atol=2e-5)


def test_rolling_window_cache_long_seq():
    cfg = reduced(get_config("gemma3_4b"))
    params = api.init_model(cfg, 0)
    B, S = 2, 60                       # window (32) < S exercises rolling
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, tokens=toks, mode="train")
    _, caches = M.forward(params, cfg, tokens=toks[:, :-1], mode="prefill")
    caches = _pad_caches(cfg, caches, B, 64)
    logits_dec, _ = M.forward(params, cfg, tokens=toks[:, -1], mode="decode",
                              caches=caches,
                              positions=jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, -1]), atol=2e-5)


def test_multi_step_decode_matches_forward():
    """Decode 4 tokens sequentially == full forward at each position."""
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    B, S, T = 2, 20, 28
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, tokens=toks, mode="train")
    _, caches = M.forward(params, cfg, tokens=toks[:, :S - 4], mode="prefill")
    caches = _pad_caches(cfg, caches, B, T)
    for i in range(4):
        pos = S - 4 + i
        logits, caches = M.forward(params, cfg, tokens=toks[:, pos],
                                   mode="decode", caches=caches,
                                   positions=jnp.full((B,), pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, pos]), atol=2e-5)
