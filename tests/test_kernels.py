"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import sparse_matmul as K

SHAPES = [
    (1, 256, 128, 128),     # matvec, tiny
    (4, 512, 384, 128),     # uneven m
    (8, 1024, 512, 256),    # bigger blocks
    (3, 384, 256, 128),     # B not multiple of bt
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(B, n, m, dtype, key=0):
    k = jax.random.PRNGKey(key)
    x = jax.random.normal(k, (B, n), dtype)
    w = (jax.random.normal(jax.random.fold_in(k, 1), (n, m), dtype) * 0.1
         ).astype(dtype)
    g = jnp.abs(jax.random.normal(jax.random.fold_in(k, 2), (n,))) + 0.1
    return x, w, g


@pytest.mark.parametrize("B,n,m,blk", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparse_matmul_shared(B, n, m, blk, dtype):
    x, w, _ = _data(B, n, m, dtype)
    nb = n // blk
    idx = jnp.arange(0, nb, 2, dtype=jnp.int32)      # every other block
    y = K.sparse_matmul_shared(x, w, idx, blk=blk, interpret=True)
    yr = ref.ref_sparse_matmul_shared(x, w, idx, blk)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,n,m,blk", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparse_matmul_per_seq(B, n, m, blk, dtype):
    x, w, _ = _data(B, n, m, dtype)
    nb = n // blk
    kb = max(nb // 2, 1)
    idx = jnp.stack([(jnp.arange(kb) + b) % nb for b in range(B)]
                    ).astype(jnp.int32)
    y = K.sparse_matmul_per_seq(x, w, idx, blk=blk, interpret=True)
    yr = ref.ref_sparse_matmul_per_seq(x, w, idx, blk)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,n,m,blk", SHAPES)
@pytest.mark.parametrize("alpha,tau", [(0.0, 0.3), (0.7, 0.5), (1.5, 1.0)])
def test_score_mask(B, n, m, blk, alpha, tau):
    x, _, g = _data(B, n, m, jnp.float32)
    xm, bs = K.score_mask(x, g, alpha, tau, blk=blk, interpret=True)
    xmr, bsr = ref.ref_score_mask(x, g, alpha, tau, blk)
    np.testing.assert_allclose(np.asarray(xm), np.asarray(xmr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(bs), np.asarray(bsr), rtol=1e-4)


@pytest.mark.parametrize("B,n,m,blk", SHAPES[:3])
@pytest.mark.parametrize("k_frac,keep_frac", [(1.0, 1.0), (0.75, 0.5),
                                              (0.5, 0.5)])
def test_wisparse_project_vs_oracle(B, n, m, blk, k_frac, keep_frac):
    x, w, g = _data(B, n, m, jnp.float32)
    sp = {"g": g, "alpha": jnp.float32(0.7), "tau": jnp.float32(0.2),
          "keep_frac": jnp.float32(keep_frac)}
    y = ops.wisparse_project(x, w, sp, block=blk, k_frac=k_frac,
                             interpret=True)
    kb = max(1, min(n // blk, round(n // blk * k_frac)))
    yr = ref.ref_wisparse_project(x, w, sp, k_blocks=kb, blk=blk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


# awkward (prime / non-divisible) batch and output dims: the kernels
# must pad to full tiles and slice, not silently degrade to 1-wide tiles
AWKWARD = [
    (5, 256, 257, 128),     # prime m, B below bt
    (13, 384, 131, 128),    # prime B above bt, prime m below mt
    (9, 512, 384, 256),     # B pads 9 -> 16, m tiles at 256 -> pads to 512
    (1, 128, 1, 128),       # matvec to a single output column
]


@pytest.mark.parametrize("B,n,m,blk", AWKWARD)
def test_sparse_matmul_shared_awkward_shapes(B, n, m, blk):
    x, w, _ = _data(B, n, m, jnp.float32)
    nb = n // blk
    idx = jnp.arange(0, nb, 2, dtype=jnp.int32)
    y = K.sparse_matmul_shared(x, w, idx, blk=blk, interpret=True)
    yr = ref.ref_sparse_matmul_shared(x, w, idx, blk)
    assert y.shape == (B, m)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,n,m,blk", AWKWARD[:3])
def test_sparse_matmul_per_seq_awkward_shapes(B, n, m, blk):
    x, w, _ = _data(B, n, m, jnp.float32)
    nb = n // blk
    kb = max(nb // 2, 1)
    idx = jnp.stack([(jnp.arange(kb) + b) % nb for b in range(B)]
                    ).astype(jnp.int32)
    y = K.sparse_matmul_per_seq(x, w, idx, blk=blk, interpret=True)
    yr = ref.ref_sparse_matmul_per_seq(x, w, idx, blk)
    assert y.shape == (B, m)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,n,m,blk", [(4, 257, 128, 128),
                                       (3, 384 + 7, 131, 128)])
def test_wisparse_project_awkward_channel_dim(B, n, m, blk):
    """Non-divisible channel dims pad to full-width blocks (the old
    fallback degraded blk to 1, changing both tiles and block-selection
    granularity).  Oracle: the same op on explicitly zero-padded
    inputs — padded channels score 0 and multiply zero weight rows."""
    x, w, g = _data(B, n, m, jnp.float32)
    sp = {"g": g, "alpha": jnp.float32(0.7), "tau": jnp.float32(0.2),
          "keep_frac": jnp.float32(0.5)}
    y = ops.wisparse_project(x, w, sp, block=blk, k_frac=0.75,
                             interpret=True)
    pad = -n % blk
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    wp = jnp.pad(w, ((0, pad), (0, 0)))
    sp_p = {**sp, "g": jnp.pad(g, (0, pad))}
    nb = (n + pad) // blk
    kb = max(1, min(nb, round(nb * 0.75)))
    yr = ref.ref_wisparse_project(xp, wp, sp_p, k_blocks=kb, blk=blk)
    assert y.shape == (B, m)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_interpret_auto_detects_backend():
    """interpret=None (the new default everywhere, including
    SparsityPolicy) resolves from the JAX backend: interpret-mode off
    TPU, compiled on TPU — forgetting the kwarg can no longer run the
    interpreter on real hardware."""
    assert K.default_interpret() == (jax.default_backend() != "tpu")
    x, w, g = _data(2, 256, 128, jnp.float32)
    idx = jnp.arange(0, 2, dtype=jnp.int32)
    y_auto = K.sparse_matmul_shared(x, w, idx)          # interpret=None
    y_explicit = K.sparse_matmul_shared(x, w, idx,
                                        interpret=K.default_interpret())
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_explicit))
    sp = {"g": g, "alpha": jnp.float32(0.5), "tau": jnp.float32(0.1),
          "keep_frac": jnp.float32(0.6)}
    y1 = ops.wisparse_project(x, w, sp, block=128, k_frac=0.8)
    y2 = ops.wisparse_project(x, w, sp, block=128, k_frac=0.8,
                              interpret=K.default_interpret())
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # the policy default threads through to the kernels
    from repro.sparsity import SparsityPolicy
    pol = SparsityPolicy.uniform("pallas", k_max_frac=0.8)
    assert pol.interpret is None
    assert SparsityPolicy.from_dict(pol.to_dict()) == pol   # survives io


def test_full_keep_matches_dense():
    """keep everything (tau=-inf, k=all) -> exactly the dense matmul."""
    x, w, g = _data(4, 512, 256, jnp.float32)
    sp = {"g": g, "alpha": jnp.float32(1.0), "tau": jnp.float32(-jnp.inf),
          "keep_frac": jnp.float32(1.0)}
    y = ops.wisparse_project(x, w, sp, block=128, k_frac=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_project_jit_and_grad_free():
    x, w, g = _data(2, 256, 128, jnp.float32)
    sp = {"g": g, "alpha": jnp.float32(0.5), "tau": jnp.float32(0.1),
          "keep_frac": jnp.float32(0.6)}
    f = jax.jit(lambda x: ops.wisparse_project(x, w, sp, block=128,
                                               k_frac=0.8))
    y1, y2 = f(x), ops.wisparse_project(x, w, sp, block=128, k_frac=0.8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
