"""Policy-ladder calibration: warm-started allocation invariants (rung
monotonicity, budget feasibility, fewer-generation convergence) and the
self-contained multi-rung artifact."""
import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced
from repro.core.allocation import (EvoConfig, block_fitness,
                                   block_level_allocation, weighted_average)
from repro.models import api
from repro.sparsity import PolicyLadder, SparsityPolicy, calibrate_ladder
from repro.sparsity.policy import _flatten_sp


# ---------------------------------------------------------------------------
# search invariants on a synthetic context (fast, deterministic)
# ---------------------------------------------------------------------------

class FakeCtx:
    """Minimal CalibContext stand-in with a quadratic fitness: block d
    contributes sens[d] * p[d]^2 KL, so the optimum prunes insensitive
    blocks hardest — enough structure for warm starts to matter."""

    def __init__(self, sens):
        self.sens = np.asarray(sens, float)
        self.num_blocks = len(self.sens)
        self.keys_by_depth = {d: ["l"] for d in range(self.num_blocks)}

    def block_weight(self, d):
        return 1.0

    def make_sp(self, alphas, ratios):
        return np.array([1.0 - ratios[(d, "l")]
                         for d in range(self.num_blocks)])

    def fitness(self, p):
        return float(np.sum(self.sens * np.asarray(p) ** 2))


def _sens(n, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.2, 5.0, size=n)


def test_warm_start_respects_floor_and_budget():
    ctx = FakeCtx(_sens(12, 3))
    evo = EvoConfig(generations=4, offspring=8, eps=0.02, seed=0)
    p1 = block_level_allocation(ctx, 0.3, evo)
    assert weighted_average(ctx, p1) <= 0.3 + 1e-9
    p2 = block_level_allocation(ctx, 0.6, evo, p_init=p1, p_min=p1,
                                generations=2)
    assert weighted_average(ctx, p2) <= 0.6 + 1e-9
    # monotone: the higher-budget rung never keeps more channels in any
    # block than the lower one
    assert (p2 >= p1 - 1e-12).all()


def test_warm_start_restores_budget_mass_lost_to_clipping():
    """A big budget jump clips shifted blocks at max_sparsity; the repair
    pass must redistribute that mass so the rung actually delivers its
    labeled budget (not silently less sparsity)."""
    ctx = FakeCtx(_sens(10, 11))
    evo = EvoConfig(generations=2, offspring=4, eps=0.02,
                    max_sparsity=0.95, seed=2)
    p1 = block_level_allocation(ctx, 0.5, evo)
    p2 = block_level_allocation(ctx, 0.9, evo, p_init=p1, p_min=p1,
                                generations=1)
    assert weighted_average(ctx, p2) <= 0.9 + 1e-9
    assert weighted_average(ctx, p2) >= 0.9 - evo.eps - 1e-9
    assert (p2 >= p1 - 1e-12).all()


def test_warm_start_infeasible_budget_raises():
    ctx = FakeCtx(_sens(6, 0))
    with pytest.raises(ValueError, match="ascending"):
        block_level_allocation(ctx, 0.2, EvoConfig(generations=1),
                               p_min=np.full(6, 0.5))


def test_warm_start_converges_in_fewer_generations():
    """Warm-starting from the adjacent rung reaches a better (or equal)
    fitness in a third of the generations of a cold search at the same
    budget."""
    ctx = FakeCtx(_sens(16, 7))
    evo = EvoConfig(generations=9, offspring=12, eps=0.02, seed=1)
    p_low = block_level_allocation(ctx, 0.3, evo)
    cold = block_level_allocation(ctx, 0.6, evo)
    warm = block_level_allocation(ctx, 0.6, evo, p_init=p_low, p_min=p_low,
                                  generations=3)
    assert block_fitness(ctx, warm) <= block_fitness(ctx, cold) + 1e-9


try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    @given(st.integers(4, 24), st.integers(0, 2**16),
           st.floats(0.05, 0.4), st.floats(0.05, 0.4))
    @settings(deadline=None, max_examples=20)
    def test_rung_monotonicity_property(n, seed, t1, dt):
        """Hypothesis: for any budgets t1 < t2 the warm-started rung is
        elementwise at least as sparse as the lower rung and both meet
        their budgets."""
        ctx = FakeCtx(_sens(n, seed))
        evo = EvoConfig(generations=2, offspring=4, eps=0.03,
                        seed=seed % 97)
        t2 = min(t1 + dt, 0.9)
        p1 = block_level_allocation(ctx, t1, evo)
        p2 = block_level_allocation(ctx, t2, evo, p_init=p1, p_min=p1,
                                    generations=1)
        assert weighted_average(ctx, p1) <= t1 + 1e-9
        assert weighted_average(ctx, p2) <= t2 + 1e-9
        assert (p2 >= p1 - 1e-12).all()
except ImportError:                                  # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# real-model ladder (tiny budgets)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ladder_setup():
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    ladder = calibrate_ladder(
        params, cfg, {"tokens": toks}, budgets=(0.0, 0.3, 0.6),
        evo=EvoConfig(generations=2, offspring=3, eps=0.1),
        warm_generations=1, delta=0.25, coord_passes=0)
    return params, cfg, ladder


def _keep_leaves(sp):
    """{path: keep_frac array} for one stacked sp tree."""
    return {k: v for k, v in _flatten_sp(sp).items()
            if k.endswith("/keep_frac")}


def test_calibrated_ladder_is_monotone(ladder_setup):
    _, _, ladder = ladder_setup
    assert len(ladder) == 3
    assert ladder.policies[0].is_dense
    # block-level prune ratios never decrease with the budget
    for lo, hi in zip(ladder.block_ratios, ladder.block_ratios[1:]):
        assert (np.asarray(hi) >= np.asarray(lo) - 1e-9).all()
    # per-linear keep fractions never increase with the budget
    for lo, hi in zip(ladder.sps, ladder.sps[1:]):
        klo, khi = _keep_leaves(lo), _keep_leaves(hi)
        assert klo.keys() == khi.keys()
        for k in klo:
            assert (khi[k] <= klo[k] + 1e-6).all(), k


def test_ladder_artifact_roundtrip(tmp_path, ladder_setup):
    """The whole ladder round-trips through one npz without the model
    checkpoint, sharing the g arrays across rungs."""
    _, _, ladder = ladder_setup
    f = str(tmp_path / "ladder.npz")
    ladder.save(f)

    z = np.load(f)
    # the weight-column norms are stored once (rung 0), not per rung
    assert any(k.startswith("sp0/") and k.endswith("/g") for k in z.files)
    assert not any(k.startswith(("sp1/", "sp2/")) and k.endswith("/g")
                   for k in z.files)

    l2 = PolicyLadder.load(f)
    assert l2.budgets == ladder.budgets
    assert l2.policies == ladder.policies
    for a, b in zip(ladder.sps, l2.sps):
        fa, fb = _flatten_sp(a), _flatten_sp(b)
        assert fa.keys() == fb.keys()
        for k in fa:
            np.testing.assert_array_equal(np.asarray(fa[k]),
                                          np.asarray(fb[k]))
    for a, b in zip(ladder.block_ratios, l2.block_ratios):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_artifact_kind_gates(tmp_path, ladder_setup):
    _, _, ladder = ladder_setup
    f = str(tmp_path / "ladder.npz")
    ladder.save(f)
    with pytest.raises(ValueError, match="PolicyLadder.load"):
        SparsityPolicy.load(f)
    g = str(tmp_path / "policy.npz")
    ladder.policies[1].save(g, sp=ladder.sps[1])
    with pytest.raises(ValueError, match="SparsityPolicy.load"):
        PolicyLadder.load(g)
    # single-policy artifacts still round-trip under the v2 format
    pol, sp = SparsityPolicy.load(g)
    assert pol == ladder.policies[1]


def test_ladder_validation():
    params_cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(params_cfg, 0)
    lad = PolicyLadder.uniform(params, params_cfg, budgets=(0.0, 0.5))
    assert len(lad) == 2 and lad.policies[0].is_dense
    with pytest.raises(ValueError, match="ascending"):
        PolicyLadder(budgets=(0.5, 0.3), policies=lad.policies,
                     sps=lad.sps)
    with pytest.raises(ValueError, match="rung count"):
        PolicyLadder(budgets=(0.1,), policies=lad.policies, sps=lad.sps)
